"""Distributed solve path: comm-strategy comparison on a forced 4-device mesh.

Measures the ring-overlapped sharded matvec (``ShardedGram(comm="ring")``,
docs/distributed.md) against the gather baseline — per-matvec collective
schedule (counted in the jaxpr: ``all_gather`` / ``ppermute`` / ``psum``),
solver matvec accounting per comm strategy, ring-vs-gather parity, and the
trace-counter proof that distributed SGD's regulariser never materialises the
(n, 2q) feature matrix.

The measurements run in a *subprocess* with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` set before jax imports:
the parent (benchmarks.run or check_matvecs) has already initialised a
single-device jax, and the forced host platform must not leak into it. The
worker prints one JSON document; the parent turns it into Report rows.

Gate (check_matvecs --distributed-baseline): matvec counts exact (zero slack),
collectives-per-matvec ≤ the committed baseline, ring ``all_gather`` == 0 and
SGD materialised-feature traces == 0 structurally on the fresh run.

CPU container note: the ring's *wall-clock* win needs real interconnect —
on a host-platform mesh the ppermute is a memcpy, so ``us_per_mv`` here is
informational (schedule structure, not speed, is what CI gates).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import Report

DEVICES = 4

_WORKER = r"""
import json, re, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import ShardedGram, make_params, solve, CG, SGD, AP
from repro.core.distributed import distributed_solve, shard_training_rows
from repro.kernels.ops import FEATURE_TRACE_COUNTS, reset_feature_trace_counts

n, d, s = map(int, (NSIZE, 3, 4))
devices = DEVCOUNT
mesh = jax.make_mesh((devices,), ("data",))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (n, d))
y = jnp.sin(x.sum(-1))
v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
p = make_params("se", lengthscale=1.0, noise=0.2, d=d)
xs = shard_training_rows(mesh, x)

out = {"n": n, "devices": devices, "comm": {}}
ops = {
    "gather": ShardedGram(x=xs, params=p, mesh=mesh, comm="gather"),
    "ring": ShardedGram(x=xs, params=p, mesh=mesh, comm="ring"),
}
for comm, op in ops.items():
    rec = {}
    # collective schedule of one matvec, straight from the jaxpr
    txt = str(jax.make_jaxpr(lambda w: op.mv(w))(v))
    for coll in ("all_gather", "ppermute", "psum"):
        rec[coll] = len(re.findall(rf"\b{coll}\b", txt))
    rec["collectives"] = rec["all_gather"] + rec["ppermute"] + rec["psum"]
    # wall per matvec (informational on a host-platform mesh)
    mv = jax.jit(lambda w: op.mv(w))
    mv(v).block_until_ready()
    t0 = time.time()
    reps = 20
    for _ in range(reps):
        r = mv(v)
    r.block_until_ready()
    rec["us_per_mv"] = (time.time() - t0) / reps * 1e6
    # solver accounting, comm-invariant: CG below its convergence region spends
    # exactly its budget, SGD exactly the finalize residual, AP zero
    res_cg = distributed_solve(p, xs, y, mesh, CG(max_iters=15, tol=1e-12),
                               comm=comm)
    rec["cg_matvecs"] = int(res_cg.matvecs)
    rec["cg_iterations"] = int(res_cg.iterations)
    reset_feature_trace_counts()
    res_sgd = distributed_solve(
        p, xs, y, mesh,
        SGD(num_steps=200, batch_size=64, num_features=32),
        comm=comm, backend="pallas", key=key,
    )
    rec["sgd_matvecs"] = int(res_sgd.matvecs)
    rec["sgd_feature_traces_materialised"] = int(FEATURE_TRACE_COUNTS["features"])
    rec["sgd_feature_traces_fused"] = int(FEATURE_TRACE_COUNTS["pallas"])
    res_ap = distributed_solve(p, xs, y, mesh,
                               AP(num_steps=30, block_size=32),
                               comm=comm, key=key)
    rec["ap_matvecs"] = int(res_ap.matvecs)
    out["comm"][comm] = rec

out["mv_parity"] = float(jnp.max(jnp.abs(
    jnp.asarray(ops["ring"].mv(v)) - jnp.asarray(ops["gather"].mv(v)))))
print("BENCH_JSON:" + json.dumps(out))
"""


def _run_worker(n: int) -> dict:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={DEVICES}"\n'
        'os.environ["JAX_PLATFORMS"] = "cpu"\n'
        + _WORKER.replace("NSIZE", str(n)).replace("DEVCOUNT", str(DEVICES))
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    if r.returncode != 0:
        raise RuntimeError(f"distributed worker failed:\n{r.stderr[-4000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("BENCH_JSON:"):
            return json.loads(line[len("BENCH_JSON:"):])
    raise RuntimeError(f"no BENCH_JSON line in worker output:\n{r.stdout[-2000:]}")


def run(report: Report, full: bool = False, smoke: bool = False) -> None:
    n = 1024 if full else 256
    data = _run_worker(n)
    ds = f"synthetic-{data['n']}x{data['devices']}dev"
    for comm, rec in data["comm"].items():
        report.add(
            "dist_collectives", f"mv_{comm}", ds,
            all_gather=rec["all_gather"], ppermute=rec["ppermute"],
            psum=rec["psum"], collectives=rec["collectives"],
            us_per_mv=rec["us_per_mv"],
        )
        report.add("dist_solve", f"cg_{comm}", ds,
                   matvecs=rec["cg_matvecs"], iterations=rec["cg_iterations"])
        report.add("dist_solve", f"sgd_{comm}", ds,
                   matvecs=rec["sgd_matvecs"],
                   feature_traces_materialised=rec[
                       "sgd_feature_traces_materialised"],
                   feature_traces_fused=rec["sgd_feature_traces_fused"])
        report.add("dist_solve", f"ap_{comm}", ds, matvecs=rec["ap_matvecs"])
    report.add("dist_mv", "ring_vs_gather", ds, parity=data["mv_parity"])
