"""Figures 4.1–4.3: primal-vs-dual step sizes, coordinates-vs-features noise,
momentum + geometric averaging ablations (Chapter 4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import gram, make_params
from repro.core.solvers.base import Gram
from repro.core.solvers.spec import SDD, SGD, solve
from repro.data.pipeline import regression_dataset

from .common import Report


def _setup(n=2000, seed=0):
    data = regression_dataset("pol", seed=seed)
    x, y = data["x"][:n], data["y"][:n]
    p = make_params("matern32", lengthscale=2.0, signal=1.0, noise=0.1, d=x.shape[1])
    op = Gram(x=x, params=p)
    kmat = gram(p, x) + p.noise * jnp.eye(n)
    v_star = jnp.linalg.solve(kmat, y)
    return op, y, v_star, kmat, p


def _knorm(w, kmat):
    return float(jnp.sqrt(jnp.maximum(w @ (kmat @ w), 0.0)))


def run(report: Report, full: bool = False):
    op, y, v_star, kmat, p = _setup(4000 if full else 1500)
    n = op.n

    # --- Fig 4.1: primal vs dual stability vs (normalised) step size -----------
    def primal_gd(steps, beta_n):
        beta = beta_n / n
        v = jnp.zeros_like(y)
        for _ in range(steps):
            g = op.mv_k(op.mv(v) - y)  # K(Kv + σ²v − y): primal gradient
            v = v - beta * g
        return v

    def dual_gd(steps, beta_n):
        beta = beta_n / n
        a = jnp.zeros_like(y)
        for _ in range(steps):
            a = a - beta * (op.mv(a) - y)  # dual gradient (Eq. 4.14)
        return a

    for beta_n in (0.1, 1.0, 10.0, 50.0):
        vp = primal_gd(150, beta_n)
        vd = dual_gd(150, beta_n)
        report.add("dual(F4.1)", f"primal β·n={beta_n}", "pol",
                   k_err=_knorm(vp - v_star, kmat) if jnp.isfinite(vp).all() else float("inf"))
        report.add("dual(F4.1)", f"dual   β·n={beta_n}", "pol",
                   k_err=_knorm(vd - v_star, kmat) if jnp.isfinite(vd).all() else float("inf"))

    # --- Fig 4.2: random features (additive noise) vs random coordinates -------
    res_coord = solve(op, y, SDD(num_steps=10_000, batch_size=256,
                                 step_size_times_n=5.0), key=jax.random.PRNGKey(0))
    res_feat = solve(op, y, SGD(num_steps=10_000, batch_size=256, num_features=100,
                                step_size_times_n=0.5), key=jax.random.PRNGKey(0))
    report.add("dual(F4.2)", "rand-coordinates", "pol",
               k_err=_knorm(res_coord.solution - v_star, kmat),
               rel_resid=float(res_coord.rel_residual.max()))
    report.add("dual(F4.2)", "rand-features(SGD)", "pol",
               k_err=_knorm(res_feat.solution - v_star, kmat),
               rel_resid=float(res_feat.rel_residual.max()))

    # --- Fig 4.3: momentum / averaging ablation ---------------------------------
    for mom, avg, label in [(0.0, 1.0, "no-momentum"), (0.9, 1.0, "nesterov"),
                            (0.9, None, "nesterov+geom-avg")]:
        r = solve(op, y, SDD(num_steps=6_000, batch_size=256,
                             step_size_times_n=5.0, momentum=mom, averaging=avg),
                  key=jax.random.PRNGKey(1))
        report.add("dual(F4.3)", label, "pol",
                   k_err=_knorm(r.solution - v_star, kmat))
