"""Pallas Gram-matvec kernel: block-shape sweep (VMEM footprint × arithmetic
intensity trade) + correctness-vs-ref at each point. Runs in interpret mode on
CPU, so the numbers reported are the *analytic* VMEM/intensity terms that drive
TPU block choice; wall-clock ranking comes from real hardware.

Also regenerates ``results/AUTOTUNE_gram.json`` — the committed block-size
table ``block="auto"`` resolves from at trace time (kernels/autotune.py). Every
key of the autotune shape grid gets an entry: on TPU the candidates are timed
and the fastest wins; off-TPU (interpret mode times kernel *emulation*, not
kernels) the VMEM-budget model picks, which keeps the artifact honest — the
committed table never encodes CPU-emulation rankings as TPU advice.
``check_matvecs.py`` gates the table's keys against the grid, so changing the
grid without re-running this bench fails CI.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.kernels import autotune
from repro.kernels.ops import gram_matvec, rff_matvec
from repro.kernels.ref import gram_matvec_ref

from .common import Report, timed


def _vmem_bytes(bm, bn, d, s):
    # x tile + z tile + v tile + k tile + accumulator (fp32)
    return 4 * (bm * d + bn * d + bn * s + bm * bn + bm * s)


def _timed_block(family: str, n: int, d: int, dtype: str) -> int:
    """Fastest candidate block by measurement — real hardware only."""
    s = autotune.RHS_WIDTH_ESTIMATE
    precision = "bf16" if dtype == "bfloat16" else "fp32"
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    best, best_dt = None, float("inf")
    for b in autotune.CANDIDATE_BLOCKS:
        if b > max(autotune.CANDIDATE_BLOCKS[-1], n):
            continue
        if autotune.vmem_bytes(family, b, b, d, s=s, dtype=dtype) > autotune.VMEM_BUDGET_BYTES:
            continue
        if family == "gram":
            v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
            _, dt = timed(gram_matvec, make_params("se", d=d), x, v,
                          block=b, precision=precision)
        else:
            m = max(b, 128)
            om = jax.random.normal(jax.random.fold_in(key, 2), (m, d))
            w = jax.random.normal(jax.random.fold_in(key, 3), (2 * m, s))
            _, dt = timed(rff_matvec, x, om, w, block=b, precision=precision)
        if dt < best_dt:
            best, best_dt = b, dt
    return best if best is not None else autotune.CANDIDATE_BLOCKS[-1]


def emit_autotune_table(report: Report) -> None:
    """Write the full-grid block table to ``results/AUTOTUNE_gram.json``."""
    on_tpu = jax.default_backend() == "tpu"
    table = {}
    for fam in autotune.FAMILIES:
        for n in autotune.N_GRID:
            for d in autotune.D_GRID:
                for dtype in autotune.DTYPES:
                    k = autotune.table_key(fam, n, d, dtype)
                    if on_tpu:
                        table[k] = _timed_block(fam, n, d, dtype)
                    else:
                        table[k] = autotune.heuristic_block(fam, n, d, dtype=dtype)
    path = autotune.DEFAULT_TABLE_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(
            {
                "table": table,
                "source": "timed" if on_tpu else "vmem-model",
                "grid": {
                    "families": list(autotune.FAMILIES),
                    "n": list(autotune.N_GRID),
                    "d": list(autotune.D_GRID),
                    "dtypes": list(autotune.DTYPES),
                    "candidates": list(autotune.CANDIDATE_BLOCKS),
                },
            },
            f, indent=1, sort_keys=True,
        )
    autotune.load_table.cache_clear()
    report.add("gram-autotune", "timed" if on_tpu else "vmem-model", path,
               entries=len(table), missing=len(autotune.expected_keys() - set(table)))


def run(report: Report, full: bool = False):
    n, d, s = (2048, 8, 16) if not full else (8192, 8, 32)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    p = make_params("matern32", lengthscale=1.0, signal=1.0, d=d, noise=0.1)
    ref = gram_matvec_ref(x / p.lengthscale, x / p.lengthscale, v,
                          kind="matern32", signal=1.0, jitter=0.1)
    for block in (128, 256, 512):
        out, dt = timed(gram_matvec, p, x, v, jitter=0.1, block=block, interpret=True)
        err = float(np.abs(np.asarray(out - ref)).max())
        vmem = _vmem_bytes(block, block, d, s)
        intensity = (2 * block * d + 2 * block * s) and (
            (2.0 * block * block * (d + s + 8)) / (4.0 * (2 * block * d + 2 * block * s))
        )
        report.add("gram-kernel", f"block={block}", f"n={n}",
                   max_err=err, vmem_kb=round(vmem / 1024, 1),
                   flops_per_byte=round(intensity, 1),
                   fits_vmem=vmem < 16 * 2**20)

    # the differentiable hot path: forward + custom-VJP backward (three fused
    # Pallas contractions), timed against autodiff through the dense Gram
    def fused_quad(params):
        return jnp.sum(v * gram_matvec(p_like(params), x, v, block=256,
                                       interpret=True))

    def dense_quad(params):
        from repro.core.kernels_fn import gram

        return jnp.sum(v * (gram(p_like(params), x) @ v))

    def p_like(theta):
        import dataclasses as dc

        return dc.replace(p, log_lengthscale=theta)

    theta0 = p.log_lengthscale
    g_fused, dt_f = timed(jax.grad(fused_quad), theta0)
    g_dense, dt_d = timed(jax.grad(dense_quad), theta0)
    report.add("gram-kernel-vjp", "fused-vs-dense", f"n={n}",
               max_err=float(np.abs(np.asarray(g_fused - g_dense)).max()),
               seconds_fused=round(dt_f, 3), seconds_dense=round(dt_d, 3))

    emit_autotune_table(report)
