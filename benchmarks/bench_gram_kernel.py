"""Pallas Gram-matvec kernel: block-shape sweep (VMEM footprint × arithmetic
intensity trade) + correctness-vs-ref at each point. Runs in interpret mode on
CPU, so the numbers reported are the *analytic* VMEM/intensity terms that drive
TPU block choice; wall-clock ranking comes from real hardware."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.kernels.ops import gram_matvec
from repro.kernels.ref import gram_matvec_ref

from .common import Report, timed


def _vmem_bytes(bm, bn, d, s):
    # x tile + z tile + v tile + k tile + accumulator (fp32)
    return 4 * (bm * d + bn * d + bn * s + bm * bn + bm * s)


def run(report: Report, full: bool = False):
    n, d, s = (2048, 8, 16) if not full else (8192, 8, 32)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    p = make_params("matern32", lengthscale=1.0, signal=1.0, d=d, noise=0.1)
    ref = gram_matvec_ref(x / p.lengthscale, x / p.lengthscale, v,
                          kind="matern32", signal=1.0, jitter=0.1)
    for block in (128, 256, 512):
        out, dt = timed(gram_matvec, p, x, v, jitter=0.1, block=block, interpret=True)
        err = float(np.abs(np.asarray(out - ref)).max())
        vmem = _vmem_bytes(block, block, d, s)
        intensity = (2 * block * d + 2 * block * s) and (
            (2.0 * block * block * (d + s + 8)) / (4.0 * (2 * block * d + 2 * block * s))
        )
        report.add("gram-kernel", f"block={block}", f"n={n}",
                   max_err=err, vmem_kb=round(vmem / 1024, 1),
                   flops_per_byte=round(intensity, 1),
                   fits_vmem=vmem < 16 * 2**20)

    # the differentiable hot path: forward + custom-VJP backward (three fused
    # Pallas contractions), timed against autodiff through the dense Gram
    def fused_quad(params):
        return jnp.sum(v * gram_matvec(p_like(params), x, v, block=256,
                                       interpret=True))

    def dense_quad(params):
        from repro.core.kernels_fn import gram

        return jnp.sum(v * (gram(p_like(params), x) @ v))

    def p_like(theta):
        import dataclasses as dc

        return dc.replace(p, log_lengthscale=theta)

    theta0 = p.log_lengthscale
    g_fused, dt_f = timed(jax.grad(fused_quad), theta0)
    g_dense, dt_d = timed(jax.grad(dense_quad), theta0)
    report.add("gram-kernel-vjp", "fused-vs-dense", f"n={n}",
               max_err=float(np.abs(np.asarray(g_fused - g_dense)).max()),
               seconds_fused=round(dt_f, 3), seconds_dense=round(dt_d, 3))
