"""Chapter 6: latent Kronecker efficiency — measured FLOP ratio vs the §6.2.6
break-even formula, and LKGP vs standard iterative GP resource use."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.core.kronecker import (
    break_even_density, lkgp_matvec_flops, lkgp_posterior, make_lkgp,
)
from repro.data.pipeline import grid_curves

from .common import Report, timed


def run(report: Report, full: bool = False):
    # --- break-even accuracy (Table/formula §6.2.6) ---------------------------
    for n1, n2 in [(64, 32), (128, 50), (256, 100)]:
        rho_star = break_even_density(n1, n2)
        lk, direct = lkgp_matvec_flops(n1, n2, rho_star)
        report.add("kronecker(§6.2.6)", "break-even", f"{n1}x{n2}",
                   rho_star=round(rho_star, 4), flop_ratio=round(lk / direct, 3))
        for mult in (0.5, 2.0):
            rho = min(1.0, rho_star * mult)
            lk, direct = lkgp_matvec_flops(n1, n2, rho)
            report.add("kronecker(§6.2.6)", f"rho={mult}·rho*", f"{n1}x{n2}",
                       flop_ratio=round(lk / direct, 3))

    # --- LKGP vs dense-matvec iterative GP on a masked grid --------------------
    size = (96, 40) if not full else (512, 50)
    data = grid_curves(n_configs=size[0], n_steps=size[1], density=0.7, seed=0)
    mask = np.asarray(data["mask"])
    n_obs = int(mask.sum())
    p1 = make_params("matern52", lengthscale=1.0, signal=1.0, d=4)
    p2 = make_params("matern52", lengthscale=1.0, signal=1.0, d=1)
    gp = make_lkgp(p1, p2, data["grid1"], data["grid2"], data["mask"], 1e-2)
    y_obs = data["curves"].reshape(-1)[jnp.asarray(np.nonzero(mask.reshape(-1))[0])]
    (mean, samples), dt_lk = timed(lkgp_posterior, gp, y_obs - y_obs.mean(),
                                   jax.random.PRNGKey(0), num_samples=8,
                                   max_iters=200)
    report.add("kronecker(§6.3)", "LKGP", f"{size[0]}x{size[1]}",
               n_obs=n_obs, seconds=round(dt_lk, 2),
               density=round(n_obs / (size[0] * size[1]), 3),
               rho_star=round(break_even_density(*size), 3))

    # standard iterative GP on the same observations (dense matvec on n_obs)
    from repro.core.pathwise import posterior_functions
    from repro.core.solvers.spec import CG

    grid_x = np.repeat(np.asarray(data["grid1"]), size[1], axis=0)
    grid_t = np.tile(np.asarray(data["grid2"]), (size[0], 1))
    x_all = jnp.asarray(np.concatenate([grid_x, grid_t], axis=1))
    x_obs = x_all[jnp.asarray(np.nonzero(mask.reshape(-1))[0])]
    p_flat = make_params("matern52", lengthscale=1.0, signal=1.0, noise=1e-1, d=5)
    pf, dt_std = timed(posterior_functions, p_flat, x_obs, y_obs - y_obs.mean(),
                       jax.random.PRNGKey(1), num_samples=8, num_features=1024,
                       spec=CG(max_iters=200))
    report.add("kronecker(§6.3)", "standard-iterGP", f"{size[0]}x{size[1]}",
               n_obs=n_obs, seconds=round(dt_std, 2),
               lkgp_speedup=round(dt_std / max(dt_lk, 1e-9), 2))
