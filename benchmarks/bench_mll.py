"""Chapter 5 (Fig 5.1 + early stopping): pathwise gradient estimator + warm
starting — total inner-solver iterations and wall time per MLL optimisation.

``smoke=True`` (the CI iteration-count gate, ``benchmarks/check_matvecs.py``)
keeps the committed problem size, outer-step count, PRNG keys and CG spec — so
the ``solver_iters`` totals are comparable to the committed
``results/BENCH_bench_mll.json`` — and only skips the rows the gate does not
compare (the Hutchinson estimator and the §5.4 early-stopping study).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.gp import exact_mll
from repro.core.kernels_fn import make_params
from repro.core.mll import optimize_mll
from repro.core.solvers.spec import CG
from repro.data.pipeline import regression_dataset

from .common import Report


def run(report: Report, full: bool = False, smoke: bool = False):
    data = regression_dataset("elevators", seed=0)
    n = 4000 if full else 1200
    x, y = data["x"][:n], data["y"][:n]
    d = x.shape[1]
    p0 = make_params("matern32", lengthscale=2.0, signal=0.5, noise=0.5, d=d)
    kw = dict(num_steps=12, lr=0.08, num_probes=8, spec=CG(max_iters=600, tol=1e-3))

    rows = {}
    estimators = ("pathwise",) if smoke else ("hutchinson", "pathwise")
    for est in estimators:
        for warm in (False, True):
            t0 = time.time()
            st = optimize_mll(p0, x, y, jax.random.PRNGKey(0), warm_start=warm,
                              estimator=est, **kw)
            dt = time.time() - t0
            mll = float(exact_mll(st.params, x, y)) / n
            label = f"{est}{'+warm' if warm else ''}"
            rows[label] = st.total_solver_iters
            report.add("mll(F5.1)", label, "elevators",
                       solver_iters=st.total_solver_iters, seconds=round(dt, 1),
                       mll_per_n=round(mll, 4))
    if smoke:
        return
    base = rows.get("hutchinson", 1)
    best = rows.get("pathwise+warm", base)
    report.add("mll(F5.1)", "speedup", "elevators",
               iteration_reduction=round(base / max(best, 1), 2))

    # §5.4 early stopping: residual after a fixed budget, warm vs cold
    from repro.core.solvers.base import Gram
    from repro.core.solvers.spec import solve

    p = make_params("matern32", lengthscale=1.5, signal=1.0, noise=0.2, d=d)
    op = Gram(x=x, params=p)
    cold = solve(op, y, CG(max_iters=20, tol=0.0))
    # warm start from a cheap preliminary solve at slightly different θ
    import dataclasses
    p_near = dataclasses.replace(p, log_lengthscale=p.log_lengthscale + 0.05)
    prelim = solve(Gram(x=x, params=p_near), y, CG(max_iters=60, tol=0.0))
    warm = solve(op, y, CG(max_iters=20, tol=0.0), x0=prelim.solution)
    report.add("mll-earlystop(§5.4)", "cold-20it", "elevators",
               rel_resid=float(cold.rel_residual.max()))
    report.add("mll-earlystop(§5.4)", "warm-20it", "elevators",
               rel_resid=float(warm.rel_residual.max()),
               reduction=round(float(cold.rel_residual.max())
                               / max(float(warm.rel_residual.max()), 1e-12), 1))
