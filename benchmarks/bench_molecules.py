"""Table 4.2: molecule–protein binding affinity — Tanimoto-kernel GP via SDD
(synthetic fingerprints/scores; structure matches the DOCKSTRING benchmark)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import TANIMOTO, gram, make_params
from repro.core.solvers.base import Gram
from repro.core.solvers.spec import CG, SDD, solve
from repro.data.pipeline import molecule_fingerprints

from .common import Report


def _r2(y, pred):
    y, pred = np.asarray(y), np.asarray(pred)
    ss_res = ((y - pred) ** 2).sum()
    ss_tot = ((y - y.mean()) ** 2).sum()
    return float(1.0 - ss_res / ss_tot)


def run(report: Report, full: bool = False):
    n = 8192 if full else 2048
    for protein_seed, name in enumerate(["ESR2", "F2", "KIT"]):
        data = molecule_fingerprints(n=n, dim=1024, seed=protein_seed)
        p = make_params(TANIMOTO, signal=1.0, noise=0.3)
        op = Gram(x=data["x"], params=p)
        k_test = gram(p, data["x_test"], data["x"])
        for method, spec in [
            ("SDD", SDD(num_steps=6000, batch_size=256, step_size_times_n=2.0)),
            ("CG", CG(max_iters=200, tol=1e-4)),
        ]:
            res = solve(op, data["y"], spec, key=jax.random.PRNGKey(0))
            pred = k_test @ res.solution
            report.add("molecules(T4.2)", method, name, r2=round(_r2(data["y_test"], pred), 3))
        # mean predictor control
        report.add("molecules(T4.2)", "mean-baseline", name,
                   r2=round(_r2(data["y_test"], np.zeros(len(data["y_test"]))), 3))
