"""Guardrail-overhead benchmark: ``solve_robust`` must be free on the happy
path and effective on the broken one (``docs/robustness.md``).

Three row families in ``BENCH_bench_robust.json``:

* ``robust_overhead`` — plain ``solve`` vs ``solve_robust`` on a
  well-conditioned system: identical matvec counts (the in-loop health checks
  reuse reductions the solvers already compute; the ladder adds one host
  readback of the (s,) flags vector) and wall-clock overhead < 2%. The
  ``overhead_pct`` metric is the headline number; matvec equality is the
  structural gate ``check_matvecs.py --robust-baseline`` enforces.
* ``robust_recovery`` — the near-singular stagnation problem: the ladder
  recovers every flagged column and the row records which rungs it took and
  what the rescue cost in matvecs.
* ``robust_failure`` — a poisoned (NaN) RHS: every rung declines, the report
  is a structured failure, and the healthy columns' payloads survive intact.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EscalationPolicy, Gram, make_params, solve, solve_robust
from repro.testing import nan_columns, near_singular_problem

from .common import Report

#: gated workload shape — keep in lockstep with the committed baseline.
#: s=16 is a serving-realistic RHS width (the engine buckets columns to
#: powers of two); the guardrail cost is O(1) per solve, so the overhead
#: bound is measured against a representative per-solve cost, not a toy one.
N, D_IN, S = 512, 3, 16
SPEC_KW = dict(max_iters=120, tol=1e-4)


def _happy_problem():
    key = jax.random.PRNGKey(0)
    kx, kb = jax.random.split(key)
    x = jax.random.uniform(kx, (N, D_IN))
    params = make_params("matern32", lengthscale=0.5, signal=1.0, noise=0.1,
                         d=D_IN)
    return Gram(x=x, params=params), jax.random.normal(kb, (N, S))


def _walls_interleaved(fns, reps: int):
    """Best-of-``reps`` wall per fn, sampled interleaved so clock drift and
    cache state hit every variant equally (the overhead being measured is a
    fraction of a percent — sequential medians would drown it in noise)."""
    for fn in fns:  # warmup: compile excluded
        jax.block_until_ready(fn().solution)
    best = [float("inf")] * len(fns)
    for r in range(reps):
        order = range(len(fns)) if r % 2 == 0 else reversed(range(len(fns)))
        for i in order:  # ABBA alternation: drift cancels across variants
            t0 = time.perf_counter()
            jax.block_until_ready(fns[i]().solution)
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def run(report: Report, full: bool = False, smoke: bool = False):
    op, b = _happy_problem()
    reps = 10 if smoke else (100 if full else 60)

    # ---- happy path: the guardrails must cost nothing ----------------------
    plain = solve(op, b, "cg", **SPEC_KW)
    robust = solve_robust(op, b, "cg", **SPEC_KW)
    assert not robust.escalated, "happy-path problem escalated — bench invalid"
    plain_mv, robust_mv = int(plain.matvecs), int(robust.result.matvecs)
    wall_plain, wall_robust = _walls_interleaved(
        [
            lambda: solve(op, b, "cg", **SPEC_KW),
            lambda: solve_robust(op, b, "cg", **SPEC_KW).result,
        ],
        reps,
    )
    overhead = 100.0 * (wall_robust - wall_plain) / wall_plain
    report.add(
        "robust_overhead", "plain", f"n={N} s={S}",
        matvecs=plain_mv, wall_s=round(wall_plain, 4),
    )
    report.add(
        "robust_overhead", "robust", f"n={N} s={S}",
        matvecs=robust_mv, wall_s=round(wall_robust, 4),
        overhead_pct=round(overhead, 2),
        matvecs_equal=int(plain_mv == robust_mv),
    )

    # ---- recovery: near-singular stagnation rides the ladder home ----------
    op_ns, b_ns, _, _ = near_singular_problem(96, 3)
    rep = solve_robust(
        op_ns, b_ns, "cg", max_iters=200, tol=1e-6, stall_window=30,
        policy=EscalationPolicy(),
    )
    report.add(
        "robust_recovery", "ladder", "near_singular n=96",
        recovered=int(rep.recovered),
        rungs=len(rep.rungs),
        failed_columns=len(rep.failed_columns),
        matvecs=int(rep.result.matvecs),
        ladder=" > ".join(rep.ladder),
    )

    # ---- structured failure: a poisoned RHS fails loudly, not silently -----
    rep_bad = solve_robust(op, nan_columns(b, (1,)), "cg", **SPEC_KW)
    healthy_ok = bool(
        jnp.array_equal(rep_bad.result.solution[:, 0], plain.solution[:, 0])
    )
    report.add(
        "robust_failure", "nan_rhs", f"n={N} s={S}",
        escalated=int(rep_bad.escalated),
        failed_columns=len(rep_bad.failed_columns),
        healthy_columns_intact=int(healthy_ok),
    )
