"""§Roofline: render the dry-run JSONL (launch/dryrun.py --out) as the roofline
table — per (arch × shape × mesh): three terms, dominant bottleneck, MFU."""
from __future__ import annotations

import json
import os

from .common import Report

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def run(report: Report, full: bool = False, path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        report.add("roofline", "missing", "-",
                   note=f"run `python -m repro.launch.dryrun --all --both-meshes --out {path}` first")
        return
    for line in open(path):
        r = json.loads(line)
        tag = f"{r['arch']}×{r['shape']}"
        if r["status"] != "ok":
            report.add("roofline", r["mesh"], tag, status=r["status"])
            continue
        rf = r["roofline"]
        report.add(
            "roofline", r["mesh"], tag,
            compute_s=round(rf["compute_s"], 4), memory_s=round(rf["memory_s"], 4),
            collective_s=round(rf["collective_s"], 4), dominant=rf["dominant"],
            mfu=rf["mfu"], useful=rf["useful_fraction"],
            hbm_gb=r["hbm_per_device"]["total_gb"],
        )
