"""Serving engine benchmark: continuous batching vs sequential service, and
warm-started repeat queries vs cold ones (``src/repro/serve``).

Three claims, each a row family in ``BENCH_bench_serve.json``:

* ``serve_throughput`` — at queue depth D, serving D queued sample requests as
  ONE shared multi-RHS solve (engine cap = D) vs one request per step
  (cap = 1). The shared solve amortises the O(n²d) Gram kernel evaluation over
  every rider's RHS columns (§2.2.4), so batched wall-clock ≈ one solve.
* ``serve_speedup`` — the headline ratio: sequential wall / batched wall at
  each depth (the acceptance bar is ≥ 3× at depth ≥ 8).
* ``serve_warmstart`` — identical requests resubmitted after completion hit
  the warm-start cache and re-enter CG at their previous solution (Ch. 5
  §5.3): the warm batch's iteration count collapses vs the cold batch's.

``serve_solve``/``serve_warmstart`` rows carry matvec/iteration counts gated by
``check_matvecs.py`` (smoke mode keeps the gated workload — problem size, PRNG
seeds, CG spec — identical to the committed baseline and only drops the
ungated depth sweep).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import make_params
from repro.core.solvers.spec import CG
from repro.serve import GPEngine, percentile

from .common import Report

#: gated workload shape — keep in lockstep with the committed baseline
N, D_IN = 512, 3
NUM_SAMPLES = 4  # RHS columns per request
NUM_ROWS = 16  # query rows per request
GATED_DEPTH = 8


def _dataset(n: int, d: int):
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.uniform(kx, (n, d))
    w = jax.random.normal(kw, (d,))
    y = jnp.sin(4.0 * (x @ w)) + 0.1 * jnp.cos(7.0 * x[:, 0])
    return x, y


def _engine(params, x, y, cap: int) -> GPEngine:
    return GPEngine(
        params, x, y,
        spec=CG(max_iters=200, tol=1e-4),
        num_samples=4,
        num_features=256,
        seed=0,
        max_batch_requests=cap,
        max_rhs_columns=128,
    )


def _xs(i: int, d: int):
    return jax.random.uniform(jax.random.fold_in(jax.random.PRNGKey(7), i),
                              (NUM_ROWS, d))


def _wave(engine: GPEngine, d: int, seeds) -> tuple:
    """Submit one sample request per seed, drain, return (handles, wall_s)."""
    handles = [
        engine.sample(_xs(s, d), num_samples=NUM_SAMPLES, seed=s) for s in seeds
    ]
    t0 = time.perf_counter()
    engine.run_until_idle()
    return handles, time.perf_counter() - t0


def run(report: Report, full: bool = False, smoke: bool = False):
    x, y = _dataset(N, D_IN)
    params = make_params("matern32", lengthscale=0.5, signal=1.0, noise=0.1,
                         d=D_IN)

    # ---- throughput: batched vs sequential at each queue depth -------------
    depths = [GATED_DEPTH] if smoke else ([2, GATED_DEPTH, 16] if full
                                          else [2, GATED_DEPTH])
    walls = {}
    for depth in depths:
        for method, cap in (("sequential", 1), ("batched", depth)):
            eng = _engine(params, x, y, cap)
            # warmup wave: same bucketed shapes, throwaway seeds — pays the
            # compile cost so the measured wave times math, not tracing
            _wave(eng, D_IN, range(10_000, 10_000 + depth))
            before = eng.stats()
            handles, wall = _wave(eng, D_IN, range(depth))
            after = eng.stats()
            lat = [h.result().metrics["total_s"] for h in handles]
            iters = after["solver_iterations"] - before["solver_iterations"]
            matvecs = after["solver_matvecs"] - before["solver_matvecs"]
            solves = after["solves"] - before["solves"]
            walls[(depth, method)] = wall
            report.add(
                "serve_throughput", method, f"n={N} depth={depth}",
                req_s=round(depth / wall, 2),
                wall_s=round(wall, 3),
                p50_s=round(percentile(lat, 50), 4),
                p99_s=round(percentile(lat, 99), 4),
                solves=solves,
                iterations=iters,
            )
            if method == "batched" and depth == GATED_DEPTH:
                # the gated row: D coalesced requests = one bucketed solve
                report.add(
                    "serve_solve", "cg-batched",
                    f"n={N} cols={depth * NUM_SAMPLES}",
                    matvecs=matvecs, iterations=iters, solves=solves,
                )
        speedup = walls[(depth, "sequential")] / walls[(depth, "batched")]
        report.add(
            "serve_speedup", "batched/sequential", f"n={N} depth={depth}",
            speedup=round(speedup, 2),
            sequential_s=round(walls[(depth, "sequential")], 3),
            batched_s=round(walls[(depth, "batched")], 3),
        )

    # ---- warm starts: identical requests resubmitted hit the cache --------
    eng = _engine(params, x, y, GATED_DEPTH)
    seeds = range(100, 100 + GATED_DEPTH)
    # compile warmup for BOTH variants: a cold wave, then its warm resubmission
    # (the warm solve carries x0 and δ, a different compiled program)
    _wave(eng, D_IN, range(10_000, 10_000 + GATED_DEPTH))
    _wave(eng, D_IN, range(10_000, 10_000 + GATED_DEPTH))
    cold_handles, cold_wall = _wave(eng, D_IN, seeds)
    warm_handles, warm_wall = _wave(eng, D_IN, seeds)  # repeat seeds → warm
    cold_iters = cold_handles[0].result().metrics["iterations"]
    warm_iters = warm_handles[0].result().metrics["iterations"]
    assert all(h.result().metrics["warm"] for h in warm_handles)
    snap = eng.stats()
    report.add(
        "serve_warmstart", "cold", f"n={N} depth={GATED_DEPTH}",
        iterations=cold_iters, wall_s=round(cold_wall, 3),
    )
    report.add(
        "serve_warmstart", "warm", f"n={N} depth={GATED_DEPTH}",
        iterations=warm_iters, wall_s=round(warm_wall, 3),
        warm_hits=snap["warm_hits"], saved=snap["iterations_saved_warm"],
    )

    if smoke:
        return

    # ---- mixed workload snapshot (not gated): realistic request mix --------
    eng = _engine(params, x, y, GATED_DEPTH)
    handles = []
    for i in range(GATED_DEPTH):
        handles.append(eng.predict(_xs(200 + i, D_IN), seed=200 + i))
        handles.append(eng.sample(_xs(300 + i, D_IN), num_samples=NUM_SAMPLES,
                                  seed=300 + i))
    t0 = time.perf_counter()
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    lat = [h.result().metrics["total_s"] for h in handles]
    report.add(
        "serve_mixed", "predict+sample", f"n={N} depth={2 * GATED_DEPTH}",
        req_s=round(len(handles) / wall, 2), wall_s=round(wall, 3),
        p50_s=round(percentile(lat, 50), 4), p99_s=round(percentile(lat, 99), 4),
        steps=eng.stats()["steps"],
    )
