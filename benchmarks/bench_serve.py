"""Serving engine benchmark: continuous batching vs sequential service, and
warm-started repeat queries vs cold ones (``src/repro/serve``).

Three claims, each a row family in ``BENCH_bench_serve.json``:

* ``serve_throughput`` — at queue depth D, serving D queued sample requests as
  ONE shared multi-RHS solve (engine cap = D) vs one request per step
  (cap = 1). The shared solve amortises the O(n²d) Gram kernel evaluation over
  every rider's RHS columns (§2.2.4), so batched wall-clock ≈ one solve.
* ``serve_speedup`` — the headline ratio: sequential wall / batched wall at
  each depth (the acceptance bar is ≥ 3× at depth ≥ 8).
* ``serve_warmstart`` — identical requests resubmitted after completion hit
  the warm-start cache and re-enter CG at their previous solution (Ch. 5
  §5.3): the warm batch's iteration count collapses vs the cold batch's.
* ``serve_refit`` — the write-heavy section: appending k observations via the
  rank-k bordered correction (``update_state_lowrank``: k solve columns at the
  OLD n + one certification matvec) vs the warm full refit (``extend_state``:
  1+s columns at n+k). The cost metric is ``matvec_columns`` — column-passes
  of the full operator, the O(n²·c) work a multi-RHS iterative solver actually
  does — where the rank-k path's spend is independent of the posterior sample
  count s while the full refit pays 1+s columns every iteration. Rows carry
  the certified drift and posterior mean/var parity vs the full refit.

``serve_solve``/``serve_warmstart``/``serve_refit`` rows carry matvec and
iteration counts gated by ``check_matvecs.py`` (the refit rows behind its
``--refit`` flag); smoke mode keeps the gated workloads — problem size, PRNG
seeds, CG specs — identical to the committed baseline and only drops the
ungated sweeps.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.core.solvers.spec import CG
from repro.serve import GPEngine, extend_state, fit_state, percentile, update_state_lowrank

from .common import Report

#: gated workload shape — keep in lockstep with the committed baseline
N, D_IN = 512, 3
NUM_SAMPLES = 4  # RHS columns per request
NUM_ROWS = 16  # query rows per request
GATED_DEPTH = 8
#: write-heavy (refit) workload shape
K_REFIT = 4  # observation rows appended per update (k ≪ n)
REFIT_SAMPLES = 16  # engine-default posterior sample count: the full refit
#                     re-solves 1+s columns, the rank-k path k — s-independence
#                     is the claim under gate
REFIT_SPEC = CG(max_iters=600, tol=1e-5)  # converges at n=512 (the serve
#                                           spec's 200-iteration cap would
#                                           censor the comparison); 1e-5 keeps
#                                           lowrank-vs-full posterior parity
#                                           under the gated 1e-4 bound


def _dataset(n: int, d: int):
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.uniform(kx, (n, d))
    w = jax.random.normal(kw, (d,))
    y = jnp.sin(4.0 * (x @ w)) + 0.1 * jnp.cos(7.0 * x[:, 0])
    return x, y


def _engine(params, x, y, cap: int) -> GPEngine:
    return GPEngine(
        params, x, y,
        spec=CG(max_iters=200, tol=1e-4),
        num_samples=4,
        num_features=256,
        seed=0,
        max_batch_requests=cap,
        max_rhs_columns=128,
    )


def _xs(i: int, d: int):
    return jax.random.uniform(jax.random.fold_in(jax.random.PRNGKey(7), i),
                              (NUM_ROWS, d))


def _wave(engine: GPEngine, d: int, seeds) -> tuple:
    """Submit one sample request per seed, drain, return (handles, wall_s)."""
    handles = [
        engine.sample(_xs(s, d), num_samples=NUM_SAMPLES, seed=s) for s in seeds
    ]
    t0 = time.perf_counter()
    engine.run_until_idle()
    return handles, time.perf_counter() - t0


def run(report: Report, full: bool = False, smoke: bool = False):
    x, y = _dataset(N, D_IN)
    params = make_params("matern32", lengthscale=0.5, signal=1.0, noise=0.1,
                         d=D_IN)

    # ---- throughput: batched vs sequential at each queue depth -------------
    depths = [GATED_DEPTH] if smoke else ([2, GATED_DEPTH, 16] if full
                                          else [2, GATED_DEPTH])
    walls = {}
    for depth in depths:
        for method, cap in (("sequential", 1), ("batched", depth)):
            eng = _engine(params, x, y, cap)
            # warmup wave: same bucketed shapes, throwaway seeds — pays the
            # compile cost so the measured wave times math, not tracing
            _wave(eng, D_IN, range(10_000, 10_000 + depth))
            before = eng.stats()
            handles, wall = _wave(eng, D_IN, range(depth))
            after = eng.stats()
            lat = [h.result().metrics["total_s"] for h in handles]
            iters = after["solver_iterations"] - before["solver_iterations"]
            matvecs = after["solver_matvecs"] - before["solver_matvecs"]
            solves = after["solves"] - before["solves"]
            walls[(depth, method)] = wall
            report.add(
                "serve_throughput", method, f"n={N} depth={depth}",
                req_s=round(depth / wall, 2),
                wall_s=round(wall, 3),
                p50_s=round(percentile(lat, 50), 4),
                p99_s=round(percentile(lat, 99), 4),
                solves=solves,
                iterations=iters,
            )
            if method == "batched" and depth == GATED_DEPTH:
                # the gated row: D coalesced requests = one bucketed solve
                report.add(
                    "serve_solve", "cg-batched",
                    f"n={N} cols={depth * NUM_SAMPLES}",
                    matvecs=matvecs, iterations=iters, solves=solves,
                )
        speedup = walls[(depth, "sequential")] / walls[(depth, "batched")]
        report.add(
            "serve_speedup", "batched/sequential", f"n={N} depth={depth}",
            speedup=round(speedup, 2),
            sequential_s=round(walls[(depth, "sequential")], 3),
            batched_s=round(walls[(depth, "batched")], 3),
        )

    # ---- warm starts: identical requests resubmitted hit the cache --------
    eng = _engine(params, x, y, GATED_DEPTH)
    seeds = range(100, 100 + GATED_DEPTH)
    # compile warmup for BOTH variants: a cold wave, then its warm resubmission
    # (the warm solve carries x0 and δ, a different compiled program)
    _wave(eng, D_IN, range(10_000, 10_000 + GATED_DEPTH))
    _wave(eng, D_IN, range(10_000, 10_000 + GATED_DEPTH))
    cold_handles, cold_wall = _wave(eng, D_IN, seeds)
    warm_handles, warm_wall = _wave(eng, D_IN, seeds)  # repeat seeds → warm
    cold_iters = cold_handles[0].result().metrics["iterations"]
    warm_iters = warm_handles[0].result().metrics["iterations"]
    assert all(h.result().metrics["warm"] for h in warm_handles)
    snap = eng.stats()
    report.add(
        "serve_warmstart", "cold", f"n={N} depth={GATED_DEPTH}",
        iterations=cold_iters, wall_s=round(cold_wall, 3),
    )
    report.add(
        "serve_warmstart", "warm", f"n={N} depth={GATED_DEPTH}",
        iterations=warm_iters, wall_s=round(warm_wall, 3),
        warm_hits=snap["warm_hits"], saved=snap["iterations_saved_warm"],
    )

    # ---- write-heavy: rank-k bordered update vs warm full refit (gated) ----
    xr, yr = _dataset(N + 4 * K_REFIT, D_IN)
    st = fit_state(
        params, xr[:N], yr[:N], jax.random.PRNGKey(2),
        spec=REFIT_SPEC, num_samples=REFIT_SAMPLES, num_features=256,
    )
    ukey = jax.random.PRNGKey(3)
    cols_full = 1 + REFIT_SAMPLES
    xt = jax.random.uniform(jax.random.PRNGKey(9), (32, D_IN))

    def _update(path, lo_idx, hi_idx):
        fn = update_state_lowrank if path == "lowrank" else (
            lambda *a: extend_state(*a, warm=True)
        )
        t0 = time.perf_counter()
        out = fn(st, xr[lo_idx:hi_idx], yr[lo_idx:hi_idx], ukey)
        jax.block_until_ready(out.post.v_mean)
        return out, time.perf_counter() - t0

    for method in ("full-warm", "lowrank"):
        # warmup batch pays the compile; the measured batch times math
        _update(method, N, N + K_REFIT)
        upd, wall = _update(method, N + K_REFIT, N + 2 * K_REFIT)
        mv = int(upd.fit_result.matvecs)
        if method == "lowrank":
            # z solve: k columns per pass; certification: one (1+s)-column pass
            matvec_columns = (mv - 1) * K_REFIT + cols_full
        else:
            matvec_columns = mv * cols_full
        row = dict(
            iterations=int(upd.fit_result.iterations),
            matvecs=mv,
            matvec_columns=matvec_columns,
            wall_s=round(wall, 3),
            rel_residual=float(jnp.max(upd.fit_result.rel_residual)),
        )
        if method == "lowrank":
            full_ref, _ = _update("full-warm", N + K_REFIT, N + 2 * K_REFIT)
            ml, vl = upd.post.sample_mean_and_var(xt)
            mf, vf = full_ref.post.sample_mean_and_var(xt)
            row["mean_err"] = float(np.max(np.abs(np.asarray(ml) - np.asarray(mf))))
            row["var_err"] = float(np.max(np.abs(np.asarray(vl) - np.asarray(vf))))
        report.add("serve_refit", method, f"n={N} k={K_REFIT} s={REFIT_SAMPLES}",
                   **row)

    if smoke:
        return

    # ---- write-heavy sweeps (not gated): k-scaling and engine write mix ----
    for k in (2, 8, 16):
        lo = update_state_lowrank(st, xr[N:N + k], yr[N:N + k], ukey)
        fu = extend_state(st, xr[N:N + k], yr[N:N + k], ukey, warm=True)
        report.add(
            "serve_refit_sweep", "lowrank/full-warm", f"n={N} k={k}",
            lowrank_matvec_columns=(int(lo.fit_result.matvecs) - 1) * k + cols_full,
            full_matvec_columns=int(fu.fit_result.matvecs) * cols_full,
            lowrank_rel_residual=float(jnp.max(lo.fit_result.rel_residual)),
        )

    # alternating write/read traffic through the engine's auto policy: every
    # write is a rank-k update until drift compacts, reads ride in between
    eng = _engine(params, x, y, GATED_DEPTH)
    _wave(eng, D_IN, range(10_000, 10_000 + 2))
    t0 = time.perf_counter()
    served = 0
    for i in range(6):
        eng.add_observations(xr[N + i * 2:N + i * 2 + 2], yr[N + i * 2:N + i * 2 + 2])
        handles, _ = _wave(eng, D_IN, range(400 + 2 * i, 400 + 2 * i + 2))
        served += len(handles)
    wall = time.perf_counter() - t0
    snap = eng.stats()
    report.add(
        "serve_write_mix", "auto", f"n={N} writes=6x2 depth=2",
        req_s=round(served / wall, 2), wall_s=round(wall, 3),
        lowrank_updates=snap["lowrank_updates"],
        compactions=snap["compactions"],
        cache_purged=snap["cache_purged"],
        final_n=snap["n"],
    )

    # ---- mixed workload snapshot (not gated): realistic request mix --------
    eng = _engine(params, x, y, GATED_DEPTH)
    handles = []
    for i in range(GATED_DEPTH):
        handles.append(eng.predict(_xs(200 + i, D_IN), seed=200 + i))
        handles.append(eng.sample(_xs(300 + i, D_IN), num_samples=NUM_SAMPLES,
                                  seed=300 + i))
    t0 = time.perf_counter()
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    lat = [h.result().metrics["total_s"] for h in handles]
    report.add(
        "serve_mixed", "predict+sample", f"n={N} depth={2 * GATED_DEPTH}",
        req_s=round(len(handles) / wall, 2), wall_s=round(wall, 3),
        p50_s=round(percentile(lat, 50), 4), p99_s=round(percentile(lat, 99), 4),
        steps=eng.stats()["steps"],
    )
