"""Table 3.1 / 4.1: regression baselines — CG vs SGD vs SDD vs SVGP.

Reproduces the paper's table *structure and claims* on synthetic UCI-shaped data:
RMSE / NLL / time per method, plus the low-noise (ill-conditioned) RMSE row where
CG degrades and SGD/SDD stay stable (§3.3.1 "robustness to ill-conditioning")."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import gram, make_params
from repro.core.pathwise import posterior_functions
from repro.core.solvers.spec import CG, SDD, SGD
from repro.core.svgp import sgpr
from repro.data.pipeline import regression_dataset

from .common import Report, nll_gaussian, rmse, timed


def run(report: Report, full: bool = False, smoke: bool = False):
    """``smoke=True`` (the CI matvec-regression gate — see check_matvecs.py)
    keeps the exact problem sizes and CG specs of the default run, so CG's
    counted matvecs stay comparable to the committed baseline, but slashes the
    stochastic solvers' step budgets: their matvec count is structural (the one
    exact finalize residual), independent of num_steps, while their wall time is
    not. Smoke RMSE/NLL rows are therefore meaningless — only matvecs matter."""
    datasets = ["pol", "elevators", "bike"] if not full else list(
        __import__("repro.data.pipeline", fromlist=["UCI_SHAPES"]).UCI_SHAPES)
    scale = 1.0 if full else 0.25  # scaled-down n for the CPU container
    stoch_steps = 100 if smoke else 8000
    for name in datasets:
        data = regression_dataset(name, seed=0)
        n = int(data["n"] * scale)
        x, y = data["x"][:n], data["y"][:n]
        xt, yt = data["x_test"], data["y_test"]
        d = x.shape[1]
        p = make_params("matern32", lengthscale=float(np.sqrt(d)) * 0.5,
                        signal=1.0, noise=0.1, d=d)

        budget = dict(num_samples=16, num_features=2048)
        for method, spec in [
            ("CG", CG(max_iters=150, tol=1e-3)),
            ("SGD", SGD(num_steps=stoch_steps, batch_size=256, step_size_times_n=0.5)),
            ("SDD", SDD(num_steps=stoch_steps, batch_size=256, step_size_times_n=2.0)),
        ]:
            pf, dt = timed(posterior_functions, p, x, y, jax.random.PRNGKey(0),
                           spec=spec, **budget)
            mu, var = pf.sample_mean_and_var(xt)
            info = pf.solve_info
            # matvecs = full (K+σ²I) matvecs the solve actually spent (CG: one
            # per iteration — the seed paid two extra per solve; SGD/SDD: the
            # single exact-residual check, their loops touch only row blocks)
            report.add("solvers(T3.1/4.1)", method, name,
                       rmse=rmse(mu, yt), nll=nll_gaussian(yt, mu, var),
                       seconds=round(dt, 2), iters=int(info.iterations),
                       matvecs=int(info.matvecs))
        # SVGP baseline (collapsed SGPR with m inducing points)
        z = x[:: max(1, n // 512)][:512]
        post, dt = timed(sgpr, p, x, y, z)
        mu = post.mean(xt)
        var = post.var(xt)
        report.add("solvers(T3.1/4.1)", "SVGP(SGPR)", name,
                   rmse=rmse(mu, yt), nll=nll_gaussian(yt, mu, var),
                   seconds=round(dt, 2))

        # low-noise, ill-conditioned row (RMSE† in Table 3.1)
        p_low = dataclasses.replace(p, log_noise=jnp.log(jnp.asarray(0.001)))
        for method, spec in [
            ("CG", CG(max_iters=150, tol=1e-3)),
            ("SDD", SDD(num_steps=stoch_steps, batch_size=256, step_size_times_n=2.0)),
        ]:
            pf, dt = timed(posterior_functions, p_low, x, y, jax.random.PRNGKey(0),
                           spec=spec, num_samples=4, num_features=2048)
            mu = pf.mean(xt)
            report.add("solvers-lownoise", method, name, rmse=rmse(mu, yt),
                       seconds=round(dt, 2),
                       matvecs=int(pf.solve_info.matvecs))
