"""Table 3.1 / 4.1: regression baselines — CG vs SGD vs SDD vs SVGP.

Reproduces the paper's table *structure and claims* on synthetic UCI-shaped data:
RMSE / NLL / time per method, plus the low-noise (ill-conditioned) RMSE row where
CG degrades and SGD/SDD stay stable (§3.3.1 "robustness to ill-conditioning")."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.core.operators import Gram
from repro.core.pathwise import posterior_functions
from repro.core.solvers.spec import CG, SDD, SGD, solve
from repro.core.svgp import sgpr
from repro.data.pipeline import regression_dataset

from .common import Report, nll_gaussian, rmse, timed

#: step budget of the dedicated per-iteration probe — fixed (and small) in both
#: smoke and default modes so the ``us_per_iter`` rows are comparable across
#: runs and against the committed baseline regardless of ``num_steps``.
PROBE_STEPS = 200

#: RHS column width of the probe — num_samples + 1, the pathwise multi-RHS batch.
PROBE_COLS = 17


def _mv_equiv(spec, n: int) -> float:
    """Equivalent-full-matvec spend of a stochastic solve, from row-block
    accounting: a row-block contraction touches p·n kernel entries (p/n of a
    full n² matvec) and a feature contraction touches n·2q entries (2q/n).
    SGD spends two row contractions (the K[idx,:] panel pair) and two feature
    contractions (Φᵀ· then Φ·) per step; SDD one row contraction. The +1 is the
    exact finalize residual — the only *full* matvec either solver executes,
    which is why their ``matvecs`` column reads 1."""
    steps, p = spec.num_steps, spec.batch_size
    if isinstance(spec, SGD):
        per_step = (2.0 * p + 4.0 * spec.num_features) / n
    else:
        per_step = p / n
    return round(1.0 + steps * per_step, 1)


def _per_iter_us(params, x, spec, key) -> int:
    """Compiled per-iteration wall time (microseconds) of a stochastic solve.

    A dedicated multi-RHS solve at the probe's fixed step budget, run twice —
    the first call compiles, the second is timed — so the number is the hot
    scan's per-step cost, independent of compile time and of ``num_steps``."""
    op = Gram(x=x, params=params)
    b = jax.random.normal(key, (x.shape[0], PROBE_COLS))
    probe = dataclasses.replace(spec, num_steps=PROBE_STEPS)
    solve(op, b, probe, key=key)  # compile + warm up
    _, dt = timed(solve, op, b, probe, key=key)
    return int(round(dt / PROBE_STEPS * 1e6))


def run(report: Report, full: bool = False, smoke: bool = False):
    """``smoke=True`` (the CI matvec-regression gate — see check_matvecs.py)
    keeps the exact problem sizes and CG specs of the default run, so CG's
    counted matvecs stay comparable to the committed baseline, but slashes the
    stochastic solvers' step budgets: their matvec count is structural (the one
    exact finalize residual), independent of num_steps, while their wall time is
    not. Smoke RMSE/NLL rows are therefore meaningless — only matvecs matter."""
    datasets = ["pol", "elevators", "bike"] if not full else list(
        __import__("repro.data.pipeline", fromlist=["UCI_SHAPES"]).UCI_SHAPES)
    scale = 1.0 if full else 0.25  # scaled-down n for the CPU container
    stoch_steps = 100 if smoke else 8000
    for name in datasets:
        data = regression_dataset(name, seed=0)
        n = int(data["n"] * scale)
        x, y = data["x"][:n], data["y"][:n]
        xt, yt = data["x_test"], data["y_test"]
        d = x.shape[1]
        p = make_params("matern32", lengthscale=float(np.sqrt(d)) * 0.5,
                        signal=1.0, noise=0.1, d=d)

        budget = dict(num_samples=16, num_features=2048)
        for method, spec in [
            ("CG", CG(max_iters=150, tol=1e-3)),
            ("SGD", SGD(num_steps=stoch_steps, batch_size=256, step_size_times_n=0.5)),
            ("SDD", SDD(num_steps=stoch_steps, batch_size=256, step_size_times_n=2.0)),
        ]:
            pf, dt = timed(posterior_functions, p, x, y, jax.random.PRNGKey(0),
                           spec=spec, **budget)
            mu, var = pf.sample_mean_and_var(xt)
            info = pf.solve_info
            # matvecs = full (K+σ²I) matvecs the solve actually spent (CG: one
            # per iteration — the seed paid two extra per solve; SGD/SDD: the
            # single exact-residual check, their loops touch only row blocks).
            # mv_equiv makes the cost columns comparable across families: for
            # the stochastic solvers it converts the per-step row-block and
            # feature work into full-matvec equivalents (see _mv_equiv) —
            # "matvecs: 1" alone badly understates what SGD/SDD spend.
            extra = {}
            if isinstance(spec, CG):
                extra["mv_equiv"] = float(int(info.matvecs))
            else:
                extra["mv_equiv"] = _mv_equiv(spec, n)
            report.add("solvers(T3.1/4.1)", method, name,
                       rmse=rmse(mu, yt), nll=nll_gaussian(yt, mu, var),
                       seconds=round(dt, 2), iters=int(info.iterations),
                       matvecs=int(info.matvecs), **extra)
            if method in ("SGD", "SDD"):
                # wall-clock per iteration — the raw-speed number this table is
                # gated on (check_matvecs --skip-walltime to bypass on noisy
                # runners); measured by a dedicated compiled probe, not dt/steps
                us = _per_iter_us(p, x, spec, jax.random.PRNGKey(3))
                report.add("solvers-periter", method, name, us_per_iter=us)
        # SVGP baseline (collapsed SGPR with m inducing points)
        z = x[:: max(1, n // 512)][:512]
        post, dt = timed(sgpr, p, x, y, z)
        mu = post.mean(xt)
        var = post.var(xt)
        report.add("solvers(T3.1/4.1)", "SVGP(SGPR)", name,
                   rmse=rmse(mu, yt), nll=nll_gaussian(yt, mu, var),
                   seconds=round(dt, 2))

        # low-noise, ill-conditioned row (RMSE† in Table 3.1)
        p_low = dataclasses.replace(p, log_noise=jnp.log(jnp.asarray(0.001)))
        for method, spec in [
            ("CG", CG(max_iters=150, tol=1e-3)),
            ("SDD", SDD(num_steps=stoch_steps, batch_size=256, step_size_times_n=2.0)),
        ]:
            pf, dt = timed(posterior_functions, p_low, x, y, jax.random.PRNGKey(0),
                           spec=spec, num_samples=4, num_features=2048)
            mu = pf.mean(xt)
            report.add("solvers-lownoise", method, name, rmse=rmse(mu, yt),
                       seconds=round(dt, 2),
                       matvecs=int(pf.solve_info.matvecs))
