"""Figures 3.7 / 4.4: parallel Thompson sampling — max value found per method
under an equal acquisition budget (SDD vs SGD vs CG posterior samples)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.core.rff import sample_prior
from repro.core.solvers.spec import CG, SDD, SGD
from repro.core.thompson import ThompsonState, thompson_step

from .common import Report


def run(report: Report, full: bool = False):
    d = 8 if full else 4
    n0 = 2000 if full else 400
    steps = 5 if full else 3
    acq = 100 if full else 32
    key = jax.random.PRNGKey(0)
    p = make_params("matern32", lengthscale=0.3, signal=1.0, noise=0.001, d=d)

    for seed in range(2):
        target = sample_prior(p, jax.random.PRNGKey(1000 + seed), 1, 2048, d)

        def objective(x):
            return target(x)[:, 0]

        x0 = jax.random.uniform(jax.random.fold_in(key, seed), (n0, d))
        y0 = objective(x0)
        base = float(y0.max())
        for method, spec in [
            ("SDD", SDD(num_steps=3000, batch_size=128, step_size_times_n=2.0)),
            ("SGD", SGD(num_steps=3000, batch_size=128, step_size_times_n=0.3)),
            ("CG", CG(max_iters=100)),
        ]:
            state = ThompsonState(x=x0, y=y0, best=base)
            for t in range(steps):
                state = thompson_step(
                    p, state, objective, jax.random.fold_in(key, 77 + 13 * t + seed),
                    acq_batch=acq, num_candidates=512, num_top=4, ascent_steps=20,
                    spec=spec,
                )
            report.add("thompson(F3.7/4.4)", method, f"d={d} seed={seed}",
                       start=round(base, 3), best=round(state.best, 3),
                       gain=round(state.best - base, 3))
        # random-search control at equal evaluation budget
        xr = jax.random.uniform(jax.random.fold_in(key, 555 + seed), (steps * acq, d))
        report.add("thompson(F3.7/4.4)", "random", f"d={d} seed={seed}",
                   start=round(base, 3),
                   best=round(max(base, float(objective(xr).max())), 3))
