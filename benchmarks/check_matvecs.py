"""Matvec-count regression gate (CI step): run bench_solvers in smoke mode and
fail if any counted full-Gram-matvec total exceeds the committed baseline in
``results/BENCH_bench_solvers.json``.

Matvec counts are the structural perf guarantee of the solver layer (CG spends
exactly one matvec per iteration, SGD/SDD exactly one, AP zero — see
``docs/solvers.md``); a refactor that silently reintroduces an A·0 warm-start
residual or a recomputed finalize residual shows up here as counts drifting
above the baseline, long before wall-clock noise would reveal it. Smoke mode
keeps the committed problem sizes and CG specs (so CG iteration counts are
comparable) and only cuts the stochastic solvers' step budgets, whose matvec
count is independent of steps.

Usage:
    PYTHONPATH=src python -m benchmarks.check_matvecs \
        [--baseline results/BENCH_bench_solvers.json] [--slack 0.15]

``--slack`` tolerates small cross-platform CG iteration jitter (fp32 reduction
order): measured > ceil(baseline · (1 + slack)) fails.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from . import bench_solvers
from .common import Report


def _matvec_rows(rows) -> dict:
    """{(table, method, dataset): matvecs} for rows that report a count."""
    out = {}
    for r in rows:
        metrics = r["metrics"] if isinstance(r, dict) else r.metrics
        if "matvecs" in metrics:
            key = tuple(
                (r[k] if isinstance(r, dict) else getattr(r, k))
                for k in ("table", "method", "dataset")
            )
            out[key] = int(metrics["matvecs"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--baseline", default="results/BENCH_bench_solvers.json",
        help="committed bench_solvers JSON to gate against",
    )
    ap.add_argument(
        "--slack", type=float, default=0.15,
        help="fractional headroom over the baseline before failing",
    )
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = _matvec_rows(json.load(f)["rows"])
    if not baseline:
        print(f"ERROR: no matvec counts in {args.baseline}", file=sys.stderr)
        return 2

    report = Report()
    bench_solvers.run(report, full=False, smoke=True)
    measured = _matvec_rows(report.rows)

    compared = 0
    failures = []
    print(f"\nmatvec gate vs {args.baseline} (slack {args.slack:.0%}):")
    for key, base in sorted(baseline.items()):
        if key not in measured:
            continue
        compared += 1
        allowed = math.ceil(base * (1.0 + args.slack))
        got = measured[key]
        status = "ok" if got <= allowed else "REGRESSION"
        print(f"  {'/'.join(key):45s} baseline={base:4d} allowed={allowed:4d} "
              f"measured={got:4d}  {status}")
        if got > allowed:
            failures.append((key, base, got))

    if compared == 0:
        print("ERROR: no comparable rows between baseline and smoke run",
              file=sys.stderr)
        return 2
    if failures:
        print(f"\n{len(failures)} matvec-count regression(s):", file=sys.stderr)
        for key, base, got in failures:
            print(f"  {'/'.join(key)}: {base} -> {got}", file=sys.stderr)
        return 1
    print(f"\nall {compared} matvec counts within baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
