"""Matvec/iteration-count regression gate (CI step): run bench_solvers and
bench_mll in smoke mode and fail if any counted total exceeds its committed
baseline (``results/BENCH_bench_solvers.json``, ``results/BENCH_bench_mll.json``).

Matvec counts are the structural perf guarantee of the solver layer (CG spends
exactly one matvec per iteration, SGD/SDD exactly one, AP zero — see
``docs/solvers.md``); a refactor that silently reintroduces an A·0 warm-start
residual or a recomputed finalize residual shows up here as counts drifting
above the baseline, long before wall-clock noise would reveal it. The bench_mll
gate adds the Ch. 5 claim: warm-started MLL optimisation totals *fewer* inner
CG iterations — a change that breaks warm starting (or the pathwise probe
batching) inflates ``solver_iters`` far past the slack. Smoke modes keep the
committed problem sizes, PRNG keys and CG specs (so iteration counts are
comparable) and only cut work the gate does not compare.

The serve gate extends the same idea to the serving engine (``bench_serve``):
its batched-solve matvec count must stay within the committed baseline (the
whole point of coalescing D requests is ONE solve's worth of matvecs), and the
warm resubmission row must use strictly fewer solver iterations than the cold
row — a broken warm-start cache (stale keying, dropped x0) shows up here as
warm == cold. With ``--refit`` it also gates the write-heavy rows: the rank-k
incremental update (``update_state_lowrank``) must spend strictly fewer
column-matvecs than the warm full refit at k ≪ n, with certified drift and
posterior mean/variance parity vs the full refit both under the 1e-4 serving
bound — a regression that re-solves the world on ``add_observations`` (or
breaks the bordered algebra) fails here.

The robust gate (``bench_robust``) closes the loop on the guardrail work
(``docs/robustness.md``): ``solve_robust`` on a healthy system must spend
*exactly* the same matvecs as plain ``solve`` (the in-loop health checks reuse
reductions the solvers already compute; the ladder's only happy-path cost is
one host readback of the flags vector), the near-singular recovery row must
still recover, and the measured wall overhead must stay under a loose
anti-regression bound (the committed <2% number comes from ``bench_robust``
itself; the CI bound is wider because container timing is noisy).

The distributed gate (``bench_distributed``) guards the comm-strategy work
(``docs/distributed.md``): solver matvec counts per comm strategy are gated
*exactly* (they are budget-determined — CG pinned below convergence, SGD's one
finalize residual, AP's zero), the per-matvec collective schedule counted in
the jaxpr must not exceed the committed baseline, the ring matvec must stage
zero ``all_gather``, and distributed SGD must trace zero materialised-feature
dispatches (the (n, 2q) matrix never exists). The measurements run in a forced
4-device subprocess so the mesh doesn't leak into this process's jax.

Two further gates ride on the same smoke run:

* **wall-clock per iteration** — bench_solvers times a 200-step stochastic
  solve probe and reports ``us_per_iter`` for SGD/SDD per dataset; the gate
  fails if a fresh probe exceeds the committed number by more than
  ``--walltime-slack`` (default 1.0 → 2× headroom: container timing is noisy,
  the gate only catches step-cost blowups like a de-fused pair step or a
  re-scalarised covariance map, not percent-level drift). ``--skip-walltime``
  for machines whose timing is incomparable to the committed baseline's.
* **autotune-table freshness** — the committed ``results/AUTOTUNE_gram.json``
  (the ``block="auto"`` lookup table, kernels/autotune.py) must cover exactly
  the shape grid the resolver expects; growing the grid without re-running
  ``bench_gram_kernel`` (which emits the artifact) fails here instead of
  silently falling back to the heuristic for the new keys.

Usage:
    PYTHONPATH=src python -m benchmarks.check_matvecs \
        [--baseline results/BENCH_bench_solvers.json] \
        [--mll-baseline results/BENCH_bench_mll.json | --skip-mll] \
        [--serve-baseline results/BENCH_bench_serve.json | --skip-serve] \
        [--refit] \
        [--robust-baseline results/BENCH_bench_robust.json | --skip-robust] \
        [--distributed-baseline results/BENCH_bench_distributed.json | --skip-distributed] \
        [--autotune-table results/AUTOTUNE_gram.json | --skip-autotune] \
        [--slack 0.15] [--walltime-slack 1.0 | --skip-walltime]

``--slack`` tolerates small cross-platform jitter (fp32 reduction order):
measured > ceil(baseline · (1 + slack)) fails.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from repro.kernels import autotune

from . import bench_distributed, bench_mll, bench_robust, bench_serve, bench_solvers
from .common import Report


def _metric_rows(rows, metric: str) -> dict:
    """{(table, method, dataset): value} for rows that report ``metric``."""
    out = {}
    for r in rows:
        metrics = r["metrics"] if isinstance(r, dict) else r.metrics
        if metric in metrics:
            key = tuple(
                (r[k] if isinstance(r, dict) else getattr(r, k))
                for k in ("table", "method", "dataset")
            )
            out[key] = int(metrics[metric])
    return out


def _gate(name: str, baseline: dict, measured: dict, slack: float) -> tuple:
    """Compare measured counts against the baseline; returns (compared, failures)."""
    compared = 0
    failures = []
    print(f"\n{name} gate (slack {slack:.0%}):")
    for key, base in sorted(baseline.items()):
        if key not in measured:
            continue
        compared += 1
        allowed = math.ceil(base * (1.0 + slack))
        got = measured[key]
        status = "ok" if got <= allowed else "REGRESSION"
        print(f"  {'/'.join(key):45s} baseline={base:4d} allowed={allowed:4d} "
              f"measured={got:4d}  {status}")
        if got > allowed:
            failures.append((key, base, got))
    return compared, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--baseline", default="results/BENCH_bench_solvers.json",
        help="committed bench_solvers JSON to gate matvec counts against",
    )
    ap.add_argument(
        "--mll-baseline", default="results/BENCH_bench_mll.json",
        help="committed bench_mll JSON to gate warm-start iteration totals against",
    )
    ap.add_argument(
        "--skip-mll", action="store_true",
        help="gate bench_solvers matvec counts only",
    )
    ap.add_argument(
        "--serve-baseline", default="results/BENCH_bench_serve.json",
        help="committed bench_serve JSON to gate batched-solve matvecs against",
    )
    ap.add_argument(
        "--skip-serve", action="store_true",
        help="skip the serving-engine gate",
    )
    ap.add_argument(
        "--refit", action="store_true",
        help="also gate the write-heavy serve_refit rows: the rank-k "
        "incremental update's column-matvec spend vs the committed baseline, "
        "strictly below the full warm refit on the fresh run, with certified "
        "drift and lowrank-vs-full posterior parity under the 1e-4 serving "
        "bound (requires the serve gate)",
    )
    ap.add_argument(
        "--robust-baseline", default="results/BENCH_bench_robust.json",
        help="committed bench_robust JSON to gate guardrail matvecs against",
    )
    ap.add_argument(
        "--skip-robust", action="store_true",
        help="skip the solver-guardrail gate",
    )
    ap.add_argument(
        "--robust-overhead-pct", type=float, default=10.0,
        help="max measured happy-path wall overhead of solve_robust (loose "
        "CI bound; the committed <2%% number lives in bench_robust itself)",
    )
    ap.add_argument(
        "--distributed-baseline",
        default="results/BENCH_bench_distributed.json",
        help="committed bench_distributed JSON: solver matvec counts per comm "
        "strategy are gated EXACTLY (zero slack — they are budget-determined), "
        "collectives-per-matvec must not exceed the baseline, and the fresh "
        "run must show zero all_gather on the ring path and zero "
        "materialised-feature traces in distributed SGD",
    )
    ap.add_argument(
        "--skip-distributed", action="store_true",
        help="skip the distributed comm-strategy gate (spawns a forced "
        "4-device subprocess)",
    )
    ap.add_argument(
        "--slack", type=float, default=0.15,
        help="fractional headroom over the baseline before failing",
    )
    ap.add_argument(
        "--walltime-slack", type=float, default=1.0,
        help="fractional headroom on the per-iteration wall-clock gate "
        "(default 1.0 → measured may be up to 2× the committed us_per_iter; "
        "generous on purpose — the gate catches step-cost blowups, not noise)",
    )
    ap.add_argument(
        "--skip-walltime", action="store_true",
        help="skip the wall-clock-per-iteration gate (incomparable hardware)",
    )
    ap.add_argument(
        "--autotune-table", default=autotune.DEFAULT_TABLE_PATH,
        help="committed block-autotune table whose keys must match the "
        "resolver's expected shape grid",
    )
    ap.add_argument(
        "--skip-autotune", action="store_true",
        help="skip the autotune-table freshness gate",
    )
    args = ap.parse_args(argv)
    if args.refit and args.skip_serve:
        print("ERROR: --refit gates bench_serve rows and cannot be combined "
              "with --skip-serve", file=sys.stderr)
        return 2

    with open(args.baseline) as f:
        base_matvecs = _metric_rows(json.load(f)["rows"], "matvecs")
    if not base_matvecs:
        print(f"ERROR: no matvec counts in {args.baseline}", file=sys.stderr)
        return 2

    report = Report()
    bench_solvers.run(report, full=False, smoke=True)
    compared, failures = _gate(
        f"matvecs vs {args.baseline}",
        base_matvecs, _metric_rows(report.rows, "matvecs"), args.slack,
    )
    if compared == 0:
        # each gate must compare > 0 rows, or a label drift between the bench
        # and its committed baseline would silently void the gate
        print("ERROR: no comparable matvec rows between baseline and smoke run",
              file=sys.stderr)
        return 2

    if not args.skip_walltime:
        with open(args.baseline) as f:
            base_walltime = _metric_rows(json.load(f)["rows"], "us_per_iter")
        if not base_walltime:
            print(f"ERROR: no us_per_iter rows in {args.baseline} — regenerate "
                  "it with benchmarks.run --only bench_solvers (or pass "
                  "--skip-walltime)", file=sys.stderr)
            return 2
        c_wt, f_wt = _gate(
            f"us_per_iter vs {args.baseline}",
            base_walltime, _metric_rows(report.rows, "us_per_iter"),
            args.walltime_slack,
        )
        if c_wt == 0:
            print("ERROR: no comparable us_per_iter rows between baseline and "
                  "smoke run", file=sys.stderr)
            return 2
        compared += c_wt
        failures += f_wt

    if not args.skip_autotune:
        committed = set(autotune.load_table(args.autotune_table))
        expected = autotune.expected_keys()
        missing = sorted(expected - committed)
        extra = sorted(committed - expected)
        print(f"\nautotune freshness gate ({args.autotune_table}):")
        print(f"  expected {len(expected)} keys, committed {len(committed)}  "
              f"{'ok' if not (missing or extra) else 'STALE'}")
        compared += 1
        if missing or extra:
            for k in missing[:8]:
                print(f"  missing: {k}", file=sys.stderr)
            for k in extra[:8]:
                print(f"  extra:   {k}", file=sys.stderr)
            print("  the committed table's shape grid drifted from "
                  "kernels/autotune.py — re-run benchmarks.run --only "
                  "bench_gram_kernel to regenerate it", file=sys.stderr)
            failures.append((("autotune", "table", "keys"),
                             len(expected), len(committed)))

    if not args.skip_mll:
        with open(args.mll_baseline) as f:
            base_iters = _metric_rows(json.load(f)["rows"], "solver_iters")
        if not base_iters:
            print(f"ERROR: no solver_iters in {args.mll_baseline}", file=sys.stderr)
            return 2
        mll_report = Report()
        bench_mll.run(mll_report, full=False, smoke=True)
        c2, f2 = _gate(
            f"mll solver_iters vs {args.mll_baseline}",
            base_iters, _metric_rows(mll_report.rows, "solver_iters"), args.slack,
        )
        if c2 == 0:
            print("ERROR: no comparable solver_iters rows between mll baseline "
                  "and smoke run", file=sys.stderr)
            return 2
        compared += c2
        failures += f2

    if not args.skip_serve:
        with open(args.serve_baseline) as f:
            serve_rows = json.load(f)["rows"]
        base_serve = {
            k: v for k, v in _metric_rows(serve_rows, "matvecs").items()
            if k[0] == "serve_solve"
        }
        if not base_serve:
            print(f"ERROR: no serve_solve matvecs in {args.serve_baseline}",
                  file=sys.stderr)
            return 2
        serve_report = Report()
        bench_serve.run(serve_report, full=False, smoke=True)
        c3, f3 = _gate(
            f"serve matvecs vs {args.serve_baseline}",
            base_serve, _metric_rows(serve_report.rows, "matvecs"), args.slack,
        )
        if c3 == 0:
            print("ERROR: no comparable serve_solve rows between serve "
                  "baseline and smoke run", file=sys.stderr)
            return 2
        compared += c3
        failures += f3
        # warm resubmissions must beat cold solves on iterations, in the fresh
        # run itself — this is a structural property, not a baseline diff
        warm_iters = _metric_rows(serve_report.rows, "iterations")
        cold = {k: v for k, v in warm_iters.items()
                if k[0] == "serve_warmstart" and k[1] == "cold"}
        warm = {(t, "warm", d): warm_iters.get((t, "warm", d))
                for (t, _, d) in cold}
        print("\nserve warm-start gate:")
        for (t, _, d), base in sorted(cold.items()):
            got = warm[(t, "warm", d)]
            status = "ok" if got is not None and got < base else "REGRESSION"
            print(f"  {t}/{d:24s} cold={base:4d} warm={got!s:>4s}  {status}")
            compared += 1
            if status != "ok":
                failures.append(((t, "warm", d), base, got))

        if args.refit:
            # committed-baseline gate on the write-heavy rows: the rank-k
            # update's column-matvec spend (k solve columns at the old n + one
            # certification pass) must not drift above the committed numbers
            base_refit = {
                k: v
                for k, v in _metric_rows(serve_rows, "matvec_columns").items()
                if k[0] == "serve_refit"
            }
            if not base_refit:
                print(f"ERROR: no serve_refit matvec_columns rows in "
                      f"{args.serve_baseline} — regenerate it with "
                      "benchmarks.run --only bench_serve", file=sys.stderr)
                return 2
            c_r, f_r = _gate(
                f"serve refit matvec_columns vs {args.serve_baseline}",
                base_refit,
                _metric_rows(serve_report.rows, "matvec_columns"), args.slack,
            )
            if c_r == 0:
                print("ERROR: no comparable serve_refit rows between baseline "
                      "and smoke run", file=sys.stderr)
                return 2
            compared += c_r
            failures += f_r
            # structural gates on the fresh run itself: for k ≪ n the rank-k
            # path must spend strictly fewer column-matvecs than the full warm
            # refit (its spend is s-independent; the refit pays 1+s columns
            # every iteration), its certified drift against the extended
            # operator must stay under the 1e-4 serving bound, and its
            # posterior must match the full refit to the same bound
            fresh = {r.method: r.metrics for r in serve_report.rows
                     if r.table == "serve_refit"}
            lo_m, fu_m = fresh.get("lowrank"), fresh.get("full-warm")
            if lo_m is None or fu_m is None:
                print("ERROR: fresh run missing serve_refit lowrank/full-warm "
                      "rows", file=sys.stderr)
                return 2
            print("\nserve refit structural gate:")
            for name, got, ok in (
                ("lowrank_below_full_matvec_columns",
                 int(lo_m["matvec_columns"]),
                 int(lo_m["matvec_columns"]) < int(fu_m["matvec_columns"])),
                ("certified_drift", float(lo_m["rel_residual"]),
                 float(lo_m["rel_residual"]) <= 1e-4),
                ("posterior_mean_parity", float(lo_m["mean_err"]),
                 float(lo_m["mean_err"]) <= 1e-4),
                ("posterior_var_parity", float(lo_m["var_err"]),
                 float(lo_m["var_err"]) <= 1e-4),
            ):
                print(f"  {name}={got:g}  {'ok' if ok else 'REGRESSION'}")
                compared += 1
                if not ok:
                    failures.append((("serve_refit", "lowrank", name), 0,
                                     int(got) if got >= 1 else 1))

    if not args.skip_robust:
        with open(args.robust_baseline) as f:
            base_robust = _metric_rows(json.load(f)["rows"], "matvecs")
        if not base_robust:
            print(f"ERROR: no matvec counts in {args.robust_baseline}",
                  file=sys.stderr)
            return 2
        robust_report = Report()
        bench_robust.run(robust_report, full=False, smoke=True)
        c4, f4 = _gate(
            f"robust matvecs vs {args.robust_baseline}",
            base_robust, _metric_rows(robust_report.rows, "matvecs"),
            args.slack,
        )
        if c4 == 0:
            print("ERROR: no comparable robust rows between baseline and "
                  "smoke run", file=sys.stderr)
            return 2
        compared += c4
        failures += f4
        # structural gates on the fresh run itself (baseline-independent):
        # guardrails must be matvec-free on the happy path, the ladder must
        # still recover the near-singular problem, and the wall overhead must
        # stay under the loose CI bound
        print("\nrobust guardrail gate:")
        for r in robust_report.rows:
            m = r.metrics
            if r.table == "robust_overhead" and r.method == "robust":
                eq = bool(m.get("matvecs_equal"))
                oh = float(m.get("overhead_pct", 0.0))
                oh_ok = oh <= args.robust_overhead_pct
                print(f"  matvecs_equal={int(eq)}  overhead_pct={oh:.2f} "
                      f"(bound {args.robust_overhead_pct:.0f}%)  "
                      f"{'ok' if eq and oh_ok else 'REGRESSION'}")
                compared += 2
                if not eq:
                    failures.append((("robust_overhead", "robust",
                                      "matvecs_equal"), 1, 0))
                if not oh_ok:
                    failures.append((("robust_overhead", "robust",
                                      "overhead_pct"),
                                     int(args.robust_overhead_pct), int(oh)))
            if r.table == "robust_recovery":
                rec = bool(m.get("recovered"))
                print(f"  recovery recovered={int(rec)}  "
                      f"{'ok' if rec else 'REGRESSION'}")
                compared += 1
                if not rec:
                    failures.append((("robust_recovery", r.method,
                                      "recovered"), 1, 0))

    if not args.skip_distributed:
        with open(args.distributed_baseline) as f:
            dist_rows = json.load(f)["rows"]
        base_dist_mv = {
            k: v for k, v in _metric_rows(dist_rows, "matvecs").items()
            if k[0] == "dist_solve"
        }
        base_dist_coll = {
            k: v for k, v in _metric_rows(dist_rows, "collectives").items()
            if k[0] == "dist_collectives"
        }
        if not base_dist_mv or not base_dist_coll:
            print(f"ERROR: no dist_solve/dist_collectives rows in "
                  f"{args.distributed_baseline}", file=sys.stderr)
            return 2
        dist_report = Report()
        bench_distributed.run(dist_report, full=False, smoke=True)
        # matvec counts per comm strategy are budget-determined (CG pinned
        # below convergence, SGD's single finalize residual, AP's zero) —
        # exact, zero slack
        c5, f5 = _gate(
            f"distributed matvecs vs {args.distributed_baseline}",
            base_dist_mv, _metric_rows(dist_report.rows, "matvecs"), 0.0,
        )
        # the collective schedule may only shrink: a refactor that sneaks an
        # extra gather/psum into the matvec shows up here
        c6, f6 = _gate(
            f"distributed collectives/matvec vs {args.distributed_baseline}",
            base_dist_coll, _metric_rows(dist_report.rows, "collectives"), 0.0,
        )
        if c5 == 0 or c6 == 0:
            print("ERROR: no comparable distributed rows between baseline and "
                  "fresh run", file=sys.stderr)
            return 2
        compared += c5 + c6
        failures += f5 + f6
        # structural gates on the fresh run itself (baseline-independent): the
        # ring matvec stages ZERO all_gather, and distributed SGD never
        # materialises the (n, 2q) feature matrix on either comm path
        print("\ndistributed structural gate:")
        for r in dist_report.rows:
            m = r.metrics
            if r.table == "dist_collectives" and r.method == "mv_ring":
                ag = int(m.get("all_gather", -1))
                print(f"  ring all_gather/mv={ag}  "
                      f"{'ok' if ag == 0 else 'REGRESSION'}")
                compared += 1
                if ag != 0:
                    failures.append(((r.table, r.method, "all_gather"), 0, ag))
            if r.table == "dist_solve" and r.method.startswith("sgd_"):
                mat = int(m.get("feature_traces_materialised", -1))
                fused = int(m.get("feature_traces_fused", 0))
                ok_feat = mat == 0 and fused > 0
                print(f"  {r.method} materialised_feature_traces={mat} "
                      f"fused={fused}  {'ok' if ok_feat else 'REGRESSION'}")
                compared += 1
                if not ok_feat:
                    failures.append(((r.table, r.method,
                                      "feature_traces_materialised"), 0, mat))

    if failures:
        print(f"\n{len(failures)} count regression(s):", file=sys.stderr)
        for key, base, got in failures:
            print(f"  {'/'.join(key)}: {base} -> {got}", file=sys.stderr)
        return 1
    print(f"\nall {compared} counts within baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
