"""Shared benchmark plumbing: timing, result tables, and dataset sizing.

CPU container note: wall-clock numbers here are CPU numbers — meaningful for
*relative* solver comparisons (the paper's tables compare methods under equal
budgets) but not for TPU-absolute claims, which come from the §Roofline dry-run.
Sizes default to scaled-down-but-shaped-like-the-paper datasets; pass --full for
paper-sized n where feasible.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


@dataclasses.dataclass
class Row:
    table: str
    method: str
    dataset: str
    metrics: dict

    def line(self) -> str:
        ms = "  ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in self.metrics.items())
        return f"{self.table:18s} {self.dataset:14s} {self.method:14s} {ms}"


class Report:
    def __init__(self):
        self.rows: list[Row] = []

    def add(self, table: str, method: str, dataset: str, **metrics):
        row = Row(table, method, dataset, metrics)
        self.rows.append(row)
        print("  " + row.line(), flush=True)

    def dump(self, path: Optional[str] = None):
        if path:
            with open(path, "w") as f:
                for r in self.rows:
                    f.write(json.dumps(dataclasses.asdict(r)) + "\n")


def timed(fn: Callable, *args, **kwargs):
    t0 = time.time()
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return out, time.time() - t0


def rmse(a, b) -> float:
    return float(np.sqrt(np.mean((np.asarray(a) - np.asarray(b)) ** 2)))


def nll_gaussian(y, mu, var) -> float:
    y, mu, var = np.asarray(y), np.asarray(mu), np.maximum(np.asarray(var), 1e-6)
    return float(np.mean(0.5 * np.log(2 * np.pi * var) + 0.5 * (y - mu) ** 2 / var))
