"""Benchmark harness entry point: `PYTHONPATH=src python -m benchmarks.run [--full]
[--only bench_solvers,...]`. One module per paper table/figure (DESIGN.md §7).

Each bench additionally emits a machine-readable ``BENCH_<name>.json`` into
``--outdir`` (default ``results/``): wall time, per-row metrics (RMSE/NLL,
solver iterations, full-Gram-matvec counts where the bench reports them), so the
performance trajectory is tracked across PRs instead of living in scrollback.
"""
from __future__ import annotations

import argparse
import dataclasses
import importlib
import inspect
import json
import os
import time
import traceback

from .common import Report

BENCHES = [
    "bench_solvers",  # Table 3.1 / 4.1
    "bench_dual",  # Figures 4.1–4.3
    "bench_mll",  # Figure 5.1 + §5.4
    "bench_kronecker",  # Chapter 6
    "bench_thompson",  # Figures 3.7 / 4.4
    "bench_serve",  # serving engine: continuous batching + warm starts
    "bench_robust",  # guardrail overhead + escalation-ladder recovery
    "bench_distributed",  # ring vs gather comm strategies (4-device subprocess)
    "bench_molecules",  # Table 4.2
    "bench_gram_kernel",  # Pallas tile sweep
    "bench_roofline",  # §Roofline (reads dry-run JSONL)
]


def _dump_bench_json(outdir: str, name: str, payload: dict) -> str:
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-sized datasets")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run for benches that support it (bench_solvers: same "
        "problem sizes, slashed stochastic step budgets — matvec counts stay "
        "baseline-comparable, RMSE rows do not)",
    )
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--out", default=None, help="dump all rows as JSONL")
    ap.add_argument(
        "--outdir", default="results",
        help="directory for the per-bench BENCH_<name>.json files",
    )
    args = ap.parse_args(argv)

    names = args.only.split(",") if args.only else BENCHES
    report = Report()
    failures = 0
    for name in names:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        mark = len(report.rows)
        ok = True
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kwargs = {"full": args.full}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            mod.run(report, **kwargs)
            print(f"=== {name} done in {time.time()-t0:.0f}s ===")
        except Exception:
            traceback.print_exc()
            failures += 1
            ok = False
        path = _dump_bench_json(
            args.outdir,
            name,
            {
                "bench": name,
                "ok": ok,
                "full": bool(args.full),
                "wall_seconds": round(time.time() - t0, 3),
                "rows": [dataclasses.asdict(r) for r in report.rows[mark:]],
            },
        )
        print(f"    wrote {path}")
    report.dump(args.out)
    print(f"\n{len(report.rows)} rows; {failures} bench failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
