"""Benchmark harness entry point: `PYTHONPATH=src python -m benchmarks.run [--full]
[--only bench_solvers,...]`. One module per paper table/figure (DESIGN.md §7)."""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

from .common import Report

BENCHES = [
    "bench_solvers",  # Table 3.1 / 4.1
    "bench_dual",  # Figures 4.1–4.3
    "bench_mll",  # Figure 5.1 + §5.4
    "bench_kronecker",  # Chapter 6
    "bench_thompson",  # Figures 3.7 / 4.4
    "bench_molecules",  # Table 4.2
    "bench_gram_kernel",  # Pallas tile sweep
    "bench_roofline",  # §Roofline (reads dry-run JSONL)
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-sized datasets")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--out", default=None, help="dump rows as JSONL")
    args = ap.parse_args(argv)

    names = args.only.split(",") if args.only else BENCHES
    report = Report()
    failures = 0
    for name in names:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(report, full=args.full)
            print(f"=== {name} done in {time.time()-t0:.0f}s ===")
        except Exception:
            traceback.print_exc()
            failures += 1
    report.dump(args.out)
    print(f"\n{len(report.rows)} rows; {failures} bench failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
