"""Large-scale parallel Thompson sampling (§3.3.2 / §4.3.2).

    PYTHONPATH=src python examples/bayesopt_thompson.py [--steps 5] [--acq 64]

Maximises a random GP-prior draw on [0,1]^d using batched posterior-sample
acquisition; each Thompson step solves ONE batched linear system (pathwise
conditioning) with stochastic dual descent, then maximises every sampled function
with multi-start gradient ascent.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import make_params
from repro.core.rff import sample_prior
from repro.core.solvers.spec import SDD
from repro.core.thompson import ThompsonState, thompson_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--n0", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--acq", type=int, default=64)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params = make_params("matern32", lengthscale=0.25, signal=1.0, noise=0.001,
                         d=args.d)
    target = sample_prior(params, jax.random.PRNGKey(42), 1, 4096, args.d)

    def objective(x):
        return target(x)[:, 0]

    x0 = jax.random.uniform(jax.random.fold_in(key, 1), (args.n0, args.d))
    y0 = objective(x0)
    state = ThompsonState(x=x0, y=y0, best=float(y0.max()))
    print(f"initial best over {args.n0} random points: {state.best:.4f}")

    for step in range(args.steps):
        t0 = time.time()
        state = thompson_step(
            params, state, objective, jax.random.fold_in(key, 100 + step),
            acq_batch=args.acq, num_candidates=2048, num_top=8, ascent_steps=30,
            spec=SDD(num_steps=4000, batch_size=256, step_size_times_n=2.0),
        )
        print(f"step {step}: best={state.best:.4f}  n={state.x.shape[0]}  "
              f"({time.time()-t0:.1f}s)")

    xr = jax.random.uniform(jax.random.fold_in(key, 999),
                            (args.steps * args.acq, args.d))
    print(f"random-search control at equal budget: "
          f"{float(jnp.maximum(objective(xr).max(), y0.max())):.4f}")


if __name__ == "__main__":
    main()
