"""Learning-curve prediction with the latent-Kronecker GP (Ch. 6 §6.3.2).

    PYTHONPATH=src python examples/learning_curves.py

Runs a small sweep of LM training configs, logs their loss curves as a partially
observed (config × step) grid (runs are stopped at random prefixes), fits the
LKGP, and shows prediction of the unseen continuations + sweep pruning decisions.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import grid_curves
from repro.train.curve_gp import divergence_score, fit_curve_gp, should_stop_early


def main():
    data = grid_curves(n_configs=32, n_steps=40, density=0.7, seed=0)
    mask = np.asarray(data["mask"])
    print(f"grid: {mask.shape[0]} configs × {mask.shape[1]} steps, "
          f"{mask.mean()*100:.0f}% observed (prefix runs)")

    pred = fit_curve_gp(data["curves"], data["mask"], data["grid1"],
                        max_iters=300, num_samples=64)

    curves = np.asarray(data["curves"])
    err_obs = np.abs(np.asarray(pred.mean) - curves)[mask].mean()
    err_unobs = np.abs(np.asarray(pred.mean) - curves)[~mask].mean()
    print(f"mean abs error — observed cells: {err_obs:.4f}, "
          f"unseen continuations: {err_unobs:.4f}")

    order = np.argsort(np.asarray(pred.final_mean))
    print("\npredicted final losses (best 5):")
    for i in order[:5]:
        seen = int(mask[i].sum())
        print(f"  config {i:2d}: pred {pred.final_mean[i]:.3f} ± "
              f"{pred.final_std[i]:.3f} (true {curves[i,-1]:.3f}, saw {seen} steps)")

    pruned = [int(i) for i in range(mask.shape[0]) if should_stop_early(pred, i)]
    kept_best = int(order[0])
    print(f"\nsweep pruning: stop {len(pruned)}/{mask.shape[0]} runs early; "
          f"best config {kept_best} kept: {kept_best not in pruned}")

    z = divergence_score(pred, 0, 20, float(curves[0, 20]) + 5.0)
    print(f"divergence detector: planted loss spike scores z={z:.1f} (>3 flags)")


if __name__ == "__main__":
    main()
