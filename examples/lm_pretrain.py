"""End-to-end LM pretraining driver with checkpoint/restart.

    PYTHONPATH=src python examples/lm_pretrain.py --arch mamba2-130m --steps 300

Trains a (reduced, CPU-sized) assigned architecture for a few hundred steps on the
synthetic bigram pipeline, checkpointing every 50 steps; re-running the same
command resumes from the newest checkpoint. On TPU hardware drop --reduced to
train the full config on the production mesh (launch/train.py).
"""
import argparse

import jax.numpy as jnp

from repro.configs.base import get_config, list_configs
from repro.train.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=list_configs())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — TPU-sized")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    tc = TrainerConfig(
        batch=args.batch, seq_len=args.seq_len, num_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10,
        opt=AdamWConfig(lr=1e-3, warmup_steps=30, mu_dtype=jnp.float32),
    )
    tr = Trainer(cfg, tc)
    tr.run()
    rep = tr.straggler_report()
    print(f"final loss {tr.losses[-1]:.4f}  (start {tr.losses[0]:.4f})  "
          f"median step {rep.median_s*1e3:.0f}ms  stragglers {len(rep.slow_steps)}")


if __name__ == "__main__":
    main()
