"""Molecular binding-affinity regression with a Tanimoto-kernel GP + SDD (§4.3.3).

    PYTHONPATH=src python examples/molecules.py

Count-fingerprint molecules, Tanimoto (Jaccard) covariance, stochastic dual
descent for the representer weights — the Chapter 4 demonstration that exact-GP
inference scales to large sparse-input tasks.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import TANIMOTO, gram, make_params
from repro.core.solvers.base import Gram
from repro.core.solvers.spec import SDD, solve
from repro.data.pipeline import molecule_fingerprints


def r2(y, pred):
    y, pred = np.asarray(y), np.asarray(pred)
    return float(1 - ((y - pred) ** 2).sum() / ((y - y.mean()) ** 2).sum())


def main():
    data = molecule_fingerprints(n=4096, dim=1024, seed=0)
    p = make_params(TANIMOTO, signal=1.0, noise=0.3)
    op = Gram(x=data["x"], params=p)
    t0 = time.time()
    res = solve(op, data["y"], SDD(num_steps=8000, batch_size=256,
                                   step_size_times_n=2.0), key=jax.random.PRNGKey(0))
    dt = time.time() - t0
    pred = gram(p, data["x_test"], data["x"]) @ res.solution
    print(f"Tanimoto-GP via SDD: n={data['x'].shape[0]}  {dt:.1f}s  "
          f"rel-resid={float(res.rel_residual.max()):.2e}")
    print(f"test R² = {r2(data['y_test'], pred):.3f} "
          f"(mean-predictor baseline: 0.000)")


if __name__ == "__main__":
    main()
