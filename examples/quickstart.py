"""Quickstart: GP regression with iterative solvers + pathwise conditioning.

    PYTHONPATH=src python examples/quickstart.py

Fits a GP to 10k synthetic observations with three linear-system solvers (CG, SGD,
SDD — Chapters 2/3/4), draws posterior function samples via pathwise conditioning,
and compares them against the exact O(n³) GP on a held-out set.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import IterativeGP
from repro.core.gp import exact_posterior
from repro.core.kernels_fn import make_params
from repro.core.pathwise import posterior_functions
from repro.core.solvers.spec import CG, SDD, SGD
from repro.data.pipeline import regression_dataset


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000, help="10000+ for the paper scale")
    ap.add_argument("--steps", type=int, default=6000)
    args = ap.parse_args()
    data = regression_dataset(args.n, d=4, seed=0, noise=0.1)
    x, y, xt, yt = data["x"], data["y"], data["x_test"], data["y_test"]
    params = make_params("matern32", lengthscale=1.0, signal=1.0, noise=0.1, d=4)

    print(f"n={x.shape[0]}, d={x.shape[1]}; exact GP as reference ...")
    t0 = time.time()
    exact = exact_posterior(params, x, y)
    mu_ref = exact.mean(xt)
    print(f"  exact (Cholesky, O(n³)): {time.time()-t0:.1f}s  "
          f"rmse={float(jnp.sqrt(jnp.mean((mu_ref - yt)**2))):.4f}")

    # each solver is a declarative spec; posterior_functions(..., spec=...) is the
    # only thing that changes between methods
    for name, spec in [
        ("CG  (§2.2.4)", CG(max_iters=200, tol=1e-4)),
        ("SGD (Ch. 3) ", SGD(num_steps=args.steps, batch_size=512,
                             step_size_times_n=0.5)),
        ("SDD (Ch. 4) ", SDD(num_steps=args.steps, batch_size=512,
                             step_size_times_n=5.0)),
    ]:
        t0 = time.time()
        pf = posterior_functions(params, x, y, jax.random.PRNGKey(0),
                                 num_samples=16, num_features=2048, spec=spec)
        mu, var = pf.sample_mean_and_var(xt)
        dt = time.time() - t0
        rmse = float(jnp.sqrt(jnp.mean((mu - yt) ** 2)))
        drift = float(jnp.max(jnp.abs(mu - mu_ref)))
        print(f"  {name}: {dt:5.1f}s  rmse={rmse:.4f}  |µ−µ_exact|∞={drift:.4f}  "
              f"mean σ={float(jnp.sqrt(var.mean())):.3f}")
    print("posterior samples are functions: evaluating 16 samples at 5 new points:")
    print(np.asarray(pf(xt[:5])).round(3))

    # ...or the whole pipeline in three lines via the façade:
    gp = IterativeGP("matern32", lengthscale=1.0, noise=0.1, spec="cg")
    mu, var = gp.fit(x, y).predict(xt, num_samples=16)
    print(f"IterativeGP façade: rmse={float(jnp.sqrt(jnp.mean((mu - yt)**2))):.4f}")


if __name__ == "__main__":
    main()
