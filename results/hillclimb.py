"""§Perf Phase-2 hillclimbs (H1–H3): run after the baseline sweep.

    PYTHONPATH=src python results/hillclimb.py --out results/hillclimb.jsonl
"""
import argparse
import dataclasses
import json


def run_cell(tag, **kw):
    from repro.launch.dryrun import lower_cell

    rec, _ = lower_cell(**kw)
    rec["tag"] = tag
    rf = rec.get("roofline", {})
    print(f"[{tag}] hbm={rec.get('hbm_per_device',{}).get('total_gb')}GB "
          f"compute={rf.get('compute_s'):.4g} memory={rf.get('memory_s'):.4g} "
          f"collective={rf.get('collective_s'):.4g} dom={rf.get('dominant')} "
          f"mfu={rf.get('mfu')}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    ap.add_argument("--which", default="h1,h2,h3,h4,h5")
    args = ap.parse_args()
    which = set(args.which.split(","))
    recs = []

    if "h1" in which:
        # H1: llama3-8b train_4k — collective-bound → pure-FSDP profile
        recs.append(run_cell("h1-baseline-tp", arch="llama3-8b", shape_name="train_4k"))
        recs.append(run_cell("h1-fsdp", arch="llama3-8b", shape_name="train_4k",
                             profile="fsdp"))

    if "h2" in which:
        # H2: dbrx-132b train_4k — memory-bound → microbatch accumulation (×2, ×4)
        recs.append(run_cell("h2-baseline", arch="dbrx-132b", shape_name="train_4k"))
        recs.append(run_cell("h2-micro2", arch="dbrx-132b", shape_name="train_4k",
                             micro_steps=2))
        recs.append(run_cell("h2-micro4", arch="dbrx-132b", shape_name="train_4k",
                             micro_steps=4))

    if "h4" in which:
        # H4: llama3-8b prefill_32k — collective-bound (88 s): train-only SP
        # (the fix is global in models/model.py; this re-lower measures it)
        recs.append(run_cell("h4-prefill-fixed", arch="llama3-8b",
                             shape_name="prefill_32k"))

    if "h5" in which:
        # H5: jamba train_4k — compute-bound (useful=0.06): the SSD intra-chunk
        # einsum costs O(s·q·d_inner) per layer; chunk q=256 makes it dominate.
        # Napkin: intra ∝ q, inter-state ∝ n=128/q — q* ≈ n. Try 128, 64.
        from repro.configs.base import get_config as _gc
        recs.append(run_cell("h5-baseline-q256", arch="jamba-1.5-large-398b",
                             shape_name="train_4k"))
        for q in (128, 64):
            cfgq = dataclasses.replace(_gc("jamba-1.5-large-398b"), ssm_chunk=q)
            recs.append(run_cell(f"h5-q{q}", arch="jamba-1.5-large-398b",
                                 shape_name="train_4k", config_override=cfgq))

    if "h3" in which:
        # H3: deepseek-v2 decode_32k — MLA latent-space (absorbed) attention
        from repro.configs.base import get_config

        recs.append(run_cell("h3-baseline", arch="deepseek-v2-236b",
                             shape_name="decode_32k"))
        cfg = dataclasses.replace(get_config("deepseek-v2-236b"), mla_absorb=True)
        recs.append(run_cell("h3-absorbed", arch="deepseek-v2-236b",
                             shape_name="decode_32k", config_override=cfg))

    with open(args.out, "a") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
