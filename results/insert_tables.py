"""Insert the dry-run/roofline summary tables into EXPERIMENTS.md markers.

    PYTHONPATH=src python results/insert_tables.py
"""
import json
import re
import subprocess
import sys


def table_for(jsonl, mesh_filter=None):
    recs = {}
    for line in open(jsonl):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"], r.get("profile", "tp"))] = r
    rows = sorted(recs.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | status | compute_s | memory_s | collective_s | "
           "dom | useful | MFU | HBM/dev GB |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_fit = 0
    for r in rows:
        if r.get("profile", "tp") != "tp":
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped "
                       f"(long_500k policy) | | | | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | | |")
            continue
        rf = r["roofline"]
        hbm = r["hbm_per_device"]["total_gb"]
        n_ok += 1
        n_fit += hbm <= 16
        out.append(
            "| {a} | {s} | {m} | ok | {c:.4g} | {mem:.4g} | {k:.4g} | {d} | {u:.2f} "
            "| **{mfu:.3g}** | {h:.1f}{flag} |".format(
                a=r["arch"], s=r["shape"], m=r["mesh"], c=rf["compute_s"],
                mem=rf["memory_s"], k=rf["collective_s"], d=rf["dominant"],
                u=rf["useful_fraction"], mfu=rf["mfu"], h=hbm,
                flag="" if hbm <= 16 else " ⚠" ))
    out.append("")
    out.append(f"compiled ok: {n_ok}; fit ≤16 GB/dev: {n_fit}/{n_ok}")
    return "\n".join(out)


def main():
    md = open("EXPERIMENTS.md").read()
    dry = table_for("results/dryrun.jsonl")  # both meshes — compile proof
    roof = table_for("results/dryrun_v2.jsonl", mesh_filter="16x16")
    md = md.replace("<!-- DRYRUN-SUMMARY -->",
                    "### Compile matrix (both meshes, baseline tp profile, "
                    "traffic-model v1)\n\n" + dry)
    md = md.replace("<!-- ROOFLINE-SUMMARY -->",
                    "### Single-pod roofline baseline (traffic-model v2 — "
                    "slice-aware; see DESIGN.md §8)\n\n" + roof)
    open("EXPERIMENTS.md", "w").write(md)
    print("inserted")


if __name__ == "__main__":
    main()
