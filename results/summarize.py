"""Render results/dryrun.jsonl into the EXPERIMENTS.md §Dry-run/§Roofline tables.

    PYTHONPATH=src python results/summarize.py [--jsonl results/dryrun.jsonl]
"""
import argparse
import json
from collections import defaultdict


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped | — | — | — | — | — | — | "
                f"{r['reason'][:46]} |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — | — | — | — | — | "
                f"{r.get('error','')[:46]} |")
    rf = r["roofline"]
    hbm = r["hbm_per_device"]["total_gb"]
    note = "fits" if hbm <= 16 else "OVER 16GB"
    return ("| {arch} | {shape} | {mesh} | ok | {c:.3g} | {m:.3g} | {k:.3g} | {dom} | "
            "{mfu:.3g} | {hbm:.1f} | {note} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"], c=rf["compute_s"],
        m=rf["memory_s"], k=rf["collective_s"], dom=rf["dominant"][:4],
        mfu=rf["mfu"], hbm=hbm, note=note)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    args = ap.parse_args()
    # keep the LAST record per (arch, shape, mesh, profile)
    recs = {}
    for line in open(args.jsonl):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"], r.get("profile", "tp"))] = r
    rows = sorted(recs.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("| arch | shape | mesh | status | compute_s | memory_s | collective_s | dom "
          "| MFU | HBM/dev GB | note |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("profile", "tp") == "tp":
            print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "ok" and r.get("profile", "tp") == "tp"]
    sk = [r for r in rows if r["status"] == "skipped"]
    er = [r for r in rows if r["status"] not in ("ok", "skipped")]
    fit = [r for r in ok if r["hbm_per_device"]["total_gb"] <= 16]
    print(f"\ncompiled OK: {len(ok)}  skipped(policy): {len(sk)}  errors: {len(er)}  "
          f"fit≤16GB: {len(fit)}/{len(ok)}")
    by_dom = defaultdict(int)
    for r in ok:
        by_dom[r["roofline"]["dominant"]] += 1
    print("dominant terms:", dict(by_dom))


if __name__ == "__main__":
    main()
