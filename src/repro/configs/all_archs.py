"""Imports every per-arch config module so the registry is populated."""
from . import (  # noqa: F401
    dbrx_132b,
    deepseek_v2_236b,
    deepseek_coder_33b,
    minitron_8b,
    llama3_8b,
    olmo_1b,
    whisper_tiny,
    jamba_1_5_large_398b,
    mamba2_130m,
    qwen2_vl_7b,
)
