"""Architecture config system: one ModelConfig per assigned architecture.

Shapes (assigned): train_4k, prefill_32k, decode_32k, long_500k — see SHAPES below.
``long_500k`` is only valid for sub-quadratic archs (ssm/hybrid); the registry marks
applicability and launch/dryrun.py records skips (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (fine-grained); 0 → use d_ff
    moe_layer_period: int = 1  # MoE every k-th layer (jamba: 2); dense otherwise
    capacity_factor: float = 1.25
    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    mla_absorb: bool = False  # §Perf H3: absorb W_uk into q → attend in latent space
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # --- SSM (mamba2 / jamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_layer_period: int = 0  # hybrid: 1 attention layer per this many (jamba: 8)
    # --- frontends (stubs) ---
    encoder_layers: int = 0  # whisper: enc-dec
    encoder_seq: int = 0  # fixed encoder length (whisper: 1500 after conv stub)
    vision_tokens: int = 0  # qwen2-vl: stub patch-embedding positions
    use_mrope: bool = False
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    parametric_norm: bool = True  # olmo: False (non-parametric LN)
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §4 shape policy)."""
        return self.family in ("ssm", "hybrid")

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test-sized variant of the same family (CPU-runnable)."""
        small = dict(
            num_layers=min(self.num_layers, 4 if not self.is_hybrid else 8),
            d_model=256,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=512,
            head_dim=64,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_tok=min(self.experts_per_tok, 2),
            moe_d_ff=256 if self.moe_d_ff else 0,
            kv_lora_rank=64 if self.use_mla else 0,
            qk_rope_dim=32 if self.use_mla else self.qk_rope_dim,
            qk_nope_dim=64 if self.use_mla else self.qk_nope_dim,
            v_head_dim=64 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=64 if self.encoder_seq else 0,
            vision_tokens=16 if self.vision_tokens else 0,
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        from . import all_archs  # noqa: F401  (populates registry)
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        from . import all_archs  # noqa: F401
    return sorted(_REGISTRY)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Shape policy from DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; full-attention arch"
    return True, ""
