"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,          # per-expert hidden dim
    vocab_size=100_352,
    num_experts=16,
    experts_per_tok=4,
    moe_d_ff=10752,
    rope_theta=500_000.0,
))
