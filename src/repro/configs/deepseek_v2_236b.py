"""deepseek-v2-236b — MLA (kv_lora=512), 2 shared + 160 routed top-6 [arXiv:2405.04434; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,    # MLA: logical kv heads == q heads; cache is the 512-d latent
    head_dim=128,
    d_ff=1536,           # per routed expert (fine-grained)
    vocab_size=102_400,
    num_experts=160,
    experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
))
