"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE 16e top-2 every other
layer [arXiv:2403.19887; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,        # 9 periods x (1 attn + 7 mamba)
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65_536,
    num_experts=16,
    experts_per_tok=2,
    moe_d_ff=24576,
    moe_layer_period=2,   # MoE every other layer
    attn_layer_period=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=128,
))
