"""mamba2-130m — SSD (state-space duality), attn-free [arXiv:2405.21060; unverified]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,             # attn-free, no MLP: mamba block only (expand=2)
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
))
