"""minitron-8b — pruned nemotron dense [arXiv:2407.14679; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
))
