"""olmo-1b — non-parametric LN (no learnable affine) [arXiv:2402.00838; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,   # MHA
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    parametric_norm=False,
    tie_embeddings=True,
))
