"""qwen2-vl-7b — M-RoPE, dynamic resolution; vision frontend is a STUB
(input_specs() provides precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    vision_tokens=1024,
    use_mrope=True,
    rope_theta=1_000_000.0,
))
