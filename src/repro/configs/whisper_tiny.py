"""whisper-tiny — enc-dec; conv frontend is a STUB (input_specs() provides
precomputed frame embeddings at 1500 encoder positions) [arXiv:2212.04356; unverified]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,         # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
))
