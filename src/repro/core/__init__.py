"""Core GP library — the paper's contribution (see DESIGN.md §1)."""
from .kernels_fn import KernelParams, make_params, gram, matvec  # noqa: F401
from .operators import (  # noqa: F401
    FeatureOperator,
    Gram,
    LatentKroneckerOp,
    LinearOperator,
    NormalEq,
    OPTIONAL_CAPABILITIES,
    OPTIONAL_FEATURE_CAPABILITIES,
    RFFGram,
    ShardedGram,
    capabilities,
    feature_capabilities,
    matvec_counts,
    require_capabilities,
    reset_matvec_counts,
    supports,
)
from .rff import (  # noqa: F401
    FourierFeatures,
    PriorSamples,
    make_fourier_features,
    sample_prior,
)
from .gp import exact_posterior, exact_mll  # noqa: F401
from .pathwise import posterior_functions, PosteriorFunctions  # noqa: F401
from .solvers.base import (  # noqa: F401
    FLAG_BREAKDOWN,
    FLAG_NONFINITE,
    FLAG_STAGNATION,
    FROZEN_FLAGS,
    SolveResult,
    flag_names,
)
from .solvers.robust import (  # noqa: F401
    EscalationPolicy,
    RungRecord,
    SolveReport,
    solve_robust,
)
from .solvers.cg import solve_cg  # noqa: F401
from .solvers.sgd import solve_sgd  # noqa: F401
from .solvers.sdd import solve_sdd  # noqa: F401
from .solvers.ap import solve_ap  # noqa: F401
from .solvers.spec import (  # noqa: F401
    AP,
    CG,
    SDD,
    SGD,
    Jacobi,
    Nystrom,
    PivotedCholesky,
    RFF,
    SolverSpec,
    as_spec,
    get_precond,
    get_solver,
    register_precond,
    register_solver,
    registered_preconds,
    registered_solvers,
    solve,
    solve_batched,
    spec_from_dict,
    spec_from_json,
    spec_to_dict,
    spec_to_json,
)
from .precond import JacobiPrecond, WoodburyPrecond  # noqa: F401
from .api import IterativeGP  # noqa: F401
from .mll import mll_grad, optimize_mll  # noqa: F401
from .inducing import inducing_posterior  # noqa: F401
from .kronecker import make_lkgp, lkgp_posterior, break_even_density  # noqa: F401
from .distributed import distributed_solve, shard_training_rows  # noqa: F401
from .svgp import sgpr, sgpr_elbo, sgpr_iterative  # noqa: F401
