"""`IterativeGP` — the paper's pipeline in three lines.

    gp = IterativeGP("matern32", lengthscale=0.5, noise=0.1, spec="sdd")
    gp.fit(x, y).optimize(num_steps=20)
    mean, var = gp.predict(x_new)

Everything routes through the unified SolverSpec API (core/solvers/spec.py): the
same spec drives MLL optimisation (Ch. 5), pathwise posterior sampling (Ch. 3) and
prediction, so swapping CG ↔ SGD ↔ SDD ↔ AP is a one-argument change.
"""
from __future__ import annotations

import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, make_params
from .mll import MLLOptimState, optimize_mll
from .pathwise import PosteriorFunctions, posterior_functions
from .solvers.base import flag_names
from .solvers.spec import SolverSpec, SpecLike, as_spec


class IterativeGP:
    """Scalable GP regression façade over the iterative-solver stack.

    Stateful and deliberately small: ``fit`` stores the data (GPs have no separate
    training phase — all cost is in the linear solves), ``optimize`` runs Adam
    ascent on the marginal likelihood with warm-started inner solves, and
    ``posterior``/``sample``/``predict`` expose pathwise-conditioned function
    samples. All PRNG handling is internal (seeded by ``seed``) unless an explicit
    ``key`` is passed.
    """

    def __init__(
        self,
        kernel: str = "matern32",
        *,
        lengthscale: float = 1.0,
        signal: float = 1.0,
        noise: float = 0.1,
        spec: SpecLike = "cg",
        seed: int = 0,
    ):
        self.kernel = kernel
        self._init_hypers = dict(lengthscale=lengthscale, signal=signal, noise=noise)
        self.spec: SolverSpec = as_spec(spec)
        self.params: Optional[KernelParams] = None
        self.x: Optional[jax.Array] = None
        self.y: Optional[jax.Array] = None
        self._key = jax.random.PRNGKey(seed)
        self._post: Optional[PosteriorFunctions] = None
        self._post_cache_key: Optional[tuple] = None
        self.last_optim: Optional[MLLOptimState] = None

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _require_fitted(self):
        if self.x is None:
            raise RuntimeError("call fit(x, y) before optimizing or predicting")

    def fit(self, x, y) -> "IterativeGP":
        """Store training data; hyperparameters are created on first fit (and
        re-initialised if the feature dimension changes — ARD lengthscales sized
        for the old d cannot be reused)."""
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        if self.params is not None and self.params.log_lengthscale.shape != (
            x.shape[1],
        ):
            self.params = None
        if self.params is None:
            self.params = make_params(
                self.kernel, d=x.shape[1], **self._init_hypers
            )
        self.x, self.y = x, y
        self._post = None
        return self

    def optimize(
        self,
        num_steps: int = 20,
        lr: float = 0.05,
        *,
        num_probes: int = 8,
        warm_start: bool = True,
        estimator: str = "pathwise",
        key: Optional[jax.Array] = None,
    ) -> "IterativeGP":
        """Adam ascent on the MLL with warm-started inner solves (Ch. 5)."""
        self._require_fitted()
        st = optimize_mll(
            self.params,
            self.x,
            self.y,
            self._next_key() if key is None else key,
            num_steps=num_steps,
            lr=lr,
            num_probes=num_probes,
            warm_start=warm_start,
            estimator=estimator,
            spec=self.spec,
        )
        self.params = st.params
        self.last_optim = st
        self._post = None
        return self

    def posterior(
        self,
        num_samples: int = 16,
        num_features: int = 2048,
        key: Optional[jax.Array] = None,
    ) -> PosteriorFunctions:
        """Pathwise-conditioned posterior function samples. Cached until the
        hyperparameters, data, or sampling arguments change; passing an explicit
        ``key`` always draws fresh samples."""
        self._require_fitted()
        cache_key = (num_samples, num_features)
        if self._post is None or key is not None or self._post_cache_key != cache_key:
            self._post = posterior_functions(
                self.params,
                self.x,
                self.y,
                self._next_key() if key is None else key,
                num_samples=num_samples,
                num_features=num_features,
                spec=self.spec,
            )
            self._post_cache_key = cache_key
            info = self._post.solve_info
            # divergence detection now lives in the solver loops + finalize()
            # (core/solvers/base.py): the facade just reads the structured
            # per-column flags instead of re-validating the payload itself
            if info is not None and not bool(info.healthy):
                bad = flag_names(
                    int(jnp.bitwise_or.reduce(jnp.atleast_1d(info.flags)))
                )
                warnings.warn(
                    f"solver {self.spec.name!r} diverged "
                    f"(flags: {', '.join(bad)}) — its step size is tuned for "
                    f"large n; reduce step_size_times_n or use spec='cg'",
                    RuntimeWarning,
                    stacklevel=2,
                )
        return self._post

    def sample(
        self,
        xs,
        num_samples: int = 16,
        key: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Evaluate posterior function samples at ``xs`` → (n*, num_samples)."""
        return self.posterior(num_samples, key=key)(jnp.asarray(xs))

    def predict(
        self,
        xs,
        num_samples: int = 64,
        key: Optional[jax.Array] = None,
    ) -> tuple:
        """Posterior mean (representer weights, no MC error) and MC variance."""
        post = self.posterior(num_samples, key=key)
        return post.sample_mean_and_var(jnp.asarray(xs))

    def engine(
        self,
        *,
        num_samples: int = 16,
        num_features: int = 2048,
        key: Optional[jax.Array] = None,
        **engine_kwargs,
    ) -> "GPEngine":
        """Hand the fitted GP off to a long-lived serving engine.

        Returns a :class:`repro.serve.GPEngine` holding this GP's fitted
        posterior state (representer weights, pathwise prior paths, solver
        spec) and serving streams of ``predict`` / ``sample`` /
        ``thompson_step`` requests with continuous batching over shared
        multi-RHS solves — see ``docs/serving.md``. The engine snapshots the
        current hyperparameters and data; further ``optimize``/``fit`` calls on
        this façade do not affect a handed-off engine (push new observations
        with ``engine.add_observations`` instead, which refits warm-started).
        """
        self._require_fitted()
        from ..serve import GPEngine  # deferred: serve imports core

        return GPEngine(
            self.params,
            self.x,
            self.y,
            spec=self.spec,
            num_samples=num_samples,
            num_features=num_features,
            key=self._next_key() if key is None else key,
            **engine_kwargs,
        )
