"""Distributed GP inference (DESIGN.md §2): ShardedGram through the unified solve().

The training rows X are sharded over the mesh's ``data`` axis (and ``pod`` when
multi-pod) — a block-row distribution of K, wrapped as the
:class:`~repro.core.operators.ShardedGram` LinearOperator. Each device computes
its K-block matvec without materialising the block through the same backend
dispatch as the single-host path (fused Pallas kernel on TPU, chunked JAX
elsewhere — ``pallas``/``chunked``/``dense`` threaded through the shards), and
the solver's reductions become ``psum``/``all_gather`` collectives over the data
axes.

Because ShardedGram implements the full capability set — including the sharded
row-gather primitives ``rows_mv``/``rows_t_mv``/``block_at`` — ANY SolverSpec
runs distributed: CG (with Nyström/pivoted-Cholesky preconditioning via
``precond_factor``), SGD, SDD and AP, all with warm starts, the δ channel and
matvec accounting. Memory per device: O(n_local · chunk) — the paper's
linear-memory claim, per device. CG iterations are bulk-synchronous; SGD/SDD
steps tolerate stale coordinates and back the straggler-tolerant mode.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .kernels_fn import KernelParams
from .operators import ShardedGram
from .solvers.base import SolveResult
from .solvers.spec import SpecLike, solve


def shard_training_rows(mesh: Mesh, x: jax.Array, data_axes=("data",)) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(data_axes, None)))


def distributed_solve(
    params: KernelParams,
    x: jax.Array,
    b: jax.Array,
    mesh: Mesh,
    spec: SpecLike = "cg",
    data_axes=("data",),
    *,
    key: Optional[jax.Array] = None,
    x0: Optional[jax.Array] = None,
    delta: Optional[jax.Array] = None,
    backend: str = "auto",
    row_chunk: int = 2048,
    gather_once: bool = False,
) -> SolveResult:
    """Spec-driven front door for sharded solves — ``solve(ShardedGram, …)``.

    ``x`` should be row-sharded over ``data_axes`` (see
    :func:`shard_training_rows`); ``b`` (and ``x0``/``delta``) are replicated.
    Any registered SolverSpec works — stochastic specs need ``key=`` exactly as
    in the single-host ``solve()`` — and the spec's ``backend`` field pins the
    per-shard kernel backend. ``gather_once=True`` replicates the sharded
    inputs once per solve (``solve()`` calls the operator's
    ``prepare_for_solve`` hook outside the solver loop) instead of
    all-gathering them on every matvec — an O(n·d) per-device memory cost that
    removes one collective per solver iteration; use when the replicated input
    panel fits. Returns the full :class:`SolveResult` (solution, residuals,
    iteration and matvec counts).
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    op = ShardedGram(
        x=x, params=params, mesh=mesh, data_axes=axes, backend=backend,
        row_chunk=row_chunk, gather_once=gather_once,
    )
    return solve(op, b, spec, key=key, x0=x0, delta=delta)
