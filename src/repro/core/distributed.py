"""Distributed GP inference (DESIGN.md §2): ShardedGram through the unified solve().

The training rows X are sharded over the mesh's ``data`` axis (and ``pod`` when
multi-pod) — a block-row distribution of K, wrapped as the
:class:`~repro.core.operators.ShardedGram` LinearOperator. Each device computes
its K-block matvec without materialising the block through the same backend
dispatch as the single-host path (fused Pallas kernel on TPU, chunked JAX
elsewhere — ``pallas``/``chunked``/``dense`` threaded through the shards), and
the solver's reductions become mesh collectives over the data axes.

``comm`` selects the collective schedule (docs/distributed.md): ``"gather"``
all-gathers the sharded inputs around each matvec (communication strictly
precedes compute; vectors replicated), ``"ring"`` pipelines ``ppermute`` shard
rotations against the per-shard fused contraction — communication overlaps
compute, the O(n·d) replicated panel never exists, zero per-matvec
``all_gather``, and solver iterates stay row-sharded through the CG loop (psum
inner products, sharded axpys: O(n·s/P) vector memory per device) — and
``"auto"`` picks ring once the replicated panel exceeds a per-device byte
budget.

Because ShardedGram implements the full capability set — including the sharded
row-gather primitives ``rows_mv``/``rows_t_mv``/``block_at`` and the
``wrap_features`` mesh-awareness hook SGD's regulariser consumes — ANY
SolverSpec runs distributed: CG (with Nyström/pivoted-Cholesky preconditioning
via ``precond_factor``), SGD, SDD and AP, all with warm starts, the δ channel
and matvec accounting. Memory per device: O(n_local · chunk) — the paper's
linear-memory claim, per device. CG iterations are bulk-synchronous; SGD/SDD
steps tolerate stale coordinates and back the straggler-tolerant mode.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .kernels_fn import KernelParams
from .operators import COMM_STRATEGIES, ShardedGram
from .solvers.base import SolveResult
from .solvers.spec import SpecLike, as_spec, solve


def shard_training_rows(mesh: Mesh, x: jax.Array, data_axes=("data",)) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(data_axes, None)))


def distributed_solve(
    params: KernelParams,
    x: jax.Array,
    b: jax.Array,
    mesh: Mesh,
    spec: SpecLike = "cg",
    data_axes=("data",),
    *,
    key: Optional[jax.Array] = None,
    x0: Optional[jax.Array] = None,
    delta: Optional[jax.Array] = None,
    backend: str = "auto",
    row_chunk: int = 2048,
    gather_once: bool = False,
    comm: str = "gather",
    comm_budget_bytes: Optional[int] = None,
) -> SolveResult:
    """Spec-driven front door for sharded solves — ``solve(ShardedGram, …)``.

    ``x`` should be row-sharded over ``data_axes`` (see
    :func:`shard_training_rows`); ``b`` (and ``x0``/``delta``) are replicated
    or row-sharded. Any registered SolverSpec works — stochastic specs need
    ``key=`` exactly as in the single-host ``solve()`` — and the spec's
    ``backend`` field pins the per-shard kernel backend.

    ``comm`` picks the collective schedule (``"gather"``/``"ring"``/``"auto"``,
    see :class:`~repro.core.operators.ShardedGram`). Under ``ring``, matvec-only
    specs (the CG family) get their RHS and warm start re-sharded over
    ``data_axes`` so every solver iterate stays row-sharded through the loop.
    ``gather_once=True`` replicates the sharded inputs once per solve
    (``solve()`` calls the operator's ``prepare_for_solve`` hook outside the
    solver loop) instead of all-gathering them on every matvec — an O(n·d)
    per-device memory cost that removes one collective per solver iteration;
    use when the replicated input panel fits. It is the opposite trade to
    ``ring``, so combining them raises ``ValueError``. Returns the full
    :class:`SolveResult` (solution, residuals, iteration and matvec counts).
    """
    if comm not in COMM_STRATEGIES:
        raise ValueError(
            f"unknown comm strategy {comm!r}; expected one of {COMM_STRATEGIES}"
        )
    if comm == "ring" and gather_once:
        raise ValueError(
            "gather_once=True pre-replicates the O(n·d) input panel that "
            "comm='ring' exists to avoid — drop one of them (comm='auto' "
            "resolves to gather when gather_once is set)"
        )
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    kwargs = {} if comm_budget_bytes is None else dict(
        comm_budget_bytes=comm_budget_bytes
    )
    op = ShardedGram(
        x=x, params=params, mesh=mesh, data_axes=axes, backend=backend,
        row_chunk=row_chunk, gather_once=gather_once, comm=comm, **kwargs,
    )
    if op._resolve_comm() == "ring" and not as_spec(spec).needs:
        # matvec-only (CG-family) spec: shard the RHS/warm start so the ring
        # mv's sharded outputs and the while_loop carries agree from step one —
        # per-device vector memory O(n·s/P) instead of replicated
        shard = lambda v: (
            None if v is None
            else jax.device_put(v, NamedSharding(mesh, P(axes, *([None] * (v.ndim - 1)))))
        )
        b, x0, delta = shard(b), shard(x0), shard(delta)
    return solve(op, b, spec, key=key, x0=x0, delta=delta)
