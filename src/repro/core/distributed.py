"""Distributed GP inference (DESIGN.md §2): shard_map block-row Gram matvec + CG.

The training rows X are sharded over the mesh's ``data`` axis (and ``pod`` when
multi-pod) — a block-row distribution of K. Each device computes its K-block matvec
without materialising the block (chunked, or the Pallas kernel on TPU); the result is
already row-sharded, and CG's scalar reductions become ``psum``s over the data axes.
The RHS batch dimension (samples/probes) can additionally shard over ``model``.

Memory per device: O(n_local · chunk) — the paper's linear-memory claim, per device.
The solver iterations are bulk-synchronous (CG semantics); SGD/SDD steps tolerate
stale coordinates and are used for straggler-tolerant mode (train/elastic.py).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .kernels_fn import KernelParams, gram
from .solvers.spec import CG, SpecLike, as_spec


def _local_block_matvec(params, x_local, x_all, v_all, jitter, row_offset):
    """K(x_local, x_all) @ v + jitter * v_local — never materialises the block."""
    out = gram(params, x_local, x_all) @ v_all
    n_local = x_local.shape[0]
    v_local = jax.lax.dynamic_slice_in_dim(v_all, row_offset, n_local, axis=0)
    return out + jitter * v_local


def make_distributed_matvec(mesh: Mesh, data_axes=("data",)):
    """Returns mv(params, x_sharded, v_replicated) -> (K+σ²I)v, row-sharded inputs.

    x is sharded over `data_axes`; v is replicated; output is replicated (all-gather).
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def mv(params: KernelParams, x: jax.Array, v: jax.Array) -> jax.Array:
        def body(x_local, v_all):
            idx = jax.lax.axis_index(axes)
            n_local = x_local.shape[0]
            x_all = jax.lax.all_gather(x_local, axes, tiled=True)
            out_local = _local_block_matvec(
                params, x_local, x_all, v_all, params.noise, idx * n_local
            )
            return jax.lax.all_gather(out_local, axes, tiled=True)

        spec_x = P(axes, None)
        spec_v = P(None, None) if v.ndim == 2 else P(None)
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_x, spec_v),
            out_specs=spec_v,
            check_rep=False,
        )(x, v)

    return mv


@partial(jax.jit, static_argnames=("mesh", "data_axes", "max_iters"))
def distributed_cg(
    params: KernelParams,
    x: jax.Array,
    b: jax.Array,
    mesh: Mesh,
    data_axes=("data",),
    max_iters: int = 200,
    tol: float = 1e-3,
) -> jax.Array:
    """CG where the matvec is sharded over the mesh. x row-sharded, b replicated."""
    mv = make_distributed_matvec(mesh, data_axes)
    b2 = b[:, None] if b.ndim == 1 else b
    v = jnp.zeros_like(b2)
    r = b2 - mv(params, x, v)
    p = r
    rz = jnp.sum(r * r, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(b2, axis=0), 1e-30)

    def cond(s):
        _, r, _, t, _ = s
        return jnp.logical_and(t < max_iters, jnp.any(jnp.linalg.norm(r, axis=0) / bn > tol))

    def body(s):
        v, r, p, t, rz = s
        ap = mv(params, x, p)
        a = rz / jnp.maximum(jnp.sum(p * ap, axis=0), 1e-30)
        v = v + a[None] * p
        r = r - a[None] * ap
        rz2 = jnp.sum(r * r, axis=0)
        p = r + (rz2 / jnp.maximum(rz, 1e-30))[None] * p
        return v, r, p, t + 1, rz2

    v, *_ = jax.lax.while_loop(cond, body, (v, r, p, 0, rz))
    return v[:, 0] if b.ndim == 1 else v


def shard_training_rows(mesh: Mesh, x: jax.Array, data_axes=("data",)) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(data_axes, None)))


def distributed_solve(
    params: KernelParams,
    x: jax.Array,
    b: jax.Array,
    mesh: Mesh,
    spec: SpecLike = "cg",
    data_axes=("data",),
) -> jax.Array:
    """Spec-driven front door for sharded solves (same SolverSpec API as solve()).

    Only CG specs have a distributed implementation today; the stochastic solvers'
    row gathers are served by the elastic path (train/elastic.py) instead.
    """
    s = as_spec(spec)
    if not isinstance(s, CG):
        raise NotImplementedError(
            f"distributed solves currently support CG specs only; got {s.name!r}"
        )
    if s.precond is not None:
        raise NotImplementedError(
            "preconditioning is not supported in the distributed path yet"
        )
    return distributed_cg(
        params, x, b, mesh, data_axes, max_iters=s.max_iters, tol=s.tol
    )
