"""Exact GP regression reference (§2.1.1–2.1.2) — the O(n³) oracle.

Used by tests/benchmarks as ground truth for the iterative methods; never used at
scale. Includes both the conventional posterior (Eqs. 2.6–2.8) and conventional
(Cholesky/affine) posterior sampling (Eq. 2.9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExactPosterior:
    params: KernelParams
    x: jax.Array
    y: jax.Array
    chol: jax.Array  # cholesky(K + σ²I)
    weights: jax.Array  # (K+σ²I)^{-1} y

    def mean(self, xs: jax.Array) -> jax.Array:
        return gram(self.params, xs, self.x) @ self.weights

    def cov(self, xs: jax.Array) -> jax.Array:
        kxs = gram(self.params, self.x, xs)
        sol = jax.scipy.linalg.cho_solve((self.chol, True), kxs)
        return gram(self.params, xs) - kxs.T @ sol

    def var(self, xs: jax.Array) -> jax.Array:
        return jnp.diag(self.cov(xs))

    def sample(self, key: jax.Array, xs: jax.Array, num_samples: int) -> jax.Array:
        """Conventional sampling via Cholesky of the posterior covariance (Eq. 2.9)."""
        c = self.cov(xs) + 1e-6 * jnp.eye(xs.shape[0], dtype=xs.dtype)
        l = jnp.linalg.cholesky(c)
        w = jax.random.normal(key, (xs.shape[0], num_samples), dtype=xs.dtype)
        return self.mean(xs)[:, None] + l @ w


def exact_posterior(params: KernelParams, x: jax.Array, y: jax.Array) -> ExactPosterior:
    a = gram(params, x) + params.noise * jnp.eye(x.shape[0], dtype=x.dtype)
    chol = jnp.linalg.cholesky(a)
    w = jax.scipy.linalg.cho_solve((chol, True), y)
    return ExactPosterior(params=params, x=x, y=y, chol=chol, weights=w)


def exact_mll(params: KernelParams, x: jax.Array, y: jax.Array) -> jax.Array:
    """Log marginal likelihood (Eq. 2.36), zero prior mean."""
    n = x.shape[0]
    a = gram(params, x) + params.noise * jnp.eye(n, dtype=x.dtype)
    chol = jnp.linalg.cholesky(a)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    data_fit = -0.5 * jnp.dot(y, alpha)
    complexity = -jnp.sum(jnp.log(jnp.diag(chol)))
    return data_fit + complexity - 0.5 * n * jnp.log(2.0 * jnp.pi)
