"""Inducing-point pathwise sampling via stochastic optimisation (§3.2.3).

For m ≪ n inducing points Z, the optimal inducing posterior mean and per-sample
uncertainty-reduction weights are minimisers of (Eqs. 3.23/3.24)

    v* = argmin ½‖y − K_XZ v‖² + σ²/2 ‖v‖²_{K_ZZ}
    α*_i = argmin ½‖f_X + ε − K_XZ α‖² + σ²/2 ‖α‖²_{K_ZZ}

i.e. solutions of the m×m normal equations (K_ZX K_XZ + σ² K_ZZ) u = K_ZX b, touched
only through K_XZ matvecs (O(n·m) per iteration, m learnable weights — §3.2.3: update
cost O(m·s) vs SVGP's O(m³)). Posterior samples: f(·) + K_(·)Z (v* − α*) (Eq. 3.36),
with f_X ≈ RFF prior (the Nyström-consistency approximation discussed in §3.2.3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram, matvec
from .rff import PriorSamples, sample_prior


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class InducingPosterior:
    params: KernelParams
    z: jax.Array  # (m, d) inducing inputs
    prior: PriorSamples
    v_mean: jax.Array  # (m,)
    alpha: jax.Array  # (m, s)

    def mean(self, xs: jax.Array) -> jax.Array:
        return gram(self.params, xs, self.z) @ self.v_mean

    def __call__(self, xs: jax.Array) -> jax.Array:
        kxz = gram(self.params, xs, self.z)
        return self.prior(xs) + kxz @ (self.v_mean[:, None] - self.alpha)


def _normal_eq_matvec(
    params: KernelParams, x: jax.Array, z: jax.Array, u: jax.Array, row_chunk: int
) -> jax.Array:
    """(K_ZX K_XZ + σ² K_ZZ) @ u without materialising K_XZ (n×m) when n is large."""
    kxz_u = matvec(params, x, u, z=z, row_chunk=row_chunk)  # (n, s)
    kzx_kxz_u = matvec(params, z, kxz_u, z=x, row_chunk=row_chunk)  # (m, s)
    kzz_u = matvec(params, z, u, z=z, row_chunk=row_chunk)
    return kzx_kxz_u + params.noise * kzz_u


@partial(jax.jit, static_argnames=("max_iters", "row_chunk"))
def _solve_inducing_cg(
    params: KernelParams,
    x: jax.Array,
    z: jax.Array,
    rhs: jax.Array,
    max_iters: int = 200,
    tol: float = 1e-3,
    row_chunk: int = 4096,
) -> jax.Array:
    mv = lambda u: _normal_eq_matvec(params, x, z, u, row_chunk)
    v = jnp.zeros_like(rhs)
    r = rhs - mv(v)
    p = r
    bn = jnp.maximum(jnp.linalg.norm(rhs, axis=0), 1e-30)
    rz = jnp.sum(r * r, axis=0)

    def cond(s):
        _, r, _, t, _ = s
        return jnp.logical_and(t < max_iters, jnp.any(jnp.linalg.norm(r, axis=0) / bn > tol))

    def body(s):
        v, r, p, t, rz = s
        ap = mv(p)
        pap = jnp.sum(p * ap, axis=0)
        a = rz / jnp.where(pap > 0, pap, 1.0)
        v = v + a[None] * p
        r = r - a[None] * ap
        rz2 = jnp.sum(r * r, axis=0)
        p = r + (rz2 / jnp.where(rz > 0, rz, 1.0))[None] * p
        return v, r, p, t + 1, rz2

    v, *_ = jax.lax.while_loop(cond, body, (v, r, p, 0, rz))
    return v


def inducing_posterior(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    z: jax.Array,
    key: jax.Array,
    *,
    num_samples: int = 16,
    num_features: int = 2048,
    max_iters: int = 200,
    row_chunk: int = 4096,
) -> InducingPosterior:
    kp, ke = jax.random.split(key)
    prior = sample_prior(params, kp, num_samples, num_features, x.shape[1])
    f_x = prior(x)
    eps = jnp.sqrt(params.noise) * jax.random.normal(ke, f_x.shape, f_x.dtype)
    targets = jnp.concatenate([y[:, None], f_x + eps], axis=1)  # (n, 1+s)
    rhs = matvec(params, z, targets, z=x, row_chunk=row_chunk)  # K_ZX b: (m, 1+s)
    sol = _solve_inducing_cg(params, x, z, rhs, max_iters=max_iters, row_chunk=row_chunk)
    return InducingPosterior(
        params=params, z=z, prior=prior, v_mean=sol[:, 0], alpha=sol[:, 1:]
    )


def select_inducing_greedy(x: jax.Array, m: int, key: jax.Array) -> jax.Array:
    """Cheap inducing-point selection: random subset (§3.3.1 uses ANN dedup; a
    uniform subset is the paper's stated-adequate fallback for large m)."""
    idx = jax.random.choice(key, x.shape[0], (m,), replace=False)
    return x[idx]
