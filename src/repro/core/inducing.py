"""Inducing-point pathwise sampling via stochastic optimisation (§3.2.3).

For m ≪ n inducing points Z, the optimal inducing posterior mean and per-sample
uncertainty-reduction weights are minimisers of (Eqs. 3.23/3.24)

    v* = argmin ½‖y − K_XZ v‖² + σ²/2 ‖v‖²_{K_ZZ}
    α*_i = argmin ½‖f_X + ε − K_XZ α‖² + σ²/2 ‖α‖²_{K_ZZ}

i.e. solutions of the m×m normal equations (K_ZX K_XZ + σ² K_ZZ) u = K_ZX b, touched
only through K_XZ matvecs (O(n·m) per iteration, m learnable weights — §3.2.3: update
cost O(m·s) vs SVGP's O(m³)). Posterior samples: f(·) + K_(·)Z (v* − α*) (Eq. 3.36),
with f_X ≈ RFF prior (the Nyström-consistency approximation discussed in §3.2.3).

The prior is a :class:`~repro.core.operators.FeatureOperator` (``PriorSamples``,
default backend ``"auto"``): both the eager f_X target evaluation here and the
differentiated sample evaluations in ``InducingPosterior.__call__`` run through
the fused RFF matvec on TPU — with the custom VJP, gradient-based acquisition
over inducing posteriors needs no materialised features either.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram, matvec
from .operators import NormalEq  # noqa: F401 (re-export: NormalEq lives in operators)
from .rff import PriorSamples, sample_prior
from .solvers.base import SolveResult
from .solvers.spec import CG, SpecLike, as_spec, solve


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class InducingPosterior:
    params: KernelParams
    z: jax.Array  # (m, d) inducing inputs
    prior: PriorSamples
    v_mean: jax.Array  # (m,)
    alpha: jax.Array  # (m, s)

    def mean(self, xs: jax.Array) -> jax.Array:
        return gram(self.params, xs, self.z) @ self.v_mean

    def __call__(self, xs: jax.Array) -> jax.Array:
        kxz = gram(self.params, xs, self.z)
        return self.prior(xs) + kxz @ (self.v_mean[:, None] - self.alpha)


def inducing_posterior(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    z: jax.Array,
    key: jax.Array,
    *,
    num_samples: int = 16,
    num_features: int = 2048,
    spec: Optional[SpecLike] = None,
    max_iters: int = 200,
    tol: float = 1e-5,
    row_chunk: int = 4096,
) -> InducingPosterior:
    """Optimal inducing posterior via ``solve()`` on the normal-equations operator.

    ``spec`` must be a matvec-only (CG-family) spec; when omitted it defaults to
    ``CG(max_iters=max_iters, tol=tol)`` (no preconditioning — the operator is not
    a Gram matrix). The tight default ``tol`` matters: the normal-equations
    operator is ill-conditioned (κ(K_XZ)²-ish), so a loose per-column tolerance
    stops refinement long before the *prediction-space* error is small — spend the
    whole ``max_iters`` budget instead.
    """
    s = as_spec(CG(max_iters=max_iters, tol=tol) if spec is None else spec)
    kp, ke = jax.random.split(key)
    prior = sample_prior(params, kp, num_samples, num_features, x.shape[1])
    f_x = prior(x)
    eps = jnp.sqrt(params.noise) * jax.random.normal(ke, f_x.shape, f_x.dtype)
    targets = jnp.concatenate([y[:, None], f_x + eps], axis=1)  # (n, 1+s)
    rhs = matvec(params, z, targets, z=x, row_chunk=row_chunk)  # K_ZX b: (m, 1+s)
    op = NormalEq(x=x, z=z, params=params, row_chunk=row_chunk)
    res: SolveResult = solve(op, rhs, s, key=key)
    sol = res.solution
    return InducingPosterior(
        params=params, z=z, prior=prior, v_mean=sol[:, 0], alpha=sol[:, 1:]
    )


def select_inducing_greedy(x: jax.Array, m: int, key: jax.Array) -> jax.Array:
    """Cheap inducing-point selection: random subset (§3.3.1 uses ANN dedup; a
    uniform subset is the paper's stated-adequate fallback for large m)."""
    idx = jax.random.choice(key, x.shape[0], (m,), replace=False)
    return x[idx]
