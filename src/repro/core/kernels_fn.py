"""Covariance functions (dissertation §2.1.3).

All kernels are expressed through a small dataclass carrying hyperparameters in
*unconstrained* (log) space so that MLL optimisation (core/mll.py) can take plain
gradients. Pairwise Gram blocks are computed with the distance-as-matmul identity
``||x - x'||^2 = ||x||^2 + ||x'||^2 - 2 x.x'`` so the dominant cost is a matmul
(MXU-shaped on TPU; the Pallas kernel in kernels/gram_matvec.py fuses this with the
elementwise map and the matvec).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

SE = "se"
MATERN12 = "matern12"
MATERN32 = "matern32"
MATERN52 = "matern52"
TANIMOTO = "tanimoto"

_STATIONARY = (SE, MATERN12, MATERN32, MATERN52)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Unconstrained GP hyperparameters θ = {log lengthscales, log signal, log noise}."""

    log_lengthscale: jax.Array  # (d,) ARD or scalar ()
    log_signal: jax.Array  # ()
    log_noise: jax.Array  # ()
    kind: str = dataclasses.field(default=SE, metadata=dict(static=True))

    @property
    def lengthscale(self) -> jax.Array:
        return jnp.exp(self.log_lengthscale)

    @property
    def signal(self) -> jax.Array:  # signal *variance*
        return jnp.exp(2.0 * self.log_signal)

    @property
    def noise(self) -> jax.Array:  # noise variance σ²
        return jnp.exp(2.0 * self.log_noise)


def make_params(
    kind: str = SE,
    lengthscale=1.0,
    signal: float = 1.0,
    noise: float = 0.1,
    d: Optional[int] = None,
    dtype=jnp.float32,
) -> KernelParams:
    ls = jnp.asarray(lengthscale, dtype)
    if d is not None and ls.ndim == 0:
        ls = jnp.full((d,), ls, dtype)
    return KernelParams(
        log_lengthscale=jnp.log(ls),
        log_signal=jnp.log(jnp.asarray(signal, dtype)),
        log_noise=jnp.log(jnp.asarray(noise, dtype)),
        kind=kind,
    )


def _sqdist(x: jax.Array, z: jax.Array) -> jax.Array:
    """Squared Euclidean distances via the matmul identity; clamped at 0."""
    xn = jnp.sum(x * x, axis=-1)[:, None]
    zn = jnp.sum(z * z, axis=-1)[None, :]
    d2 = xn + zn - 2.0 * (x @ z.T)
    return jnp.maximum(d2, 0.0)


def _stationary_map(d2: jax.Array, kind: str) -> jax.Array:
    """Elementwise covariance map applied to squared distances (lengthscale=1)."""
    if kind == SE:
        return jnp.exp(-0.5 * d2)
    r = jnp.sqrt(d2 + 1e-36)
    if kind == MATERN12:
        return jnp.exp(-r)
    if kind == MATERN32:
        s = jnp.sqrt(3.0) * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == MATERN52:
        s = jnp.sqrt(5.0) * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(f"unknown stationary kernel {kind!r}")


#: Stage the covariance map through ``lax.map`` row chunks once the d² block has
#: this many elements — below it the loop overhead outweighs the win.
_STAGED_MAP_MIN_ELEMENTS = 2 ** 18

#: Target elements per staged row chunk (~0.5 MB of fp32 — L2-resident).
_STAGED_MAP_CHUNK_ELEMENTS = 2 ** 17


def _stationary_apply(d2: jax.Array, kind: str) -> jax.Array:
    """``_stationary_map`` with large blocks staged through ``jax.lax.map``.

    On CPU, XLA emits *scalar* libm calls (~11 ns/element) for transcendentals
    that sit inside a large broadcast fusion — exactly what the exp in every
    Matérn/SE map becomes when fused with the distance matmul. Forcing the map
    to run as a ``lax.map`` over row chunks of the materialised d² array makes
    XLA emit the vectorised form (~2 ns/element), a 3–4× speedup on the panel
    shapes the stochastic solvers build every step. The restructure is purely
    elementwise — same ops on the same values — so results are bit-exact, and
    ``lax.map`` is differentiable, so gradients are unaffected. On TPU the
    fusion is fine; large blocks pass straight through.
    """
    n, m = d2.shape
    if jax.default_backend() == "tpu" or n * m < _STAGED_MAP_MIN_ELEMENTS:
        return _stationary_map(d2, kind)
    rows = max(1, min(n, _STAGED_MAP_CHUNK_ELEMENTS // max(m, 1)))
    pad = (-n) % rows
    d2p = jnp.pad(d2, ((0, pad), (0, 0)))
    chunks = d2p.reshape(-1, rows, m)
    out = jax.lax.map(partial(_stationary_map, kind=kind), chunks)
    return out.reshape(-1, m)[:n]


def gram(params: KernelParams, x: jax.Array, z: Optional[jax.Array] = None) -> jax.Array:
    """Dense Gram matrix K(x, z) — the reference path (O(n m) memory)."""
    z = x if z is None else z
    if params.kind == TANIMOTO:
        # Tanimoto/Jaccard over non-negative count vectors (Ch. 4 molecules):
        # T(x,z) = <min(x,z)> / <max(x,z)> = (via counts) s / (|x|+|z| - s), s = Σ min.
        # For binary/count fingerprints with x,z >= 0: Σ min(x_i,z_i) has no matmul
        # form in general; use the standard inner-product form valid for binary data.
        inner = x @ z.T
        xn = jnp.sum(x * x, axis=-1)[:, None]
        zn = jnp.sum(z * z, axis=-1)[None, :]
        denom = xn + zn - inner
        return params.signal * inner / jnp.maximum(denom, 1e-12)
    ls = params.lengthscale
    d2 = _sqdist(x / ls, z / ls)
    return params.signal * _stationary_apply(d2, params.kind)


def gram_diag(params: KernelParams, x: jax.Array) -> jax.Array:
    if params.kind == TANIMOTO:
        return params.signal * jnp.ones(x.shape[0], x.dtype)
    return params.signal * jnp.ones(x.shape[0], x.dtype)


def matvec(
    params: KernelParams,
    x: jax.Array,
    v: jax.Array,
    z: Optional[jax.Array] = None,
    row_chunk: int = 4096,
    jitter: Optional[jax.Array] = None,
) -> jax.Array:
    """(K(x,z) + jitter·I) @ v computed in row chunks — O(chunk·m) memory, never
    materialising K. This is the pure-JAX analogue of kernels/gram_matvec.py (which is
    the TPU Pallas version); both satisfy the same ref.py oracle.

    v may be (m,) or (m, s) for batched right-hand sides.
    """
    z_ = x if z is None else z
    n = x.shape[0]
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    row_chunk = min(row_chunk, n)  # never pad small operands up to the chunk size
    pad = (-n) % row_chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    rows = xp.reshape(n // row_chunk + (pad > 0), row_chunk, x.shape[1])

    def chunk(xc):
        return gram(params, xc, z_) @ v2

    out = jax.lax.map(chunk, rows).reshape(-1, v2.shape[1])[:n]
    if jitter is not None and z is None:
        out = out + jitter * v2
    return out[:, 0] if squeeze else out


def spectral_sample(params: KernelParams, key: jax.Array, m: int, d: int) -> jax.Array:
    """Sample m frequencies ω ~ spectral density of the kernel (§2.2.2).

    SE ↔ N(0, I/ℓ²); Matérn-ν ↔ multivariate Student-t with 2ν dof (scaled by 1/ℓ).
    """
    kind = params.kind
    knorm = jax.random.normal(key, (m, d))
    if kind == SE:
        w = knorm
    elif kind in (MATERN12, MATERN32, MATERN52):
        nu = {MATERN12: 0.5, MATERN32: 1.5, MATERN52: 2.5}[kind]
        kg = jax.random.fold_in(key, 1)
        # t_{2ν} = N(0,1) / sqrt(Gamma(ν, rate=ν))  (chi2_{2ν}/(2ν) = Gamma(ν, rate ν))
        g = jax.random.gamma(kg, nu, (m, 1)) / nu
        w = knorm / jnp.sqrt(g)
    else:
        raise ValueError(f"no spectral density for kernel {kind!r}")
    return w / params.lengthscale


# ---------------------------------------------------------------------------
# Product kernels over Cartesian grids (Ch. 6 latent Kronecker structure).


def kronecker_grams(
    params_list: list[KernelParams], grids: list[jax.Array]
) -> list[jax.Array]:
    """Per-factor Gram matrices K_j = k_j(X_j, X_j) of a product kernel (Eq. 2.68)."""
    return [gram(p, g) for p, g in zip(params_list, grids)]
