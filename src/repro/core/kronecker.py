"""Latent Kronecker structure (Chapter 6, LKGP).

Product-kernel GPs on a Cartesian grid X = X₁ × X₂ give K = K₁ ⊗ K₂ (Eq. 2.68) whose
eigendecomposition factorises — but ONLY for fully-gridded data. LKGP lifts that: with
observations on an arbitrary subset (mask M) of the grid, the observed covariance is
the *projection of a latent Kronecker product*

    K_obs = P_M (K₁ ⊗ K₂) P_Mᵀ            (§6.2.2)

which destroys factorised decompositions but PRESERVES fast matvecs:

    (K_obs + σ²I) v = P_M vec(K₁ V K₂ᵀ) + σ² v,   V = unvec(P_Mᵀ v)

costing O(n₁n₂(n₁+n₂)) instead of O(n_obs²). The operator enters the solver layer
as :class:`~repro.core.operators.LatentKroneckerOp` — ``lkgp_posterior`` routes its
batched system through the unified ``solve()`` entry point, so any CG-family
SolverSpec (preconditioning aside), warm starts, backend pinning and matvec
accounting apply to the structured matvec unchanged. Pathwise conditioning then
gives posterior samples: prior samples on the full grid are cheap via the
Kronecker Cholesky (L₁ ⊗ L₂) w (Eq. 2.73, §6.2.4) — no RFF needed.

Break-even (§6.2.6): LKGP matvec beats the direct O(n_obs²) = (ρ n₁n₂)² matvec when
the observed density ρ = n_obs/(n₁n₂) exceeds ρ* = sqrt((n₁+n₂)/(n₁n₂)); below that,
iterating over observed entries directly is cheaper. `break_even_density` returns ρ*
and benchmarks/bench_kronecker.py verifies it against measured FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram
from .operators import LatentKroneckerOp
from .solvers.base import SolveResult
from .solvers.spec import CG, SpecLike, as_spec, solve


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LatentKroneckerGP:
    """Two-factor LKGP over grid (g1 × g2) with boolean observation mask."""

    params1: KernelParams
    params2: KernelParams
    grid1: jax.Array  # (n1, d1)
    grid2: jax.Array  # (n2, d2)
    obs_idx: jax.Array  # (n_obs,) flat indices into the n1*n2 grid — the mask M
    noise: jax.Array  # σ²

    @property
    def shape(self) -> tuple[int, int]:
        return self.grid1.shape[0], self.grid2.shape[0]

    def k1(self) -> jax.Array:
        return gram(self.params1, self.grid1)

    def k2(self) -> jax.Array:
        return gram(self.params2, self.grid2)

    def project_up(self, v_obs: jax.Array) -> jax.Array:
        """P_Mᵀ v: scatter observed vector(s) into the full grid. v:(n_obs,s)→(n1,n2,s)."""
        n1, n2 = self.shape
        s = v_obs.shape[1]
        full = jnp.zeros((n1 * n2, s), v_obs.dtype)
        return full.at[self.obs_idx].set(v_obs).reshape(n1, n2, s)

    def project_down(self, v_full: jax.Array) -> jax.Array:
        """P_M v: gather observed entries. (n1,n2,s)→(n_obs,s)."""
        return v_full.reshape(-1, v_full.shape[-1])[self.obs_idx]

    def mv(self, v_obs: jax.Array) -> jax.Array:
        """(K_obs + σ²I) @ v via the latent Kronecker matvec (§6.2.3)."""
        squeeze = v_obs.ndim == 1
        v2 = v_obs[:, None] if squeeze else v_obs
        full = self.project_up(v2)  # (n1, n2, s)
        out = jnp.einsum("ab,bcs->acs", self.k1(), jnp.einsum("cd,bds->bcs", self.k2(), full))
        out = self.project_down(out) + self.noise * v2
        return out[:, 0] if squeeze else out

    # -- prior sampling on the full grid via Kronecker Cholesky (Eq. 2.73) --------
    def prior_sample_grid(self, key: jax.Array, num_samples: int) -> jax.Array:
        n1, n2 = self.shape
        # jitter ∝ signal: fp32 grams of close points round slightly indefinite
        l1 = jnp.linalg.cholesky(self.k1() + 1e-5 * self.params1.signal * jnp.eye(n1))
        l2 = jnp.linalg.cholesky(self.k2() + 1e-5 * self.params2.signal * jnp.eye(n2))
        w = jax.random.normal(key, (n1, n2, num_samples))
        return jnp.einsum("ab,bcs->acs", l1, jnp.einsum("cd,bds->bcs", l2, w))

    def cross_mv(self, weights_obs: jax.Array) -> jax.Array:
        """K_{grid,obs} @ w → full-grid predictions. (n_obs,s) → (n1,n2,s)."""
        squeeze = weights_obs.ndim == 1
        w2 = weights_obs[:, None] if squeeze else weights_obs
        full = self.project_up(w2)
        out = jnp.einsum("ab,bcs->acs", self.k1(), jnp.einsum("cd,bds->bcs", self.k2(), full))
        return out[..., 0] if squeeze else out


def lkgp_posterior(
    gp: LatentKroneckerGP,
    y_obs: jax.Array,
    key: jax.Array,
    *,
    num_samples: int = 8,
    max_iters: Optional[int] = None,
    spec: Optional[SpecLike] = None,
) -> tuple[jax.Array, jax.Array]:
    """Pathwise posterior on the FULL grid (§6.2.4), solver-spec driven.

    Returns (mean (n1,n2), samples (n1,n2,s)). One batched ``solve()`` on the
    :class:`~repro.core.operators.LatentKroneckerOp` for [y | f_obs + ε], then
    f_full + K_{grid,obs}(v − α). ``spec`` must be a matvec-only (CG-family)
    spec — the structured operator has no row-block capabilities — and defaults
    to ``CG(max_iters=500, tol=1e-4)``. An explicit ``max_iters`` overrides the
    spec's budget in both cases (a spec without that field raises).
    """
    if spec is None:
        s = CG(max_iters=500 if max_iters is None else max_iters, tol=1e-4)
    else:
        s = as_spec(spec) if max_iters is None else as_spec(spec, max_iters=max_iters)
    f_grid = gp.prior_sample_grid(key, num_samples)  # (n1, n2, s)
    f_obs = gp.project_down(f_grid)
    eps = jnp.sqrt(gp.noise) * jax.random.normal(
        jax.random.fold_in(key, 1), f_obs.shape, f_obs.dtype
    )
    rhs = jnp.concatenate([y_obs[:, None], f_obs + eps], axis=1)
    res: SolveResult = solve(LatentKroneckerOp(gp=gp), rhs, s, key=key)
    sol = res.solution
    v_mean, alpha = sol[:, :1], sol[:, 1:]
    mean = gp.cross_mv(v_mean)[..., 0]
    update = gp.cross_mv(v_mean - alpha)  # (n1, n2, s)
    samples = f_grid + update
    return mean, samples


def make_lkgp(
    params1: KernelParams,
    params2: KernelParams,
    grid1: jax.Array,
    grid2: jax.Array,
    mask: jax.Array,
    noise,
) -> LatentKroneckerGP:
    """Build an LKGP from a boolean (n1, n2) observation mask (eager nonzero)."""
    import numpy as np

    idx = jnp.asarray(np.nonzero(np.asarray(mask).reshape(-1))[0])
    return LatentKroneckerGP(
        params1=params1,
        params2=params2,
        grid1=grid1,
        grid2=grid2,
        obs_idx=idx,
        noise=jnp.asarray(noise),
    )


def break_even_density(n1: int, n2: int) -> float:
    """ρ* above which the latent Kronecker matvec is cheaper than the direct
    O(n_obs²) matvec (§6.2.6): (ρ n₁n₂)² = n₁n₂(n₁+n₂) ⇒ ρ* = sqrt((n₁+n₂)/(n₁n₂))."""
    return float(jnp.sqrt((n1 + n2) / (n1 * n2)))


def lkgp_matvec_flops(n1: int, n2: int, density: float) -> tuple[float, float]:
    """(latent-kronecker flops, direct flops) per matvec — used by bench_kronecker."""
    lk = 2.0 * n1 * n2 * (n1 + n2)
    n_obs = density * n1 * n2
    direct = 2.0 * n_obs * n_obs
    return lk, direct
