"""Latent Kronecker structure (Chapter 6, LKGP).

Product-kernel GPs on a Cartesian grid X = X₁ × X₂ give K = K₁ ⊗ K₂ (Eq. 2.68) whose
eigendecomposition factorises — but ONLY for fully-gridded data. LKGP lifts that: with
observations on an arbitrary subset (mask M) of the grid, the observed covariance is
the *projection of a latent Kronecker product*

    K_obs = P_M (K₁ ⊗ K₂) P_Mᵀ            (§6.2.2)

which destroys factorised decompositions but PRESERVES fast matvecs:

    (K_obs + σ²I) v = P_M vec(K₁ V K₂ᵀ) + σ² v,   V = unvec(P_Mᵀ v)

costing O(n₁n₂(n₁+n₂)) instead of O(n_obs²). Iterative solvers (any of core/solvers)
plus pathwise conditioning then give posterior samples: prior samples on the full grid
are cheap via the Kronecker Cholesky (L₁ ⊗ L₂) w (Eq. 2.73, §6.2.4) — no RFF needed.

Break-even (§6.2.6): LKGP matvec beats the direct O(n_obs²) = (ρ n₁n₂)² matvec when
the observed density ρ = n_obs/(n₁n₂) exceeds ρ* = sqrt((n₁+n₂)/(n₁n₂)); below that,
iterating over observed entries directly is cheaper. `break_even_density` returns ρ*
and benchmarks/bench_kronecker.py verifies it against measured FLOPs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LatentKroneckerGP:
    """Two-factor LKGP over grid (g1 × g2) with boolean observation mask."""

    params1: KernelParams
    params2: KernelParams
    grid1: jax.Array  # (n1, d1)
    grid2: jax.Array  # (n2, d2)
    obs_idx: jax.Array  # (n_obs,) flat indices into the n1*n2 grid — the mask M
    noise: jax.Array  # σ²

    @property
    def shape(self) -> tuple[int, int]:
        return self.grid1.shape[0], self.grid2.shape[0]

    def k1(self) -> jax.Array:
        return gram(self.params1, self.grid1)

    def k2(self) -> jax.Array:
        return gram(self.params2, self.grid2)

    def project_up(self, v_obs: jax.Array) -> jax.Array:
        """P_Mᵀ v: scatter observed vector(s) into the full grid. v:(n_obs,s)→(n1,n2,s)."""
        n1, n2 = self.shape
        s = v_obs.shape[1]
        full = jnp.zeros((n1 * n2, s), v_obs.dtype)
        return full.at[self.obs_idx].set(v_obs).reshape(n1, n2, s)

    def project_down(self, v_full: jax.Array) -> jax.Array:
        """P_M v: gather observed entries. (n1,n2,s)→(n_obs,s)."""
        return v_full.reshape(-1, v_full.shape[-1])[self.obs_idx]

    def mv(self, v_obs: jax.Array) -> jax.Array:
        """(K_obs + σ²I) @ v via the latent Kronecker matvec (§6.2.3)."""
        squeeze = v_obs.ndim == 1
        v2 = v_obs[:, None] if squeeze else v_obs
        full = self.project_up(v2)  # (n1, n2, s)
        out = jnp.einsum("ab,bcs->acs", self.k1(), jnp.einsum("cd,bds->bcs", self.k2(), full))
        out = self.project_down(out) + self.noise * v2
        return out[:, 0] if squeeze else out

    # -- prior sampling on the full grid via Kronecker Cholesky (Eq. 2.73) --------
    def prior_sample_grid(self, key: jax.Array, num_samples: int) -> jax.Array:
        n1, n2 = self.shape
        # jitter ∝ signal: fp32 grams of close points round slightly indefinite
        l1 = jnp.linalg.cholesky(self.k1() + 1e-5 * self.params1.signal * jnp.eye(n1))
        l2 = jnp.linalg.cholesky(self.k2() + 1e-5 * self.params2.signal * jnp.eye(n2))
        w = jax.random.normal(key, (n1, n2, num_samples))
        return jnp.einsum("ab,bcs->acs", l1, jnp.einsum("cd,bds->bcs", l2, w))

    def cross_mv(self, weights_obs: jax.Array) -> jax.Array:
        """K_{grid,obs} @ w → full-grid predictions. (n_obs,s) → (n1,n2,s)."""
        squeeze = weights_obs.ndim == 1
        w2 = weights_obs[:, None] if squeeze else weights_obs
        full = self.project_up(w2)
        out = jnp.einsum("ab,bcs->acs", self.k1(), jnp.einsum("cd,bds->bcs", self.k2(), full))
        return out[..., 0] if squeeze else out


@partial(jax.jit, static_argnames=("max_iters",))
def lkgp_solve_cg(
    gp: LatentKroneckerGP, b: jax.Array, max_iters: int = 500, tol: float = 1e-4
) -> jax.Array:
    """CG on the LKGP operator (same recursion as solvers/cg but structured matvec)."""
    b2 = b[:, None] if b.ndim == 1 else b
    v = jnp.zeros_like(b2)
    r = b2 - gp.mv(v)
    p = r
    rz = jnp.sum(r * r, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(b2, axis=0), 1e-30)

    def cond(s):
        _, r, _, t, _ = s
        return jnp.logical_and(t < max_iters, jnp.any(jnp.linalg.norm(r, axis=0) / bn > tol))

    def body(s):
        v, r, p, t, rz = s
        ap = gp.mv(p)
        a = rz / jnp.maximum(jnp.sum(p * ap, axis=0), 1e-30)
        v = v + a[None] * p
        r = r - a[None] * ap
        rz2 = jnp.sum(r * r, axis=0)
        p = r + (rz2 / jnp.maximum(rz, 1e-30))[None] * p
        return v, r, p, t + 1, rz2

    v, *_ = jax.lax.while_loop(cond, body, (v, r, p, 0, rz))
    return v[:, 0] if b.ndim == 1 else v


def lkgp_posterior(
    gp: LatentKroneckerGP,
    y_obs: jax.Array,
    key: jax.Array,
    *,
    num_samples: int = 8,
    max_iters: int = 500,
) -> tuple[jax.Array, jax.Array]:
    """Pathwise posterior on the FULL grid (§6.2.4).

    Returns (mean (n1,n2), samples (n1,n2,s)). One batched solve for
    [y | f_obs + ε], then f_full + K_{grid,obs}(v − α).
    """
    f_grid = gp.prior_sample_grid(key, num_samples)  # (n1, n2, s)
    f_obs = gp.project_down(f_grid)
    eps = jnp.sqrt(gp.noise) * jax.random.normal(
        jax.random.fold_in(key, 1), f_obs.shape, f_obs.dtype
    )
    rhs = jnp.concatenate([y_obs[:, None], f_obs + eps], axis=1)
    sol = lkgp_solve_cg(gp, rhs, max_iters=max_iters)
    v_mean, alpha = sol[:, :1], sol[:, 1:]
    mean = gp.cross_mv(v_mean)[..., 0]
    update = gp.cross_mv(v_mean - alpha)  # (n1, n2, s)
    samples = f_grid + update
    return mean, samples


def make_lkgp(
    params1: KernelParams,
    params2: KernelParams,
    grid1: jax.Array,
    grid2: jax.Array,
    mask: jax.Array,
    noise,
) -> LatentKroneckerGP:
    """Build an LKGP from a boolean (n1, n2) observation mask (eager nonzero)."""
    import numpy as np

    idx = jnp.asarray(np.nonzero(np.asarray(mask).reshape(-1))[0])
    return LatentKroneckerGP(
        params1=params1,
        params2=params2,
        grid1=grid1,
        grid2=grid2,
        obs_idx=idx,
        noise=jnp.asarray(noise),
    )


def break_even_density(n1: int, n2: int) -> float:
    """ρ* above which the latent Kronecker matvec is cheaper than the direct
    O(n_obs²) matvec (§6.2.6): (ρ n₁n₂)² = n₁n₂(n₁+n₂) ⇒ ρ* = sqrt((n₁+n₂)/(n₁n₂))."""
    return float(jnp.sqrt((n1 + n2) / (n1 * n2)))


def lkgp_matvec_flops(n1: int, n2: int, density: float) -> tuple[float, float]:
    """(latent-kronecker flops, direct flops) per matvec — used by bench_kronecker."""
    lk = 2.0 * n1 * n2 * (n1 + n2)
    n_obs = density * n1 * n2
    direct = 2.0 * n_obs * n_obs
    return lk, direct
