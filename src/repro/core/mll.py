"""Marginal likelihood optimisation with iterative solvers (Chapter 5).

The MLL gradient (Eq. 2.37) needs v_y = A⁻¹(y−μ) and tr(A⁻¹ ∂A/∂θ), A = K_θ + σ²I.

*Standard estimator* (Gardner et al. 2018, Wang et al. 2019): Hutchinson probes
z_j ~ N(0, I):   tr(A⁻¹ ∂A) ≈ mean_j (A⁻¹z_j)ᵀ ∂A z_j — requires solving A⁻¹z_j,
whose solutions are *useless for anything else*.

*Pathwise estimator* (§5.2, this paper): draw probes from the PRIOR of y,
z_j = f_X^j + ε_j ~ N(0, A). Then α_j = A⁻¹z_j has E[α_jα_jᵀ] = A⁻¹ so

    tr(A⁻¹ ∂A) ≈ mean_j α_jᵀ (∂A/∂θ) α_j,

and the α_j are **exactly the pathwise-conditioning weights** of posterior samples
(core/pathwise.py): the trace-estimation solves are amortised into posterior sampling
for free. Additionally the solutions α_j = A⁻¹z_j have smaller initial distance
‖0 − α*‖_A than the Hutchinson ones (§5.2.1: E‖α*‖²_A = n for z~N(0,A) vs
tr(A⁻¹)·cond-dependent for z~N(0,I)), so solvers need fewer iterations.

*Warm starting* (§5.3): across outer hyperparameter steps θ_t → θ_{t+1} the solutions
move little; initialising each solve at the previous solution cuts solver iterations
multiplicatively (up to 72× total speed-up in the paper), at the cost of a bias that
is provably benign for convex quadratics (§5.3.2) because the solver still converges
to the θ-dependent optimum.

Gradients of the quadratic forms w.r.t. θ are taken by autodiff through the
never-materialised kernel matvec (fused Pallas custom-VJP or chunked JAX,
depending on the solve's backend) with stop-gradient solutions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels.ops import gram_mv
from .kernels_fn import KernelParams
from .rff import sample_prior
from .solvers.base import Gram
from .solvers.spec import SpecLike, as_spec, solve


def _quad(
    params: KernelParams, x: jax.Array, u: jax.Array, w: jax.Array,
    backend: str = "auto",
) -> jax.Array:
    """uᵀ (K_θ + σ²I) w summed per column, differentiable in θ. u,w: (n,s).

    Runs through the same backend as the solve, so with ``backend="pallas"``
    both the quadratic form and its θ-gradient are fused Pallas contractions.
    """
    kw = gram_mv(params, x, w, backend=backend)  # (n, s)
    return jnp.sum(u * kw, axis=0) + params.noise * jnp.sum(u * w, axis=0)


class MLLGradEstimate(NamedTuple):
    grad: KernelParams  # gradient w.r.t. unconstrained hyperparameters
    v_y: jax.Array  # (n,) mean weights — reusable for prediction
    alpha: jax.Array  # (n, s) probe/sample weights — reusable for pathwise sampling
    solver_iterations: jax.Array


def mll_grad(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
    *,
    num_probes: int = 8,
    num_features: int = 1024,
    estimator: str = "pathwise",  # "pathwise" | "hutchinson"
    spec: Optional[SpecLike] = None,
    x0: Optional[jax.Array] = None,
    **spec_overrides,
) -> MLLGradEstimate:
    """Estimated ∇_θ log p(y|θ) (ascent direction). θ in log space (KernelParams).

    Any registered ``SolverSpec`` (instance/class/name) runs the inner solves;
    extra keyword arguments are spec-field overrides.
    """
    s = as_spec("cg" if spec is None else spec, **spec_overrides)
    backend = getattr(s, "backend", None) or "auto"
    op = Gram(x=x, params=params, backend=backend)
    n = x.shape[0]
    kp, ke, ks = jax.random.split(key, 3)

    if estimator == "pathwise":
        prior = sample_prior(params, kp, num_probes, num_features, x.shape[1])
        # backend="auto" default: fused RFF matvec on TPU, features elsewhere
        f_x = prior(x)
        eps = jnp.sqrt(params.noise) * jax.random.normal(ke, f_x.shape, f_x.dtype)
        probes = f_x + eps  # z ~ N(0, A) approx (RFF prior + exact noise)
    else:
        probes = jax.random.normal(ke, (n, num_probes), dtype=x.dtype)

    rhs = jnp.concatenate([y[:, None], probes], axis=1)
    res = solve(op, rhs, s, key=ks, x0=x0)
    sol = jax.lax.stop_gradient(res.solution)
    v_y, alpha = sol[:, 0], sol[:, 1:]

    def neg_terms(p: KernelParams) -> jax.Array:
        # data fit grad: +½ v_yᵀ ∂A v_y  ⇒ differentiate  ½ v_yᵀ A(θ) v_y
        fit = 0.5 * _quad(p, x, v_y[:, None], v_y[:, None], backend)[0]
        if estimator == "pathwise":
            # tr(A⁻¹∂A) ≈ mean_j α_jᵀ ∂A α_j  ⇒ differentiate ½ mean α A α
            tr = 0.5 * jnp.mean(_quad(p, x, alpha, alpha, backend))
        else:
            # tr(A⁻¹∂A) ≈ mean_j (A⁻¹z_j)ᵀ ∂A z_j ⇒ differentiate ½ mean α A z
            tr = 0.5 * jnp.mean(
                _quad(p, x, alpha, jax.lax.stop_gradient(probes), backend)
            )
        return fit - tr

    g = jax.grad(neg_terms)(params)
    return MLLGradEstimate(grad=g, v_y=v_y, alpha=alpha, solver_iterations=res.iterations)


@dataclasses.dataclass
class MLLOptimState:
    params: KernelParams
    adam_m: KernelParams
    adam_v: KernelParams
    warm: Optional[jax.Array]  # previous solutions (n, 1+s) for warm starting
    step: int
    total_solver_iters: int


def _tree_adam(params, g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
    v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
    t = step + 1
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, m_, v_: p + lr * m_ / (jnp.sqrt(v_) + eps), params, mhat, vhat
    )  # ASCENT on MLL
    return params, m, v


def optimize_mll(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
    *,
    num_steps: int = 20,
    lr: float = 0.05,
    warm_start: bool = True,
    estimator: str = "pathwise",
    num_probes: int = 8,
    spec: Optional[SpecLike] = None,
    callback: Optional[Callable[[int, MLLOptimState], None]] = None,
    **spec_overrides,
) -> MLLOptimState:
    """Outer loop: Adam ascent on θ with warm-started inner solves (Ch. 5)."""
    s = as_spec("cg" if spec is None else spec, **spec_overrides)
    zeros = jax.tree.map(jnp.zeros_like, params)
    st = MLLOptimState(params, zeros, zeros, None, 0, 0)
    for t in range(num_steps):
        # §5.3.3: warm starting the PATHWISE estimator requires the probe/prior
        # randomness to be held fixed across outer steps — the previous solution is
        # then a nearby init for the new θ's systems (fresh probes would re-randomise
        # the RHS and void the warm start). Bias is negligible (§5.3.2).
        est = mll_grad(
            st.params,
            x,
            y,
            key if warm_start else jax.random.fold_in(key, t),
            num_probes=num_probes,
            estimator=estimator,
            spec=s,
            x0=st.warm if warm_start else None,
        )
        p, m, v = _tree_adam(st.params, est.grad, st.adam_m, st.adam_v, t, lr)
        warm = jnp.concatenate([est.v_y[:, None], est.alpha], axis=1)
        st = MLLOptimState(
            p, m, v, warm, t + 1, st.total_solver_iters + int(est.solver_iterations)
        )
        if callback is not None:
            callback(t, st)
    return st
