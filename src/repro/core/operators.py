"""One operator abstraction from Gram to Kronecker to sharded: ``LinearOperator``
— and its feature-side twin, ``FeatureOperator``.

Every expensive GP computation in this library reduces to solving

    (K + σ²I) V = B

against a positive-definite coefficient matrix that is only ever *touched through
matvecs*.  This module makes "the matrix" a first-class protocol so the solver
layer (core/solvers) is operator-agnostic: dense-free Gram matvecs, inducing-point
normal equations, latent-Kronecker structure (Ch. 6) and mesh-sharded block-row
operators all flow through the same ``solve()`` entry point with the same
SolverSpec benefits (preconditioning, warm starts, matvec accounting, backend
pinning, JSON-drivable configs).

The protocol (see :class:`LinearOperator`):

required
    ``shape``        — ``(n, n)`` of the square system matrix A;
    ``mv(v)``        — ``A @ v`` for ``v`` of shape ``(n,)`` or ``(n, s)``;
    ``diag_part()``  — ``diag(A)`` (Jacobi preconditioning, diagnostics);
    ``noise``        — the σ² of the ``K + σ²I`` split (δ-channel folding).

optional capabilities (declared by *defining the method*; absence is detected by
``hasattr`` — the base class deliberately does not stub them out)
    ``rows_mv(idx, u)``    — ``K[idx, :] @ u`` (SGD/SDD data-fit primitive);
    ``rows_t_mv(idx, u)``  — ``K[idx, :]ᵀ @ u`` (SGD regulariser pullback, AP
                             residual update);
    ``rows_pair_mv(idx, look, b)`` — the fused pair step ``err = K[idx,:] @
                             look − b``, ``g = K[idx,:]ᵀ @ err`` with the panel
                             built once (SGD's fit gradient in one dispatch);
    ``block_at(idx)``      — ``K[idx, idx]`` principal block (AP's exact
                             sub-solve);
    ``precond_factor(rank, key=, method=)`` — an ``(n, m)`` low-rank factor L
                             with ``K ≈ L Lᵀ`` (Nyström / pivoted-Cholesky /
                             random-feature preconditioner construction).

Solver specs declare which capabilities they consume (``SolverSpec.needs``) and
``solve()`` verifies them up front — a spec requesting row blocks from a
matvec-only operator raises a :class:`TypeError` naming the missing capability
instead of an ``AttributeError`` deep inside a scan. Operators may additionally
define ``prepare_for_solve()`` — a per-solve setup hook ``solve()`` invokes once,
outside the solver's while_loop/scan (e.g. :class:`ShardedGram` gathers its
sharded inputs once instead of all-gathering per matvec).

Pathwise conditioning writes every posterior sample as ``f(·) + K(·)X w`` with
the prior ``f`` a *feature expansion* Φ(·)w (§2.2.2) — the feature side is the
dominant non-Gram cost at the paper's scales, and :class:`FeatureOperator` is its
protocol (required ``phi_mv``/``phi_t_mv``/``num_features``/``shape``; optional
``features``). ``FourierFeatures``/``PriorSamples`` (core/rff.py) implement it
over the fused differentiable RFF kernels, and :class:`RFFGram` closes the loop:
the feature surrogate ΦΦᵀ + σ²I *as* a LinearOperator, solvable and usable as a
feature-space preconditioner. See docs/features.md.

All concrete operators are frozen, pytree-registered dataclasses: hyperparameters
and inputs are traced leaves (same treedef + shapes ⇒ compiled solves are
reused), while meshes, backends and chunk sizes are static fields.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..kernels.ops import gram_mv, gram_rows_matvec, gram_rows_pair
from .kernels_fn import KernelParams, gram, gram_diag, matvec

if TYPE_CHECKING:  # runtime imports would cycle: kronecker → solvers.spec → here,
    # and rff → here (for the FeatureOperator protocol base)
    from .kronecker import LatentKroneckerGP
    from .rff import FourierFeatures


# ---------------------------------------------------------------------------
# Capability dispatch
# ---------------------------------------------------------------------------

#: Capabilities beyond the required ``mv``/``shape``/``diag_part``/``noise``.
#: ``rows_pair_mv`` is the fused err/gradient pair step (one panel build for
#: both contractions); SGD uses it when present and composes ``rows_mv``/
#: ``rows_t_mv`` otherwise, so operators without it still run every spec.
OPTIONAL_CAPABILITIES = (
    "rows_mv", "rows_t_mv", "rows_pair_mv", "block_at", "precond_factor"
)

#: FeatureOperator capabilities beyond the required ``phi_mv``/``phi_t_mv``/
#: ``num_features``/``shape``: ``features`` materialises Φ(x) (reference path,
#: RFF preconditioner factors).
OPTIONAL_FEATURE_CAPABILITIES = ("features",)


def supports(op, *caps: str) -> bool:
    """True iff ``op`` provides every named capability (method or attribute)."""
    return all(callable(getattr(op, c, None)) or hasattr(op, c) for c in caps)


def capabilities(op, optional: tuple = OPTIONAL_CAPABILITIES) -> tuple:
    """The optional capabilities ``op`` provides (sorted, for error messages)."""
    return tuple(c for c in optional if supports(op, c))


def feature_capabilities(op) -> tuple:
    """The optional :class:`FeatureOperator` capabilities ``op`` provides."""
    return capabilities(op, OPTIONAL_FEATURE_CAPABILITIES)


def require_capabilities(op, caps, *, consumer: str) -> None:
    """Raise a clear ``TypeError`` if ``op`` lacks any of ``caps``.

    ``consumer`` names who is asking (a solver spec, a preconditioner build) so
    the error reads as a capability mismatch, not a missing attribute.
    """
    missing = tuple(c for c in caps if not supports(op, c))
    if missing:
        feature_side = all(c in OPTIONAL_FEATURE_CAPABILITIES for c in missing)
        have = capabilities(
            op, OPTIONAL_FEATURE_CAPABILITIES if feature_side else OPTIONAL_CAPABILITIES
        )
        hint = (
            "Fused feature operators need the 'features' capability only for "
            "materialised reference paths and RFF preconditioner factors."
            if feature_side
            else "Matvec-only operators support CG-family specs; SGD/SDD/AP "
            "need row-block access (rows_mv/rows_t_mv/block_at)."
        )
        raise TypeError(
            f"{consumer} needs operator capabilities {missing} that "
            f"{type(op).__name__} does not provide (optional capabilities it "
            f"has: {have or '()'}). {hint}"
        )


class LinearOperator:
    """Protocol base for the square operators ``solve()`` accepts.

    Subclasses are frozen ``@jax.tree_util.register_dataclass`` dataclasses.
    They must implement ``shape``, ``mv``, ``diag_part`` and ``noise``; the
    optional capabilities in :data:`OPTIONAL_CAPABILITIES` are declared simply
    by defining the method (absence is how ``solve()`` knows to refuse a spec
    that needs them). Duck-typed operators that never subclass this also work —
    the protocol is structural, the base class is documentation plus default
    errors.
    """

    @property
    def shape(self) -> tuple:
        raise NotImplementedError(f"{type(self).__name__} must define shape")

    @property
    def noise(self) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} must define noise")

    def mv(self, v: jax.Array) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} must define mv")

    def diag_part(self) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} must define diag_part")

    def dense(self) -> jax.Array:
        """Materialised A — O(n²); reference/tests only. Default: n matvecs."""
        n = self.shape[0]
        return self.mv(jnp.eye(n))


class FeatureOperator:
    """Protocol base for feature maps Φ: the rectangular twin of
    :class:`LinearOperator`.

    A feature operator is a map Φ(·) into ``num_features`` dimensions, touched
    only through its two contractions — never through a materialised feature
    matrix. Required surface:

    * ``num_features``   — the feature dimension F of Φ(x): (n, F);
    * ``shape``          — ``(None, F)``: the row count is input-dependent
                           (feature maps are evaluable anywhere, unlike the
                           square operators bound to training rows);
    * ``phi_mv(x, w)``   — Φ(x) @ w, the prior-sample evaluation primitive
                           (pathwise conditioning, Thompson ascent);
    * ``phi_t_mv(x, u)`` — Φ(x)ᵀ @ u, the SGD regulariser pullback (Eq. 3.3).

    Optional capability (absence detected by ``hasattr``, exactly like the
    LinearOperator capabilities): ``features(x)`` materialises Φ(x) — the
    reference path and the RFF preconditioner-factor build. Consumers verify
    with ``require_capabilities(op, ("features",), consumer=...)``.

    Both primitives must be differentiable w.r.t. ``x`` and the map's own
    parameters on every backend — the fused Pallas implementations carry custom
    VJPs (kernels/rff_matvec.py), so Thompson's Adam ascent and the SGD
    regulariser gradient run fused end to end.

    Implementations are frozen, pytree-registered dataclasses
    (``FourierFeatures``, ``PriorSamples`` — core/rff.py): same treedef + shapes
    ⇒ compiled consumers are reused across fresh feature draws.
    """

    @property
    def num_features(self) -> int:
        raise NotImplementedError(f"{type(self).__name__} must define num_features")

    @property
    def shape(self) -> tuple:
        return (None, self.num_features)

    def phi_mv(self, x: jax.Array, w: jax.Array) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} must define phi_mv")

    def phi_t_mv(self, x: jax.Array, u: jax.Array) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} must define phi_t_mv")

    def phi_pair_mv(self, x: jax.Array, u: jax.Array) -> jax.Array:
        """Φ(x) (Φ(x)ᵀ u) — the SGD regulariser composition (Eq. 3.3) as one
        primitive. Default: the two contractions in sequence; fused
        implementations override with a single dispatch whose (F, s)
        intermediate never leaves VMEM (``FourierFeatures``)."""
        return self.phi_mv(x, self.phi_t_mv(x, u))


# ---------------------------------------------------------------------------
# Runtime (post-compilation) matvec counters, bumped via jax.debug.callback from
# instrumented operators — unlike trace-time counts these reflect what the
# hardware actually executed, including every while_loop/scan iteration.
# ---------------------------------------------------------------------------

_RUNTIME_COUNTS = {"mv": 0, "rows": 0}


def reset_matvec_counts() -> None:
    for k in _RUNTIME_COUNTS:
        _RUNTIME_COUNTS[k] = 0


def matvec_counts() -> dict:
    """{"mv": full operator matvecs, "rows": row-block matvecs} executed by
    instrumented operators since the last reset."""
    return dict(_RUNTIME_COUNTS)


def _bump_mv(_):
    _RUNTIME_COUNTS["mv"] += 1


def _bump_rows(_):
    _RUNTIME_COUNTS["rows"] += 1


class _InstrumentedOp(LinearOperator):
    """Shared ``instrument=True`` plumbing (host-callback matvec counters)."""

    def _count(self, fn, out: jax.Array) -> None:
        if self.instrument:
            # operand-dependent so the callback stays inside loop bodies
            jax.debug.callback(fn, out.ravel()[0])


# ---------------------------------------------------------------------------
# Gram — the workhorse (K(X,X) + σ²I) operator
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Gram(_InstrumentedOp):
    """The linear operator A = K(X,X) + σ² I, touched only through matvecs.

    Implements the full capability set: fused row-block matvecs (``rows_mv``/
    ``rows_t_mv``/``block_at``) back the stochastic solvers, and
    ``precond_factor`` backs Nyström / pivoted-Cholesky preconditioner specs.

    ``backend`` selects the matvec implementation (see kernels/ops.py):
    ``"auto"`` (fused Pallas on TPU, chunked JAX elsewhere), ``"pallas"``,
    ``"chunked"``, or ``"dense"``. Solver specs can pin it per solve
    (``CG(backend="pallas")``), and likewise ``precision`` — ``"fp32"``
    (default) or ``"bf16"`` tile contractions with fp32 accumulation (see
    kernels/ops.py PRECISIONS). ``block`` is the Pallas tile size; the
    ``"auto"`` default resolves per shape at trace time (kernels/autotune.py).
    ``instrument=True`` counts executed matvecs via ``matvec_counts()``
    (tests/benchmarks; adds a host callback per matvec).
    """

    x: jax.Array  # (n, d) training inputs
    params: KernelParams
    row_chunk: int = dataclasses.field(default=2048, metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))
    block: "int | str" = dataclasses.field(default="auto", metadata=dict(static=True))
    precision: str = dataclasses.field(default="fp32", metadata=dict(static=True))
    instrument: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def shape(self) -> tuple:
        return (self.x.shape[0], self.x.shape[0])

    @property
    def noise(self) -> jax.Array:
        return self.params.noise

    def mv(self, v: jax.Array) -> jax.Array:
        """(K + σ²I) @ v without materialising K. v: (n,) or (n,s)."""
        out = gram_mv(
            self.params, self.x, v, jitter=self.noise, backend=self.backend,
            block=self.block, row_chunk=self.row_chunk, precision=self.precision,
        )
        self._count(_bump_mv, out)
        return out

    def mv_k(self, v: jax.Array) -> jax.Array:
        """K @ v (no jitter)."""
        out = gram_mv(
            self.params, self.x, v, backend=self.backend, block=self.block,
            row_chunk=self.row_chunk, precision=self.precision,
        )
        self._count(_bump_mv, out)
        return out

    def diag_part(self) -> jax.Array:
        """diag(K + σ²I) — (n,)."""
        return gram_diag(self.params, self.x) + self.noise

    def rows_mv(self, idx: jax.Array, u: jax.Array) -> jax.Array:
        """K[idx, :] @ u — fused row-block matvec, the panel never materialised.

        The SGD/SDD/AP data-fit primitive: O(|idx|·d) gathered inputs instead of
        an O(|idx|·n) HBM panel. u: (n,) or (n, s) → (|idx|, s-like).
        """
        out = gram_rows_matvec(
            self.params, self.x, idx, u, backend=self.backend, block=self.block,
            row_chunk=self.row_chunk, precision=self.precision,
        )
        self._count(_bump_rows, out)
        return out

    def rows_t_mv(self, idx: jax.Array, u: jax.Array) -> jax.Array:
        """K[idx, :]ᵀ @ u = K[:, idx] @ u — transposed fused row-block matvec.
        u: (|idx|,) or (|idx|, s) → (n, s-like)."""
        out = gram_rows_matvec(
            self.params, self.x, idx, u, transpose=True, backend=self.backend,
            block=self.block, row_chunk=self.row_chunk, precision=self.precision,
        )
        self._count(_bump_rows, out)
        return out

    def rows_pair_mv(self, idx: jax.Array, look: jax.Array, b: jax.Array) -> tuple:
        """The fused pair step: ``err = K[idx,:] @ look − b`` and
        ``g = K[idx,:]ᵀ @ err`` with the kernel panel built ONCE.

        SGD's fit gradient in a single dispatch — the unfused ``rows_mv`` +
        ``rows_t_mv`` composition rebuilds the same |idx|×n panel twice per
        step. Counts as two row-block matvecs (the work it replaces), keeping
        ``matvec_counts()`` comparable across the fused and unfused paths.
        look: (n, s); b: (|idx|, s) → ((|idx|, s), (n, s)).
        """
        err, g = gram_rows_pair(
            self.params, self.x, idx, look, b, backend=self.backend,
            block=self.block, precision=self.precision,
        )
        self._count(_bump_rows, err)
        self._count(_bump_rows, g)
        return err, g

    def block_at(self, idx: jax.Array) -> jax.Array:
        """K[idx, idx] — the |idx|×|idx| principal block (AP's exact sub-solve)."""
        return gram(self.params, self.x[idx], self.x[idx])

    def rows(self, idx: jax.Array) -> jax.Array:
        """K[idx, :] materialised — O(|idx|·n) memory. Legacy primitive; solvers
        use the fused ``rows_mv``/``rows_t_mv``/``block_at`` instead."""
        return gram(self.params, self.x[idx], self.x)

    def precond_factor(
        self, rank: int, key: Optional[jax.Array] = None, method: str = "nystrom"
    ) -> jax.Array:
        """(n, rank) factor L with K ≈ L Lᵀ for Woodbury preconditioning."""
        from .precond import low_rank_factor  # deferred: precond imports operators

        return low_rank_factor(self.params, self.x, rank, key=key, method=method)

    def dense(self) -> jax.Array:
        """Materialised K + σ²I (tests / small-n reference only)."""
        return gram(self.params, self.x) + self.noise * jnp.eye(self.n, dtype=self.x.dtype)


# ---------------------------------------------------------------------------
# RFFGram — the feature-space surrogate ΦΦᵀ + σ²I as a LinearOperator
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RFFGram(_InstrumentedOp):
    """The operator A = Φ(X) Φ(X)ᵀ + σ² I — the random-feature surrogate of the
    Gram operator (ΦΦᵀ is an unbiased K estimate, §2.2.2), touched only through
    two fused feature matvecs per ``mv``.

    Bridges the two protocols: any :class:`FeatureOperator` (a ``FourierFeatures``
    draw) becomes a solvable :class:`LinearOperator` — ``solve(RFFGram(...), b,
    spec)`` runs any CG-family spec with O(n·(d+s)) memory per matvec on the
    Pallas backend — and its ``precond_factor`` exposes the materialised Φ as an
    exact low-rank factor (A = LLᵀ + σ²I with L = Φ), making it a feature-space
    preconditioner / surrogate for full Gram solves (the ``"rff"`` precond spec).
    """

    x: jax.Array  # (n, d) training inputs
    ff: "FourierFeatures"  # the feature map (a FeatureOperator)
    sigma2: jax.Array  # () noise variance σ²
    # feature-matvec backend/precision overrides; None inherits the ff's own.
    # A spec's ``backend``/``precision`` fields pin them through solve(), like
    # Gram/ShardedGram.
    backend: Optional[str] = dataclasses.field(default=None, metadata=dict(static=True))
    precision: Optional[str] = dataclasses.field(default=None, metadata=dict(static=True))
    instrument: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def shape(self) -> tuple:
        return (self.x.shape[0], self.x.shape[0])

    @property
    def noise(self) -> jax.Array:
        return self.sigma2

    def mv(self, v: jax.Array) -> jax.Array:
        """(ΦΦᵀ + σ²I) @ v = Φ(Φᵀv) + σ²v — two fused feature matvecs."""
        bk, pr = self.backend, self.precision
        out = self.ff.phi_mv(
            self.x, self.ff.phi_t_mv(self.x, v, backend=bk, precision=pr),
            backend=bk, precision=pr,
        ) + self.sigma2 * v
        self._count(_bump_mv, out)
        return out

    def diag_part(self) -> jax.Array:
        """diag(ΦΦᵀ) + σ². Paired sin/cos features satisfy Σ_j Φ_ij² = σ_f²
        exactly (sin² + cos² = 1 per frequency); the cos-only variant needs the
        materialised rows."""
        if self.ff.paired:
            diag = jnp.broadcast_to(self.ff.signal, (self.n,))
        else:
            diag = jnp.sum(self.ff.features(self.x) ** 2, axis=1)
        return diag + self.sigma2

    def precond_factor(
        self, rank: int, key: Optional[jax.Array] = None, method: str = "rff"
    ) -> jax.Array:
        """The materialised feature matrix Φ — an *exact* factor (A = ΦΦᵀ + σ²I,
        no approximation), so Woodbury preconditioning of this operator is an
        exact inverse. Only ``method="rff"`` is meaningful here (the ``RFF``
        precond spec): a Nyström/pivoted-Cholesky request would silently get a
        factor of the operator's full feature count instead of the requested
        low-rank approximation, so it raises. ``rank``/``key`` are accepted for
        interface parity and ignored: the factor's rank is the operator's
        feature count.
        """
        if method != "rff":
            raise ValueError(
                f"RFFGram's only factor is its own feature matrix (method "
                f"'rff', {self.ff.num_features} columns); a {method!r} factor "
                f"of rank {rank} is not available — use CG(precond=RFF()) or "
                f"Jacobi() on this operator"
            )
        require_capabilities(
            self.ff, ("features",), consumer="RFFGram.precond_factor"
        )
        return self.ff.features(self.x)

    def dense(self) -> jax.Array:
        """Materialised ΦΦᵀ + σ²I (tests / small-n reference only)."""
        phi = self.ff.features(self.x)
        return phi @ phi.T + self.sigma2 * jnp.eye(self.n, dtype=self.x.dtype)


# ---------------------------------------------------------------------------
# NormalEq — inducing-point normal equations (§3.2.3), matvec-only
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NormalEq(LinearOperator):
    """The m×m operator K_ZX K_XZ + σ² K_ZZ, touched only through matvecs.

    A matvec-only operator (no kernel-row capabilities), so only CG-family specs
    can drive it through ``solve()`` — the stochastic solvers raise a capability
    error. Used by ``inducing_posterior`` (Eqs. 3.23/3.24) and the iterative
    SGPR path (``svgp.sgpr_iterative``): note (K_ZX K_XZ + σ²K_ZZ) = σ²·B with
    B the Titsias matrix K_ZZ + σ⁻²K_ZX K_XZ.

    ``ridge`` adds ridge·I to the operator (a traced leaf, so changing it does
    not retrace solves) — the iterative SGPR path uses it to reproduce the dense
    path's fp32-stabilising ridge exactly, since the two would otherwise
    converge to visibly different solutions in the κ(K_XZ)²-amplified
    directions.
    """

    x: jax.Array  # (n, d) training inputs
    z: jax.Array  # (m, d) inducing inputs
    params: KernelParams
    ridge: jax.Array = 0.0  # additive ridge·I (traced; 0 = the pure operator)
    row_chunk: int = dataclasses.field(default=4096, metadata=dict(static=True))

    @property
    def shape(self) -> tuple:
        return (self.z.shape[0], self.z.shape[0])

    @property
    def noise(self) -> jax.Array:
        return self.params.noise

    def mv(self, u: jax.Array) -> jax.Array:
        """(K_ZX K_XZ + σ² K_ZZ + ridge·I) @ u without materialising K_XZ (n×m)."""
        kxz_u = matvec(self.params, self.x, u, z=self.z, row_chunk=self.row_chunk)
        kzx_kxz_u = matvec(self.params, self.z, kxz_u, z=self.x, row_chunk=self.row_chunk)
        kzz_u = matvec(self.params, self.z, u, z=self.z, row_chunk=self.row_chunk)
        return kzx_kxz_u + self.params.noise * kzz_u + self.ridge * u

    def diag_part(self) -> jax.Array:
        """diag(K_ZX K_XZ) + σ²·diag(K_ZZ) + ridge, in row chunks of X."""
        n = self.x.shape[0]
        chunk = min(self.row_chunk, n)
        pad = (-n) % chunk
        xp = jnp.pad(self.x, ((0, pad), (0, 0)))
        rows = xp.reshape(-1, chunk, self.x.shape[1])

        def col_sq(xc):  # Σ_i k(x_i, z_j)² over the chunk (padded rows: see below)
            return jnp.sum(gram(self.params, xc, self.z) ** 2, axis=0)

        sq = jnp.sum(jax.lax.map(col_sq, rows), axis=0)
        if pad:  # padded (zero) rows contribute k(0, z_j)² — subtract them
            sq = sq - pad * gram(self.params, jnp.zeros((1, self.x.shape[1])), self.z)[0] ** 2
        return sq + self.params.noise * gram_diag(self.params, self.z) + self.ridge


# ---------------------------------------------------------------------------
# LatentKroneckerOp — Ch. 6 structured operator
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LatentKroneckerOp(_InstrumentedOp):
    """(P_M (K₁ ⊗ K₂) P_Mᵀ + σ²I) as a LinearOperator (§6.2.2–6.2.3).

    Wraps a :class:`~repro.core.kronecker.LatentKroneckerGP`: the matvec costs
    O(n₁n₂(n₁+n₂)) through the latent Kronecker identity instead of O(n_obs²),
    and the whole solver layer (CG warm starts, matvec accounting, spec configs)
    applies unchanged. Matvec-only: row gathers of the projected product kernel
    would each cost a full structured matvec, so SGD/SDD/AP specs are refused
    with a capability error.
    """

    gp: "LatentKroneckerGP"
    instrument: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def shape(self) -> tuple:
        n_obs = self.gp.obs_idx.shape[0]
        return (n_obs, n_obs)

    @property
    def noise(self) -> jax.Array:
        return self.gp.noise

    def mv(self, v: jax.Array) -> jax.Array:
        """(K_obs + σ²I) @ v via the latent Kronecker matvec (§6.2.3)."""
        out = self.gp.mv(v)
        self._count(_bump_mv, out)
        return out

    def diag_part(self) -> jax.Array:
        """diag(K_obs) + σ² = d₁[i₁]·d₂[i₂] at each observed grid index + σ²."""
        n1, n2 = self.gp.shape
        d1 = gram_diag(self.gp.params1, self.gp.grid1)
        d2 = gram_diag(self.gp.params2, self.gp.grid2)
        i1 = self.gp.obs_idx // n2
        i2 = self.gp.obs_idx % n2
        return d1[i1] * d2[i2] + self.gp.noise


# ---------------------------------------------------------------------------
# ShardedGram — mesh-aware block-row Gram operator
# ---------------------------------------------------------------------------

#: Communication strategies for :class:`ShardedGram` (docs/distributed.md).
#: ``gather`` all-gathers the sharded inputs (or vectors) around each matvec;
#: ``ring`` pipelines ``ppermute`` shard rotations against the per-shard fused
#: contraction so the O(n·d) replicated panel never exists and no per-matvec
#: ``all_gather`` is staged; ``auto`` picks ring once the replicated panel
#: would exceed the operator's per-device byte budget.
COMM_STRATEGIES = ("gather", "ring", "auto")


def _psum_row_gather(x_local, idx, axes):
    """``x_full[idx]`` without replicating x: every device contributes the
    ``idx`` rows that live in its shard (others zeroed) and a psum reduces.

    The collective moves O(|idx|·d) bytes instead of the O(n·d) ``all_gather``
    the gather strategy pays to index the global inputs. Assumes the canonical
    block-row layout (device i holds rows [i·n_local, (i+1)·n_local))."""
    i = jax.lax.axis_index(axes)
    n_local = x_local.shape[0]
    rel = idx - i * n_local
    mask = (rel >= 0) & (rel < n_local)
    safe = jnp.clip(rel, 0, n_local - 1)
    part = jnp.where(mask[:, None], x_local[safe], jnp.zeros((1, 1), x_local.dtype))
    return jax.lax.psum(part, axes)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedGram(_InstrumentedOp):
    """(K(X,X) + σ²I) with training rows sharded over mesh ``data_axes``.

    A block-row distribution of K: each device computes its K-block matvec
    without materialising the block — the local contraction runs through the
    same backend dispatch as :class:`Gram` (``pallas``/``chunked``/``dense``),
    so the fused Pallas kernel is threaded through the shards — and results are
    combined with mesh collectives.

    ``comm`` selects the collective schedule (see :data:`COMM_STRATEGIES` and
    docs/distributed.md):

    * ``"gather"`` (default) — every ``mv`` all-gathers the sharded inputs
      before contracting its block row; vectors (RHS batches, iterates) are
      replicated. The O(n·d) input panel transits the interconnect per matvec
      (or is cached by ``gather_once``), and communication strictly precedes
      compute.
    * ``"ring"`` — the collective-matmul idiom: ``(K+σ²I)v`` decomposes into P
      pipeline stages, each device contracting K(x_local, x_peer) @ v_peer
      against the shard pair it currently holds while ``jax.lax.ppermute``
      rotates the next (x_peer, v_peer) around the ring — the stage-t+1
      permute overlaps the stage-t contraction under XLA's latency-hiding
      scheduler, the replicated panel never exists, and no per-matvec
      ``all_gather`` is staged. Inputs AND vectors stay row-sharded end to
      end (``mv`` maps sharded → sharded), so solver iterates threaded through
      it keep per-device O(n·s/P) footprint; row gathers go through an
      O(|idx|·d) masked psum instead of replicating x.
    * ``"auto"`` — ring once the replicated (n, d) panel exceeds
      ``comm_budget_bytes`` per device, gather otherwise.

    Implements the full capability set, including the *sharded row-gather*
    primitives that let SGD/SDD/AP specs run distributed: ``rows_mv`` psum-
    reduces per-device column-block contributions K(x[idx], x_local) @ u_local,
    ``rows_t_mv`` computes per-device row blocks (all-gathered under ``gather``,
    left row-sharded under ``ring``), and ``block_at`` gathers the |idx|×|idx|
    principal block from the global (sharded) inputs. ``wrap_features`` is the
    mesh-awareness capability the SGD regulariser consumes: it shard_map-wraps
    a :class:`FeatureOperator` over this operator's mesh so the fused RFF pair
    step runs distributed without materialising the (n, 2q) feature matrix
    (see :class:`~repro.core.rff.ShardedFourierFeatures`).

    ``gather_once=True`` trades memory for collectives: instead of all-gathering
    the sharded inputs on *every* matvec (an O(n·d) collective per solver
    iteration), ``prepare_for_solve()`` — invoked once per solve by ``solve()``,
    outside the solver's while_loop/scan — replicates them into ``x_full``, and
    every subsequent ``mv``/``rows_mv``/``rows_t_mv`` reads the cached panel.
    Use it when the replicated (n, d) panel fits device memory (d is small; the
    K blocks still never materialise). Incompatible with ``comm="ring"`` (whose
    whole point is that the replicated panel never exists) — the combination
    raises ``ValueError``; ``comm="auto"`` + ``gather_once`` resolves to gather.

    Memory per device: O(n_local · chunk) — the paper's linear-memory claim,
    per device (plus O(n·d) with ``gather_once``; O(n·s/P) solver vectors
    under ``ring`` vs O(n·s) replicated under ``gather``).
    """

    x: jax.Array  # (n, d) training inputs, row-sharded over data_axes
    params: KernelParams
    mesh: Mesh = dataclasses.field(metadata=dict(static=True))
    data_axes: tuple = dataclasses.field(default=("data",), metadata=dict(static=True))
    row_chunk: int = dataclasses.field(default=2048, metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))
    block: "int | str" = dataclasses.field(default="auto", metadata=dict(static=True))
    precision: str = dataclasses.field(default="fp32", metadata=dict(static=True))
    instrument: bool = dataclasses.field(default=False, metadata=dict(static=True))
    # replicated input panel, populated by prepare_for_solve() when gather_once
    x_full: Optional[jax.Array] = None
    gather_once: bool = dataclasses.field(default=False, metadata=dict(static=True))
    comm: str = dataclasses.field(default="gather", metadata=dict(static=True))
    # "auto" switches to ring when the replicated (n, d) panel exceeds this
    comm_budget_bytes: int = dataclasses.field(
        default=128 * 2**20, metadata=dict(static=True)
    )

    def __post_init__(self):
        if self.comm not in COMM_STRATEGIES:
            raise ValueError(
                f"unknown comm strategy {self.comm!r}; expected one of "
                f"{COMM_STRATEGIES}"
            )
        if self.comm == "ring" and self.gather_once:
            raise ValueError(
                "gather_once=True replicates the O(n·d) input panel that "
                "comm='ring' exists to avoid — pick one: gather_once with "
                "comm='gather', or comm='ring' alone (comm='auto' resolves "
                "to gather when gather_once is set)"
            )

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def shape(self) -> tuple:
        return (self.x.shape[0], self.x.shape[0])

    @property
    def noise(self) -> jax.Array:
        return self.params.noise

    def _local_mv(self, x_local, x_other, v):
        """K(x_local, x_other) @ v through the backend dispatch (no jitter)."""
        return gram_mv(
            self.params, x_local, v, z=x_other, backend=self.backend,
            block=self.block, row_chunk=self.row_chunk, precision=self.precision,
        )

    def _mesh_size(self) -> int:
        """Number of shards along ``data_axes`` (the ring's pipeline depth P)."""
        return math.prod(self.mesh.shape[a] for a in self.data_axes)

    def _resolve_comm(self) -> str:
        """The effective strategy: ``auto`` → ring once the replicated (n, d)
        panel would exceed ``comm_budget_bytes`` per device (with ``gather_once``
        the user already asked for the panel, so auto resolves to gather)."""
        if self.comm != "auto":
            return self.comm
        if self.gather_once or self._mesh_size() == 1:
            return "gather"
        panel_bytes = self.x.shape[0] * self.x.shape[1] * self.x.dtype.itemsize
        return "ring" if panel_bytes > self.comm_budget_bytes else "gather"

    def _gather_rows(self, idx: jax.Array) -> jax.Array:
        """x[idx] as a replicated (|idx|, d) panel without replicating x: a
        masked psum over the canonical block-row layout (ring strategy), the
        cached ``x_full`` (gather_once), or a plain take (gather, where the
        partitioner stages its own gather of the small panel)."""
        if self.x_full is not None:
            return jnp.take(self.x_full, idx, axis=0)
        if self._resolve_comm() != "ring":
            return jnp.take(self.x, idx, axis=0)
        axes = self.data_axes
        return shard_map(
            lambda x_local, idx_rep: _psum_row_gather(x_local, idx_rep, axes),
            mesh=self.mesh, in_specs=(P(axes, None), P(None)),
            out_specs=P(None, None), check_rep=False,
        )(self.x, idx)

    def prepare_for_solve(self) -> "ShardedGram":
        """Per-solve setup hook (called once by ``solve()``, outside the solver
        loop): with ``gather_once``, replicate the sharded inputs into
        ``x_full`` so no matvec inside the loop pays the O(n·d) all_gather."""
        if not self.gather_once or self.x_full is not None:
            return self
        x_full = jax.device_put(
            self.x, NamedSharding(self.mesh, P(None, None))
        )
        return dataclasses.replace(self, x_full=x_full)

    def mv(self, v: jax.Array) -> jax.Array:
        """(K + σ²I) @ v under the resolved comm strategy.

        gather: per-device block-row matvec + all_gather of the result. v
        replicated; the input panel comes from ``x_full`` when pre-gathered,
        else a per-matvec all_gather. ring: P pipeline stages of
        K(x_local, x_peer) @ v_peer with ``ppermute`` rotating the next shard
        pair while the current one contracts — zero ``all_gather`` in the
        jaxpr, and the result stays row-sharded."""
        axes = self.data_axes
        squeeze = v.ndim == 1
        v2 = v[:, None] if squeeze else v

        if self._resolve_comm() == "ring":
            p_size = self._mesh_size()
            perm = [((j + 1) % p_size, j) for j in range(p_size)]

            def ring_body(x_local, v_local):
                # Stage t contracts the shard pair this device holds while the
                # permute for stage t+1 is already in flight — issuing the
                # ppermute *before* the contraction lets XLA's latency-hiding
                # scheduler overlap the rotation with the fused block matvec.
                acc = self.params.noise * v_local
                x_peer, v_peer = x_local, v_local
                for t in range(p_size):
                    if t + 1 < p_size:
                        nxt = jax.lax.ppermute((x_peer, v_peer), axes, perm)
                    acc = acc + self._local_mv(x_local, x_peer, v_peer)
                    if t + 1 < p_size:
                        x_peer, v_peer = nxt
                return acc

            out = shard_map(
                ring_body, mesh=self.mesh,
                in_specs=(P(axes, None), P(axes, None)),
                out_specs=P(axes, None), check_rep=False,
            )(self.x, v2)
            self._count(_bump_mv, out)
            return out[:, 0] if squeeze else out

        def block_row(x_local, x_all, v_all):
            i = jax.lax.axis_index(axes)
            n_local = x_local.shape[0]
            out = self._local_mv(x_local, x_all, v_all)
            v_local = jax.lax.dynamic_slice_in_dim(v_all, i * n_local, n_local, axis=0)
            out = out + self.params.noise * v_local
            return jax.lax.all_gather(out, axes, tiled=True)

        if self.x_full is not None:
            out = shard_map(
                block_row, mesh=self.mesh,
                in_specs=(P(axes, None), P(None, None), P(None, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, self.x_full, v2)
        else:
            def body(x_local, v_all):
                x_all = jax.lax.all_gather(x_local, axes, tiled=True)
                return block_row(x_local, x_all, v_all)

            out = shard_map(
                body, mesh=self.mesh, in_specs=(P(axes, None), P(None, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, v2)
        self._count(_bump_mv, out)
        return out[:, 0] if squeeze else out

    def rows_mv(self, idx: jax.Array, u: jax.Array) -> jax.Array:
        """K[idx, :] @ u — sharded row-gather: each device contracts its column
        block K(x[idx], x_local) @ u_local; a psum over the data axes reduces.
        idx is replicated; output is replicated (|idx|, s-like). Under ring the
        idx panel comes from an O(|idx|·d) masked psum instead of an all_gather
        of x, and u may arrive row-sharded (SGD iterates)."""
        axes = self.data_axes
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u

        def contract(x_local, xi, u_all):
            i = jax.lax.axis_index(axes)
            n_local = x_local.shape[0]
            u_local = jax.lax.dynamic_slice_in_dim(u_all, i * n_local, n_local, axis=0)
            part = self._local_mv(xi, x_local, u_local)
            return jax.lax.psum(part, axes)

        if self.x_full is not None:
            xi = self.x_full[idx]  # gathered once per solve, indexed replicated
            out = shard_map(
                contract, mesh=self.mesh,
                in_specs=(P(axes, None), P(None, None), P(None, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, xi, u2)
        elif self._resolve_comm() == "ring":
            def body_ring(x_local, idx_rep, u_local):
                xi = _psum_row_gather(x_local, idx_rep, axes)
                part = self._local_mv(xi, x_local, u_local)
                return jax.lax.psum(part, axes)

            out = shard_map(
                body_ring, mesh=self.mesh,
                in_specs=(P(axes, None), P(None), P(axes, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, idx, u2)
        else:
            def body(x_local, idx_rep, u_all):
                x_all = jax.lax.all_gather(x_local, axes, tiled=True)
                return contract(x_local, x_all[idx_rep], u_all)

            out = shard_map(
                body, mesh=self.mesh,
                in_specs=(P(axes, None), P(None), P(None, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, idx, u2)
        self._count(_bump_rows, out)
        return out[:, 0] if squeeze else out

    def rows_t_mv(self, idx: jax.Array, u: jax.Array) -> jax.Array:
        """K[idx, :]ᵀ @ u = K[:, idx] @ u — each device computes its row block
        K(x_local, x[idx]) @ u. Under gather the blocks are all-gathered to a
        replicated (n, s-like); under ring the idx panel comes from the masked
        psum and the output *stays row-sharded* — the all_gather this
        primitive used to pay per SGD step is gone, and downstream axpys on
        the iterate run shard-local."""
        axes = self.data_axes
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u

        def row_block(x_local, xi, u_rep):
            out_local = self._local_mv(x_local, xi, u_rep)
            return jax.lax.all_gather(out_local, axes, tiled=True)

        if self.x_full is not None:
            xi = self.x_full[idx]
            out = shard_map(
                row_block, mesh=self.mesh,
                in_specs=(P(axes, None), P(None, None), P(None, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, xi, u2)
        elif self._resolve_comm() == "ring":
            def body_ring(x_local, idx_rep, u_rep):
                xi = _psum_row_gather(x_local, idx_rep, axes)
                return self._local_mv(x_local, xi, u_rep)

            out = shard_map(
                body_ring, mesh=self.mesh,
                in_specs=(P(axes, None), P(None), P(None, None)),
                out_specs=P(axes, None), check_rep=False,
            )(self.x, idx, u2)
        else:
            def body(x_local, idx_rep, u_rep):
                x_all = jax.lax.all_gather(x_local, axes, tiled=True)
                return row_block(x_local, x_all[idx_rep], u_rep)

            out = shard_map(
                body, mesh=self.mesh,
                in_specs=(P(axes, None), P(None), P(None, None)),
                out_specs=P(None, None), check_rep=False,
            )(self.x, idx, u2)
        self._count(_bump_rows, out)
        return out[:, 0] if squeeze else out

    def rows_pair_mv(self, idx: jax.Array, look: jax.Array, b: jax.Array):
        """err = K[idx,:] @ look − b, then g = K[idx,:]ᵀ @ err — composed from
        the sharded row primitives. No VMEM fusion applies across the mesh
        collectives, but exposing the capability keeps the operator drop-in for
        the fused SGD step; the counters still record two row-block matvecs."""
        err = self.rows_mv(idx, look) - b
        return err, self.rows_t_mv(idx, err)

    def block_at(self, idx: jax.Array) -> jax.Array:
        """K[idx, idx] — gathered from the global (sharded) inputs; the |idx|×d
        panel and |idx|² block are small and land replicated. Under ring the
        panel comes from the masked psum (no all_gather of x)."""
        xi = self._gather_rows(idx)
        return gram(self.params, xi, xi)

    def wrap_features(self, ff: "FourierFeatures"):
        """Mesh-awareness capability (``supports(op, "wrap_features")``): wrap a
        feature operator so its phi_mv/phi_t_mv/phi_pair_mv run shard_map-ped
        over this operator's mesh — row-sharded x, psum-reduced transposes, the
        fused per-shard kernels (and their custom VJPs) intact, and the (n, 2q)
        feature matrix never materialised. SGD's regulariser consumes this to
        run its Eq. 3.3 pair step distributed."""
        from .rff import ShardedFourierFeatures  # deferred: rff imports this module

        return ShardedFourierFeatures(
            inner=ff, mesh=self.mesh, data_axes=self.data_axes
        )

    def diag_part(self) -> jax.Array:
        return gram_diag(self.params, self.x) + self.noise

    def precond_factor(
        self, rank: int, key: Optional[jax.Array] = None, method: str = "nystrom"
    ) -> jax.Array:
        """(n, rank) factor for Woodbury preconditioning; computed under global
        sharding semantics (the n×rank factor is the preconditioner's memory
        footprint either way)."""
        from .precond import low_rank_factor  # deferred: precond imports operators

        return low_rank_factor(self.params, self.x, rank, key=key, method=method)

    def dense(self) -> jax.Array:
        """Materialised K + σ²I (tests / small-n reference only)."""
        return gram(self.params, self.x) + self.noise * jnp.eye(self.n, dtype=self.x.dtype)
