"""Pathwise conditioning (§2.1.2, Eq. 2.12) driven by iterative solvers (Ch. 3–4).

A posterior function sample is a *function*

    f_|y(·) = f(·) + K_(·)X (v* − α*_i),
        v*   = (K+σ²I)⁻¹ y                  (posterior-mean representer weights)
        α*_i = (K+σ²I)⁻¹ (f_X^i + ε_i)      (per-sample uncertainty-reduction weights)

with f a prior sample approximated by random Fourier features. All s+1 linear systems
share the coefficient matrix, so they are solved as ONE batched multi-RHS call to any
solver in core/solvers (this batch is also where Ch. 5's probe vectors ride along —
see core/mll.py). Evaluating the result at new X* costs one kernel matvec: one solve
per *sample*, not per location — the property that makes Thompson sampling and BO
tractable (§3.3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram, matvec
from .rff import PriorSamples, sample_prior
from .solvers.base import Gram, SolveResult
from .solvers.cg import solve_cg


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PosteriorFunctions:
    """s posterior function samples + the posterior mean, evaluable anywhere."""

    params: KernelParams
    x: jax.Array  # (n, d) training inputs
    prior: PriorSamples  # s prior functions
    v_mean: jax.Array  # (n,) representer weights of the mean
    alpha: jax.Array  # (n, s) per-sample uncertainty-reduction weights
    solve_info: Optional[SolveResult] = None

    @property
    def num_samples(self) -> int:
        return self.alpha.shape[1]

    def mean(self, xs: jax.Array) -> jax.Array:
        return matvec(self.params, xs, self.v_mean, z=self.x)

    def __call__(self, xs: jax.Array) -> jax.Array:
        """Evaluate all samples at xs → (n*, s)."""
        kxs = gram(self.params, xs, self.x)  # (n*, n)
        return self.prior(xs) + kxs @ (self.v_mean[:, None] - self.alpha)

    def sample_mean_and_var(self, xs: jax.Array) -> tuple[jax.Array, jax.Array]:
        f = self(xs)
        return self.mean(xs), jnp.var(f, axis=1)


def pathwise_rhs(
    op: Gram,
    y: jax.Array,
    prior: PriorSamples,
    key: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Build the batched RHS [y | f_X^1+ε_1 | ... | f_X^s+ε_s] and the noise draws.

    Returns (rhs (n, 1+s), eps (n, s)). ε is returned separately so SGD's
    variance-reduced objective (Eq. 3.6) can move it into the regulariser as δ=ε/σ².
    """
    f_x = prior(op.x)  # (n, s)
    eps = jnp.sqrt(op.noise) * jax.random.normal(key, f_x.shape, dtype=f_x.dtype)
    rhs = jnp.concatenate([y[:, None], f_x + eps], axis=1)
    return rhs, eps


def posterior_functions(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
    *,
    num_samples: int = 16,
    num_features: int = 2048,
    solver: Callable[..., SolveResult] = solve_cg,
    x0: Optional[jax.Array] = None,
    **solver_kwargs,
) -> PosteriorFunctions:
    """End-to-end pathwise posterior: RFF prior + one batched iterative solve."""
    kp, ke, ks = jax.random.split(key, 3)
    op = Gram(x=x, params=params)
    prior = sample_prior(params, kp, num_samples, num_features, x.shape[1])
    rhs, eps = pathwise_rhs(op, y, prior, ke)
    if solver is solve_cg:
        res = solver(op, rhs, x0, **solver_kwargs)
    elif getattr(solver, "__name__", "") == "solve_sgd":
        # variance-reduced targets: data target [y | f_X], δ = [0 | ε/σ²]
        data = rhs.at[:, 1:].add(-eps)
        delta = jnp.concatenate([jnp.zeros_like(y)[:, None], eps / params.noise], axis=1)
        res = solver(op, data, x0, key=ks, delta=delta, **solver_kwargs)
    else:
        res = solver(op, rhs, x0, key=ks, **solver_kwargs)
    sol = res.solution
    return PosteriorFunctions(
        params=params,
        x=x,
        prior=prior,
        v_mean=sol[:, 0],
        alpha=sol[:, 1:],
        solve_info=res,
    )
