"""Pathwise conditioning (§2.1.2, Eq. 2.12) driven by iterative solvers (Ch. 3–4).

A posterior function sample is a *function*

    f_|y(·) = f(·) + K_(·)X (v* − α*_i),
        v*   = (K+σ²I)⁻¹ y                  (posterior-mean representer weights)
        α*_i = (K+σ²I)⁻¹ (f_X^i + ε_i)      (per-sample uncertainty-reduction weights)

with f a prior sample approximated by random Fourier features. All s+1 linear systems
share the coefficient matrix, so they are solved as ONE batched multi-RHS call to any
solver in core/solvers (this batch is also where Ch. 5's probe vectors ride along —
see core/mll.py). Evaluating the result at new X* costs one kernel matvec: one solve
per *sample*, not per location — the property that makes Thompson sampling and BO
tractable (§3.3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.ops import gram_mv
from .kernels_fn import KernelParams
from .rff import PriorSamples, sample_prior
from .solvers.base import Gram, SolveResult
from .solvers.spec import SpecLike, as_spec, solve


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PosteriorFunctions:
    """s posterior function samples + the posterior mean, evaluable anywhere.

    Evaluation is one fused prior-feature matvec Φ(·) @ W plus one
    cross-covariance matvec K(·, X) @ [weights], both through the same backend
    that drove the solve — neither the (n*, 2m) feature matrix nor the (n*, n)
    cross-Gram block is ever materialised, and both paths carry custom VJPs, so
    Thompson sampling's Adam ascent differentiates straight through the fused
    kernels by default on TPU.
    """

    params: KernelParams
    x: jax.Array  # (n, d) training inputs
    prior: PriorSamples  # s prior functions
    v_mean: jax.Array  # (n,) representer weights of the mean
    alpha: jax.Array  # (n, s) per-sample uncertainty-reduction weights
    solve_info: Optional[SolveResult] = None
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))

    @property
    def num_samples(self) -> int:
        return self.alpha.shape[1]

    def mean(self, xs: jax.Array) -> jax.Array:
        return gram_mv(self.params, xs, self.v_mean, z=self.x, backend=self.backend)

    def __call__(self, xs: jax.Array) -> jax.Array:
        """Evaluate all samples at xs → (n*, s)."""
        w = self.v_mean[:, None] - self.alpha  # (n, s)
        return self.prior(xs) + gram_mv(
            self.params, xs, w, z=self.x, backend=self.backend
        )

    def sample_mean_and_var(self, xs: jax.Array) -> tuple[jax.Array, jax.Array]:
        f = self(xs)
        return self.mean(xs), jnp.var(f, axis=1)

    def blocked_mean_and_var(
        self, xs_blocks: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        """Batched query path: many query blocks through ONE fused pass.

        ``xs_blocks`` is ``(B, L, d)`` — B queries padded to a common block
        length L (the serving engine's fixed bucket shapes). The blocks are
        flattened into a single ``(B·L, d)`` evaluation, so one fused
        cross-covariance matvec and one prior feature matvec serve all B
        queries, and the results are reshaped back to ``(B, L)`` mean and
        variance. Padding rows cost flops but not correctness — callers slice
        their valid prefix.
        """
        b, l, d = xs_blocks.shape
        mean, var = self.sample_mean_and_var(xs_blocks.reshape(b * l, d))
        return mean.reshape(b, l), var.reshape(b, l)

    def sample_paths(
        self, xs: jax.Array, w_prior: jax.Array, alpha: jax.Array
    ) -> jax.Array:
        """Evaluate *fresh* posterior sample paths at ``xs`` → (n*, s).

        A fresh pathwise sample is defined by new prior weight columns
        ``w_prior`` (num_features, s) on this posterior's feature map and the
        solved uncertainty-reduction weights ``alpha`` (n, s) for the targets
        ``Φ(X) w_prior + ε`` (the serving engine batches those solves across
        requests):

            f_|y(·) = Φ(·) w_prior + K(·, X) (v_mean − alpha)

        Zero columns are exact mean paths (zero prior weights, zero alpha), so
        bucket-padded weight columns evaluate to the posterior mean and slice
        off cleanly.
        """
        w = self.v_mean[:, None] - alpha  # (n, s)
        return self.prior.phi_mv(xs, w_prior) + gram_mv(
            self.params, xs, w, z=self.x, backend=self.backend
        )


def pathwise_target_rows(
    noise,
    y_rows: jax.Array,
    f_rows: jax.Array,
    key: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pathwise target rows for ONE row block, in ``solve()``'s (b, δ) convention.

    Returns (data (m, 1+s), delta (m, 1+s), eps (m, s)) with data =
    [y | f_X^1 .. f_X^s] and δ = [0 | ε_1/σ² .. ε_s/σ²]; ε is drawn fresh from
    ``key``. Because the targets are row-local (each row only needs its own
    prior-path value and noise draw), appending k observations appends k target
    rows: ``fit_state`` builds the whole system from one call over all n rows,
    while ``extend_state``/``update_state_lowrank`` call it on just the k new
    rows and keep the old rows' stored draws — which is exactly what makes the
    old solution a valid warm start / low-rank-correctable solution.
    """
    eps = jnp.sqrt(noise) * jax.random.normal(key, f_rows.shape, dtype=f_rows.dtype)
    data = jnp.concatenate([y_rows[:, None], f_rows], axis=1)
    delta = jnp.concatenate([jnp.zeros_like(y_rows)[:, None], eps / noise], axis=1)
    return data, delta, eps


def pathwise_targets(
    op: Gram,
    y: jax.Array,
    prior: PriorSamples,
    key: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Batched targets for the pathwise solve in ``solve()``'s (b, δ) convention.

    Returns (data (n, 1+s), delta (n, 1+s)) with data = [y | f_X^1 .. f_X^s] and
    δ = [0 | ε_1/σ² .. ε_s/σ²]: the system solved is (K+σ²I)V = data + σ²δ =
    [y | f_X+ε]. Keeping ε in the δ channel lets SGD apply the Eq. 3.6
    variance-reduction shift; every other solver folds it into the RHS.
    """
    # prior defaults to backend="auto": fused RFF matvec on TPU, features on CPU
    f_x = prior(op.x)  # (n, s)
    data, delta, _ = pathwise_target_rows(op.noise, y, f_x, key)
    return data, delta


def posterior_functions(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
    *,
    num_samples: int = 16,
    num_features: int = 2048,
    spec: Optional[SpecLike] = None,
    x0: Optional[jax.Array] = None,
    **spec_overrides,
) -> PosteriorFunctions:
    """End-to-end pathwise posterior: RFF prior + one batched iterative solve.

    ``spec`` is any registered :class:`~repro.core.solvers.spec.SolverSpec`
    (instance, class, or name like ``"sdd"``); defaults to CG. Extra keyword
    arguments are spec-field overrides (``spec="cg", max_iters=50``).
    """
    s = as_spec("cg" if spec is None else spec, **spec_overrides)
    backend = getattr(s, "backend", None) or "auto"
    kp, ke, ks = jax.random.split(key, 3)
    op = Gram(x=x, params=params, backend=backend)
    prior = sample_prior(params, kp, num_samples, num_features, x.shape[1])
    data, delta = pathwise_targets(op, y, prior, ke)
    res = solve(op, data, s, key=ks, x0=x0, delta=delta)
    sol = res.solution
    return PosteriorFunctions(
        params=params,
        x=x,
        prior=prior,
        v_mean=sol[:, 0],
        alpha=sol[:, 1:],
        solve_info=res,
        backend=backend,
    )
