"""Preconditioners for CG (§2.2.4; Gardner et al. 2018, Wang et al. 2019).

The low-rank family builds a rank-m surrogate K ≈ L Lᵀ and applies
(L Lᵀ + σ²I)⁻¹ via Woodbury in O(n·m) per application:

  * ``nystrom``: uniform-subset Nyström (TPU default — one m×m eig + matmuls).
  * ``pivoted_cholesky``: greedy diagonal pivoting (paper fidelity; sequential,
    latency-bound — kept for benchmark parity, see DESIGN.md §2).
  * ``rff``: the materialised random-feature matrix Φ as the factor (ΦΦᵀ is an
    unbiased K estimate, §2.2.2) — the feature-space preconditioner, sharing its
    surrogate with the pathwise prior (see ``RFFGram`` in core/operators.py and
    docs/features.md).

Factor construction is an *operator capability*: preconditioner specs call
``op.precond_factor(rank, key=, method=)`` (see core/operators.py), which routes
here via :func:`low_rank_factor` — so any operator that can produce a low-rank
factor of its K part (``Gram``, ``ShardedGram``, ``RFFGram``) is
preconditionable, and matvec-only operators raise a clear capability error
instead of a type check on ``Gram``.

:class:`JacobiPrecond` is the zero-setup fallback: diagonal scaling built from
the protocol's *required* ``diag_part()``, so every operator — including the
matvec-only ``LatentKroneckerOp`` and ``NormalEq`` — can be preconditioned by
the ``Jacobi`` spec without any capability beyond the protocol itself.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram, gram_diag
from .operators import LinearOperator


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WoodburyPrecond(LinearOperator):
    """The surrogate M = L Lᵀ + σ²I as a *pytree* LinearOperator, not a closure.

    Protocol convention: ``mv`` is the FORWARD apply M @ v (every operator's
    ``mv`` is A @ v — ``solve(woodbury, b, "cg")`` legitimately solves MV = b),
    while ``__call__`` is the preconditioner-apply convention r ↦ M⁻¹r (the
    Woodbury solve), which is what CG consumes. Being a registered pytree means
    a preconditioner can cross ``jax.jit`` boundaries as a traced argument:
    rebuilding one of the same rank (e.g. after a hyperparameter step) produces
    the same treedef and shapes, so the compiled CG solve is reused instead of
    retraced — the seed's closure-as-static-arg design recompiled the solve on
    every rebuild.
    """

    l: jax.Array  # (n, m) low-rank factor, K ≈ L Lᵀ
    chol: jax.Array  # (m, m) lower Cholesky of LᵀL + σ²I
    sigma2: jax.Array  # () noise variance

    @property
    def rank(self) -> int:
        return self.l.shape[1]

    @property
    def shape(self) -> tuple:
        return (self.l.shape[0], self.l.shape[0])

    @property
    def noise(self) -> jax.Array:
        return self.sigma2

    def mv(self, v: jax.Array) -> jax.Array:
        """M @ v = L(Lᵀv) + σ²v — the protocol's forward apply."""
        return self.l @ (self.l.T @ v) + self.sigma2 * v

    def diag_part(self) -> jax.Array:
        """diag(M) = Σ_j L² + σ²."""
        return jnp.sum(self.l * self.l, axis=1) + self.sigma2

    def __call__(self, r: jax.Array) -> jax.Array:
        """M⁻¹ @ r via Woodbury: (r − L (LᵀL + σ²I)⁻¹ Lᵀ r) / σ²."""
        sol = jax.scipy.linalg.cho_solve((self.chol, True), self.l.T @ r)
        return (r - self.l @ sol) / self.sigma2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class JacobiPrecond(LinearOperator):
    """Diagonal (Jacobi) preconditioner M = diag(A) as a pytree LinearOperator.

    Built from the protocol's required ``diag_part()`` — the cheap fallback for
    operators without a ``precond_factor`` capability (``LatentKroneckerOp``,
    ``NormalEq``). Same conventions as :class:`WoodburyPrecond`: ``mv`` is the
    forward apply M @ v, ``__call__`` the preconditioner apply r ↦ M⁻¹r that CG
    consumes. A pytree of one (n,) leaf, so per-solve rebuilds (fresh
    hyperparameters) reuse the compiled CG solve.
    """

    d: jax.Array  # (n,) diag(A) — includes the σ² shift (diag_part convention)

    @property
    def shape(self) -> tuple:
        return (self.d.shape[0], self.d.shape[0])

    def mv(self, v: jax.Array) -> jax.Array:
        """M @ v = diag(A) ⊙ v."""
        return self.d[:, None] * v if v.ndim == 2 else self.d * v

    def diag_part(self) -> jax.Array:
        return self.d

    def __call__(self, r: jax.Array) -> jax.Array:
        """M⁻¹ @ r = r / diag(A)."""
        return r / self.d[:, None] if r.ndim == 2 else r / self.d


def jacobi_preconditioner(op: LinearOperator) -> JacobiPrecond:
    """The Jacobi apply for any protocol operator — diag_part() is required, so
    this never raises a capability error."""
    return JacobiPrecond(d=op.diag_part())


def _woodbury_apply(l: jax.Array, sigma2: jax.Array) -> WoodburyPrecond:
    """Build the Woodbury apply for L: (n, m)."""
    m = l.shape[1]
    inner = l.T @ l + sigma2 * jnp.eye(m, dtype=l.dtype)  # (m, m)
    return WoodburyPrecond(l=l, chol=jnp.linalg.cholesky(inner), sigma2=jnp.asarray(sigma2))


def woodbury_from_factor(l: jax.Array, sigma2) -> WoodburyPrecond:
    """Public alias: (n, m) factor L with K ≈ LLᵀ → the (LLᵀ + σ²I)⁻¹ apply."""
    return _woodbury_apply(l, sigma2)


def nystrom_factor(
    params: KernelParams, x: jax.Array, key: jax.Array, rank: int = 100
) -> jax.Array:
    """(n, rank) Nyström factor L = K_xz K_zz^{-1/2} from a uniform subset."""
    n = x.shape[0]
    idx = jax.random.choice(key, n, (min(rank, n),), replace=False)
    z = x[idx]
    kzz = gram(params, z) + 1e-6 * jnp.eye(z.shape[0], dtype=x.dtype)
    kxz = gram(params, x, z)
    return kxz @ jnp.linalg.cholesky(jnp.linalg.inv(kzz))


def nystrom_preconditioner(
    params: KernelParams, x: jax.Array, key: jax.Array, rank: int = 100
) -> Callable[[jax.Array], jax.Array]:
    return _woodbury_apply(nystrom_factor(params, x, key, rank), params.noise)


@partial(jax.jit, static_argnames=("rank",))
def _pivoted_cholesky_factor(params: KernelParams, x: jax.Array, rank: int) -> jax.Array:
    n = x.shape[0]
    diag = gram_diag(params, x)
    l = jnp.zeros((n, rank), dtype=x.dtype)

    def body(i, carry):
        l, diag = carry
        p = jnp.argmax(diag)
        kp = gram(params, x[p][None, :], x)[0]  # row p of K
        row = kp - l @ l[p]
        piv = jnp.sqrt(jnp.maximum(diag[p], 1e-12))
        col = row / piv
        col = col.at[p].set(piv)
        l = l.at[:, i].set(col)
        diag = jnp.maximum(diag - col * col, 0.0)
        diag = diag.at[p].set(0.0)
        return l, diag

    l, _ = jax.lax.fori_loop(0, rank, body, (l, diag))
    return l


def pivoted_cholesky_preconditioner(
    params: KernelParams, x: jax.Array, rank: int = 100
) -> Callable[[jax.Array], jax.Array]:
    l = _pivoted_cholesky_factor(params, x, rank)
    return _woodbury_apply(l, params.noise)


def rff_factor(
    params: KernelParams, x: jax.Array, key: jax.Array, rank: int = 256
) -> jax.Array:
    """(n, rank) random-feature factor L = Φ(x) with E[LLᵀ] = K (§2.2.2).

    The feature-space preconditioner: a fresh paired sin/cos feature draw from
    the kernel's spectral density, materialised once at build time (rank must be
    even — one sin and one cos column per frequency)."""
    from .rff import make_fourier_features  # deferred: rff imports operators

    if rank % 2:
        raise ValueError(
            f"rff precond rank must be even (paired sin/cos columns); got {rank}"
        )
    ff = make_fourier_features(params, key, rank, x.shape[1], paired=True)
    return ff.features(x)


PRECOND_FACTOR_METHODS = ("nystrom", "pivoted_cholesky", "rff")


def low_rank_factor(
    params: KernelParams,
    x: jax.Array,
    rank: int,
    *,
    key: Optional[jax.Array] = None,
    method: str = "nystrom",
) -> jax.Array:
    """(n, rank) factor L with K(x, x) ≈ L Lᵀ — the ``precond_factor`` backend
    shared by ``Gram`` and ``ShardedGram``."""
    if method == "nystrom":
        key = jax.random.PRNGKey(0) if key is None else key
        return nystrom_factor(params, x, key, rank)
    if method == "pivoted_cholesky":
        return _pivoted_cholesky_factor(params, x, rank)
    if method == "rff":
        key = jax.random.PRNGKey(0) if key is None else key
        return rff_factor(params, x, key, rank)
    raise ValueError(
        f"unknown precond factor method {method!r}; expected one of "
        f"{PRECOND_FACTOR_METHODS}"
    )
