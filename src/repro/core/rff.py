"""Random Fourier features (§2.2.2): approximate prior function samples.

A prior sample is f(x) ≈ Φ(x) w with w ~ N(0, I), Φ(x)_j = sqrt(2σ_f²/m) cos(ω_jᵀx+b_j),
or the lower-variance paired sin/cos form (Sutherland & Schneider, 2015). Pathwise
conditioning (core/pathwise.py) consumes these to evaluate f_X (train) and f_X* (test)
*jointly* in O((n+n*) m), which is the paper's replacement for O((n+n*)³) conditional
sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, spectral_sample


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FourierFeatures:
    omega: jax.Array  # (m, d) frequencies
    phase: jax.Array  # (m,) phases (cos variant) — unused in paired variant
    signal: jax.Array  # σ_f² signal variance
    paired: bool = dataclasses.field(default=True, metadata=dict(static=True))

    @property
    def num_features(self) -> int:
        m = self.omega.shape[0]
        return 2 * m if self.paired else m

    def features(self, x: jax.Array) -> jax.Array:
        """Φ(x): (n, num_features). Uses the paired sin/cos map by default."""
        proj = x @ self.omega.T  # (n, m)
        m = self.omega.shape[0]
        if self.paired:
            scale = jnp.sqrt(self.signal / m)
            return scale * jnp.concatenate([jnp.sin(proj), jnp.cos(proj)], axis=-1)
        scale = jnp.sqrt(2.0 * self.signal / m)
        return scale * jnp.cos(proj + self.phase[None, :])


def make_fourier_features(
    params: KernelParams, key: jax.Array, num_features: int, d: int, paired: bool = True
) -> FourierFeatures:
    m = num_features // 2 if paired else num_features
    omega = spectral_sample(params, key, m, d)
    phase = jax.random.uniform(jax.random.fold_in(key, 7), (m,), maxval=2.0 * jnp.pi)
    return FourierFeatures(omega=omega, phase=phase, signal=params.signal, paired=paired)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PriorSamples:
    """s prior function samples f^(i)(·) = Φ(·) w_i, evaluable anywhere.

    ``backend`` selects the evaluation path: ``"features"`` (default)
    materialises Φ(x) and matmuls — differentiable everywhere; ``"auto"``
    evaluates through the fused Pallas RFF matvec on TPU (the (n × 2m) feature
    matrix never hits HBM — kernels/rff_matvec.py) and through features
    elsewhere; ``"fused"`` forces the Pallas kernel (interpret mode off-TPU).

    The fused path has no transpose rule, so it must not be differentiated
    *through* — the default stays ``"features"`` because user-facing posterior
    samples are (e.g. Thompson sampling gradient-ascends through them). The
    eager, never-differentiated prior evaluations (MLL probes, pathwise solve
    targets) opt in to ``"auto"`` via ``with_backend``.
    """

    ff: FourierFeatures
    w: jax.Array  # (num_features, s)
    backend: str = dataclasses.field(default="features", metadata=dict(static=True))

    def with_backend(self, backend: str) -> "PriorSamples":
        return dataclasses.replace(self, backend=backend)

    def __call__(self, x: jax.Array) -> jax.Array:
        if self.backend == "fused" and not self.ff.paired:
            raise ValueError(
                "the fused RFF matvec only implements the paired sin/cos "
                "feature map; use paired features or backend='features'"
            )
        use_fused = self.ff.paired and (
            self.backend == "fused"
            or (self.backend == "auto" and jax.default_backend() == "tpu")
        )
        if use_fused:
            from ..kernels.ops import rff_matvec  # deferred: pallas import

            return rff_matvec(x, self.ff.omega, self.w, signal=self.ff.signal)
        return self.ff.features(x) @ self.w  # (n, s)


def sample_prior(
    params: KernelParams,
    key: jax.Array,
    num_samples: int,
    num_features: int,
    d: int,
) -> PriorSamples:
    kf, kw = jax.random.split(key)
    ff = make_fourier_features(params, kf, num_features, d)
    w = jax.random.normal(kw, (ff.num_features, num_samples))
    return PriorSamples(ff=ff, w=w)
