"""Random Fourier features (§2.2.2): approximate prior function samples.

A prior sample is f(x) ≈ Φ(x) w with w ~ N(0, I), Φ(x)_j = sqrt(2σ_f²/m) cos(ω_jᵀx+b_j),
or the lower-variance paired sin/cos form (Sutherland & Schneider, 2015). Pathwise
conditioning (core/pathwise.py) consumes these to evaluate f_X (train) and f_X* (test)
*jointly* in O((n+n*) m), which is the paper's replacement for O((n+n*)³) conditional
sampling.

Both classes implement the :class:`~repro.core.operators.FeatureOperator` protocol
(``phi_mv``/``phi_t_mv``/``num_features``/``shape``) over the backend-dispatched
feature matvecs in kernels/ops.py: on the ``pallas`` backend the (n × 2m) feature
matrix never exists in HBM, and — since the fused kernels carry full custom VJPs
(forward, transpose, and input cotangents, kernels/rff_matvec.py) — the fused path
is differentiable w.r.t. inputs, frequencies, weights and σ_f². The historical
"must not differentiate through the fused path" restriction is gone: ``auto`` is
the default everywhere, so Thompson sampling's Adam ascent and the SGD regulariser
gradient run fused end to end on TPU and fall back to materialised features on CPU.
See docs/features.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, spectral_sample
from .operators import FeatureOperator


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FourierFeatures(FeatureOperator):
    """The feature map Φ itself — a :class:`FeatureOperator` with fused,
    differentiable contractions.

    ``backend`` selects the feature-matvec path (see kernels/ops.py):
    ``"auto"`` (fused Pallas on TPU, materialised features elsewhere),
    ``"pallas"`` (forced fused; interpret mode off-TPU), or ``"features"``
    (always materialise — reference path, any variant). The fused kernels only
    implement the paired sin/cos map; ``auto`` falls back to features for the
    cos-only variant, explicit ``pallas`` raises.

    ``precision`` selects the tile precision of the feature contractions —
    ``"fp32"`` (default) or ``"bf16"`` MXU operands with fp32 accumulation
    (kernels/ops.py PRECISIONS); solver specs pin it per solve like
    ``backend``. The sin/cos map itself always evaluates in fp32.
    """

    omega: jax.Array  # (m, d) frequencies
    phase: jax.Array  # (m,) phases (cos variant) — unused in paired variant
    signal: jax.Array  # σ_f² signal variance
    paired: bool = dataclasses.field(default=True, metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))
    precision: str = dataclasses.field(default="fp32", metadata=dict(static=True))

    @property
    def num_features(self) -> int:
        m = self.omega.shape[0]
        return 2 * m if self.paired else m

    def with_backend(self, backend: str) -> "FourierFeatures":
        return dataclasses.replace(self, backend=backend)

    def _resolve(self, backend: Optional[str]) -> str:
        from ..kernels.ops import resolve_feature_backend  # deferred: pallas import

        return resolve_feature_backend(
            self.backend if backend is None else backend, paired=self.paired
        )

    def features(self, x: jax.Array) -> jax.Array:
        """Φ(x) materialised: (n, num_features) — the optional ``features``
        capability (reference path, RFF preconditioner factors)."""
        proj = x @ self.omega.T  # (n, m)
        m = self.omega.shape[0]
        if self.paired:
            scale = jnp.sqrt(self.signal / m)
            return scale * jnp.concatenate([jnp.sin(proj), jnp.cos(proj)], axis=-1)
        scale = jnp.sqrt(2.0 * self.signal / m)
        return scale * jnp.cos(proj + self.phase[None, :])

    def phi_mv(self, x: jax.Array, w: jax.Array, *, backend: Optional[str] = None,
               precision: Optional[str] = None) -> jax.Array:
        """Φ(x) @ w: (n, s-like). Differentiable on every backend."""
        from ..kernels.ops import FEATURE_TRACE_COUNTS, rff_mv  # deferred: pallas

        if not self.paired:  # cos-only: no fused form (``_resolve`` refuses pallas)
            self._resolve(backend)
            FEATURE_TRACE_COUNTS["features"] += 1  # materialises Φ below
            return self.features(x) @ w
        return rff_mv(x, self.omega, w, signal=self.signal,
                      backend=self._resolve(backend),
                      precision=precision or self.precision)

    def phi_t_mv(self, x: jax.Array, u: jax.Array, *, backend: Optional[str] = None,
                 precision: Optional[str] = None) -> jax.Array:
        """Φ(x)ᵀ @ u: (num_features, s-like) — the SGD regulariser pullback."""
        from ..kernels.ops import FEATURE_TRACE_COUNTS, rff_t_mv  # deferred: pallas

        if not self.paired:
            self._resolve(backend)
            FEATURE_TRACE_COUNTS["features"] += 1  # materialises Φ below
            return self.features(x).T @ u
        return rff_t_mv(x, self.omega, u, signal=self.signal,
                        backend=self._resolve(backend),
                        precision=precision or self.precision)

    def phi_pair_mv(self, x: jax.Array, u: jax.Array, *,
                    backend: Optional[str] = None,
                    precision: Optional[str] = None) -> jax.Array:
        """Φ(x) (Φ(x)ᵀ u): (n, s-like) — the SGD regulariser composition in ONE
        dispatch. On the ``features`` backend Φ(x) materialises once and serves
        both contractions; on ``pallas`` the two-phase ``rff_pair`` kernel keeps
        the (2m, s) intermediate in VMEM for its whole lifetime."""
        from ..kernels.ops import FEATURE_TRACE_COUNTS, rff_pair_mv  # deferred

        if not self.paired:
            self._resolve(backend)
            FEATURE_TRACE_COUNTS["features"] += 2  # materialises Φ below, used twice
            feats = self.features(x)
            return feats @ (feats.T @ u)
        return rff_pair_mv(x, self.omega, u, signal=self.signal,
                           backend=self._resolve(backend),
                           precision=precision or self.precision)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedFourierFeatures(FeatureOperator):
    """A :class:`FourierFeatures` shard_map-ped over a device mesh — the
    distributed SGD regulariser path (ROADMAP item 2a, closed).

    ``x`` arrives row-sharded over ``data_axes`` and every contraction runs the
    *fused* per-shard kernels — custom VJPs intact, so the regulariser gradient
    differentiates through the sharded path exactly like the single-device one:

    * ``phi_mv``      — embarrassingly parallel: Φ(x_local) @ w per shard with
      ``w`` replicated; the (n, s) result stays row-sharded, zero collectives;
    * ``phi_t_mv``    — Φ(x_local)ᵀ @ u_local per shard, one psum reduces the
      (F, s) partials (the transpose's only collective);
    * ``phi_pair_mv`` — the Eq. 3.3 composition: per-shard pullback, psum of
      the small (F, s) intermediate, per-shard push-forward — row-sharded out.

    The (n, 2q) feature matrix never materialises (per-shard the fused kernels
    keep features in VMEM; the ``features`` capability is deliberately absent),
    and the only data crossing the interconnect is the (F, s) intermediate.
    Constructed by ``ShardedGram.wrap_features`` — the mesh-awareness
    capability SGD discovers via ``supports(op, "wrap_features")``.
    """

    inner: FourierFeatures
    mesh: jax.sharding.Mesh = dataclasses.field(metadata=dict(static=True))
    data_axes: tuple = dataclasses.field(default=("data",), metadata=dict(static=True))

    @property
    def num_features(self) -> int:
        return self.inner.num_features

    def _shard_map(self, body, in_specs, out_specs):
        from jax.experimental.shard_map import shard_map  # local: keeps rff importable early
        from jax.sharding import PartitionSpec as P

        return shard_map(
            body, mesh=self.mesh,
            in_specs=tuple(P(*s) for s in in_specs),
            out_specs=P(*out_specs), check_rep=False,
        )

    def phi_mv(self, x: jax.Array, w: jax.Array, *, backend: Optional[str] = None,
               precision: Optional[str] = None) -> jax.Array:
        """Φ(x) @ w with x row-sharded, w replicated → row-sharded (n, s-like).
        No collective: each shard evaluates its own feature rows."""
        axes = self.data_axes
        squeeze = w.ndim == 1
        w2 = w[:, None] if squeeze else w

        def body(x_local, w_rep):
            return self.inner.phi_mv(x_local, w_rep, backend=backend,
                                     precision=precision)

        out = self._shard_map(
            body, in_specs=((axes, None), (None, None)), out_specs=(axes, None)
        )(x, w2)
        return out[:, 0] if squeeze else out

    def phi_t_mv(self, x: jax.Array, u: jax.Array, *, backend: Optional[str] = None,
                 precision: Optional[str] = None) -> jax.Array:
        """Φ(x)ᵀ @ u → replicated (F, s-like): per-shard fused pullback on the
        local rows, psum-reduced over the data axes."""
        axes = self.data_axes
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u

        def body(x_local, u_local):
            t = self.inner.phi_t_mv(x_local, u_local, backend=backend,
                                    precision=precision)
            return jax.lax.psum(t, axes)

        out = self._shard_map(
            body, in_specs=((axes, None), (axes, None)), out_specs=(None, None)
        )(x, u2)
        return out[:, 0] if squeeze else out

    def phi_pair_mv(self, x: jax.Array, u: jax.Array, *,
                    backend: Optional[str] = None,
                    precision: Optional[str] = None) -> jax.Array:
        """Φ(x) (Φ(x)ᵀ u) in one shard_map: fused pullback, psum of the (F, s)
        intermediate — the only bytes on the wire — fused push-forward.
        Row-sharded in, row-sharded out."""
        axes = self.data_axes
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u

        def body(x_local, u_local):
            t = self.inner.phi_t_mv(x_local, u_local, backend=backend,
                                    precision=precision)
            t = jax.lax.psum(t, axes)
            return self.inner.phi_mv(x_local, t, backend=backend,
                                     precision=precision)

        out = self._shard_map(
            body, in_specs=((axes, None), (axes, None)), out_specs=(axes, None)
        )(x, u2)
        return out[:, 0] if squeeze else out


def make_fourier_features(
    params: KernelParams, key: jax.Array, num_features: int, d: int, paired: bool = True
) -> FourierFeatures:
    m = num_features // 2 if paired else num_features
    omega = spectral_sample(params, key, m, d)
    phase = jax.random.uniform(jax.random.fold_in(key, 7), (m,), maxval=2.0 * jnp.pi)
    return FourierFeatures(omega=omega, phase=phase, signal=params.signal, paired=paired)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PriorSamples(FeatureOperator):
    """s prior function samples f^(i)(·) = Φ(·) w_i, evaluable anywhere.

    A :class:`FeatureOperator` with bound weights: ``__call__(x)`` is
    ``phi_mv(x, w)`` through the map's backend dispatch. The default backend is
    ``"auto"`` — fused Pallas RFF matvecs on TPU (the (n × 2m) feature matrix
    never hits HBM), materialised features elsewhere — and because the fused
    kernels carry a full custom VJP this default is safe to differentiate
    *through*: Thompson sampling gradient-ascends posterior samples on the fused
    path. ``"features"`` forces materialisation; ``"pallas"`` (alias
    ``"fused"``) forces the fused kernel (interpret mode off-TPU).
    """

    ff: FourierFeatures
    w: jax.Array  # (num_features, s)
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))

    @property
    def num_features(self) -> int:
        return self.ff.num_features

    @property
    def num_samples(self) -> int:
        return self.w.shape[1]

    def with_backend(self, backend: str) -> "PriorSamples":
        return dataclasses.replace(self, backend=backend)

    def features(self, x: jax.Array) -> jax.Array:
        return self.ff.features(x)

    def phi_mv(self, x: jax.Array, w: jax.Array) -> jax.Array:
        return self.ff.phi_mv(x, w, backend=self.backend)

    def phi_t_mv(self, x: jax.Array, u: jax.Array) -> jax.Array:
        return self.ff.phi_t_mv(x, u, backend=self.backend)

    def phi_pair_mv(self, x: jax.Array, u: jax.Array) -> jax.Array:
        return self.ff.phi_pair_mv(x, u, backend=self.backend)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.phi_mv(x, self.w)  # (n, s)


def sample_prior(
    params: KernelParams,
    key: jax.Array,
    num_samples: int,
    num_features: int,
    d: int,
) -> PriorSamples:
    kf, kw = jax.random.split(key)
    ff = make_fourier_features(params, kf, num_features, d)
    w = jax.random.normal(kw, (ff.num_features, num_samples))
    return PriorSamples(ff=ff, w=w)
