"""Alternating projections / randomised block-coordinate solver (§5.1.1 baseline;
Shalev-Shwartz & Zhang 2013 SDCA; Tu et al. 2016; Wu et al. 2024).

Each step picks a random coordinate block I (|I| = p), solves the p×p block system
exactly, and updates the *maintained residual* incrementally:

    Δ = (K_II + σ² I_p)⁻¹ r_I ;   α_I += Δ ;   r −= (K_:I + σ² E_I) Δ

O(n·p + p³) per step, one kernel row-block gather (``rows_t_mv`` for the
residual update plus the exact ``block_at`` sub-solve — like SDD there is no
forward/transpose pair over one panel, so the SGD-style ``rows_pair_mv`` fusion
does not apply) — the third solver family the Ch. 5 improvements (warm start,
pathwise estimator) are demonstrated on.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (
    FLAG_NONFINITE,
    LinearOperator,
    SolveResult,
    as_matrix_rhs,
    finalize,
)


@partial(jax.jit, static_argnames=("num_steps", "block_size"))
def solve_ap(
    op: LinearOperator,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    key: jax.Array,
    num_steps: int = 2000,
    block_size: int = 512,
    tol: float = 1e-2,
) -> SolveResult:
    b2, squeeze = as_matrix_rhs(b)
    n, s = b2.shape
    sigma2 = op.noise
    if x0 is None:
        a0 = jnp.zeros_like(b2)
        r0 = b2  # α₀ == 0 ⇒ the initial residual is free (no A·0 matvec)
        init_mv = 0
    else:
        a0 = x0[:, None] if x0.ndim == 1 else x0
        r0 = b2 - op.mv(a0)
        init_mv = 1

    def step(carry, t):
        alpha, r, fl = carry
        idx = jax.random.randint(jax.random.fold_in(key, t), (block_size,), 0, n)
        # only the p×p principal block is materialised; the (p, n) panel the seed
        # gathered per step is replaced by one fused transposed row-block matvec
        kii = op.block_at(idx) + sigma2 * jnp.eye(block_size, dtype=b2.dtype)
        # duplicate indices in idx would double-count; deduplicate by weighting is
        # avoided simply by solving the (possibly singular-duplicated) system with a
        # small extra jitter — exactness per-step is not required for convergence.
        delta = jnp.linalg.solve(
            kii + 1e-6 * jnp.eye(block_size, dtype=b2.dtype), r[idx]
        )  # (p, s)
        # in-loop health check on the (p, s) block update: a NaN/Inf column (a
        # poisoned RHS, or a block solve gone bad) flags and freezes — its Δ is
        # zeroed, so the column-independent updates below leave it untouched
        ok = jnp.all(jnp.isfinite(delta), axis=0)
        healthy = (fl & FLAG_NONFINITE) == 0
        fl = fl | jnp.where(healthy & ~ok, FLAG_NONFINITE, 0).astype(jnp.int32)
        delta = jnp.where((healthy & ok)[None, :], delta, 0.0)
        alpha = alpha.at[idx].add(delta)
        r = r - op.rows_t_mv(idx, delta)  # r −= K[:, idx] @ Δ, fused
        r = r.at[idx].add(-sigma2 * delta)
        return (alpha, r, fl), None

    fl0 = jnp.where(
        jnp.all(jnp.isfinite(r0), axis=0), 0, FLAG_NONFINITE
    ).astype(jnp.int32)
    (alpha, r, fl), _ = jax.lax.scan(step, (a0, r0, fl0), jnp.arange(num_steps))
    # the maintained residual IS b − A α — finalize adds no extra matvec
    return finalize(
        op, alpha, b2, num_steps, squeeze, tol=tol, residual=r, matvecs=init_mv,
        flags=fl,
    )
