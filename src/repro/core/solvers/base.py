"""Shared iterative-solver infrastructure (§2.2.4).

Everything the paper does reduces to solving, for a batch of right-hand sides B,

    (K_XX + σ² I) V = B,      B = [y − μ | f_X + ε (s samples) | z_1.. z_p (probes)]

with a positive-definite coefficient matrix that is only ever *touched through
matvecs*. ``Gram`` wraps the training inputs + hyperparameters and provides
O(chunk·n)-memory matvecs and row blocks; every solver (cg/sgd/sdd/ap) consumes this
interface, takes an optional warm-start V₀ (Ch. 5 §5.3), and returns a ``SolveResult``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..kernels_fn import KernelParams, gram, matvec


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Gram:
    """The linear operator A = K(X,X) + σ² I, touched only through matvecs."""

    x: jax.Array  # (n, d) training inputs
    params: KernelParams
    row_chunk: int = dataclasses.field(default=2048, metadata=dict(static=True))
    use_pallas: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def noise(self) -> jax.Array:
        return self.params.noise

    def mv(self, v: jax.Array) -> jax.Array:
        """(K + σ²I) @ v without materialising K. v: (n,) or (n,s)."""
        if self.use_pallas:
            from ...kernels.ops import gram_matvec  # lazy: pallas import

            return gram_matvec(self.params, self.x, v, jitter=self.noise)
        return matvec(self.params, self.x, v, row_chunk=self.row_chunk, jitter=self.noise)

    def mv_k(self, v: jax.Array) -> jax.Array:
        """K @ v (no jitter)."""
        return matvec(self.params, self.x, v, row_chunk=self.row_chunk)

    def rows(self, idx: jax.Array) -> jax.Array:
        """K[idx, :] row block — O(|idx|·n) memory (the SGD/SDD/AP primitive)."""
        return gram(self.params, self.x[idx], self.x)

    def dense(self) -> jax.Array:
        """Materialised K + σ²I (tests / small-n reference only)."""
        return gram(self.params, self.x) + self.noise * jnp.eye(self.n, dtype=self.x.dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    solution: jax.Array  # (n, s)
    residual_norm: jax.Array  # (s,) final ||A v − b||₂ per RHS
    rel_residual: jax.Array  # (s,) ||A v − b|| / ||b||
    iterations: jax.Array  # () number of iterations executed
    converged: jax.Array  # () bool — all RHS under tolerance


def as_matrix_rhs(b: jax.Array) -> tuple[jax.Array, bool]:
    if b.ndim == 1:
        return b[:, None], True
    return b, False


def finalize(
    op: Gram, v: jax.Array, b: jax.Array, iterations, squeeze: bool, *, tol: float
) -> SolveResult:
    """Residual bookkeeping shared by all solvers. ``tol`` is the solver's own
    relative-residual tolerance, so ``converged`` is meaningful for CG and the
    stochastic solvers alike (it is *not* a fixed constant)."""
    r = b - op.mv(v)
    rn = jnp.linalg.norm(r, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    sol = v[:, 0] if squeeze else v
    return SolveResult(
        solution=sol,
        residual_norm=rn,
        rel_residual=rn / bn,
        iterations=jnp.asarray(iterations),
        converged=jnp.all(rn / bn <= tol),
    )


Solver = Callable[..., SolveResult]
