"""Shared iterative-solver infrastructure (§2.2.4).

Everything the paper does reduces to solving, for a batch of right-hand sides B,

    A V = B,      B = [y − μ | f_X + ε (s samples) | z_1.. z_p (probes)]

with a positive-definite coefficient matrix that is only ever *touched through
matvecs*. Solvers consume the :class:`~repro.core.operators.LinearOperator`
protocol (``mv``/``shape``/``diag_part``/``noise`` plus the optional row-block
capabilities ``rows_mv``/``rows_t_mv``/``block_at``), so the same cg/sgd/sdd/ap
code drives dense-free Gram operators, inducing-point normal equations, latent
Kronecker structure, and mesh-sharded operators alike. Each solver takes an
optional warm-start V₀ (Ch. 5 §5.3) and returns a ``SolveResult`` that reports
how many full operator matvecs it spent.

``Gram`` and the runtime matvec counters live in core/operators.py and are
re-exported here for backward compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..operators import (  # noqa: F401 (re-exports: legacy import path)
    Gram,
    LinearOperator,
    matvec_counts,
    reset_matvec_counts,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    solution: jax.Array  # (n, s)
    residual_norm: jax.Array  # (s,) final ||A v − b||₂ per RHS
    rel_residual: jax.Array  # (s,) ||A v − b|| / ||b||
    iterations: jax.Array  # () number of iterations executed
    converged: jax.Array  # () bool — all RHS under tolerance
    matvecs: jax.Array = 0  # () full operator matvecs spent (excl. row-block gathers)


def as_matrix_rhs(b: jax.Array) -> tuple[jax.Array, bool]:
    if b.ndim == 1:
        return b[:, None], True
    return b, False


def finalize(
    op: LinearOperator,
    v: jax.Array,
    b: jax.Array,
    iterations,
    squeeze: bool,
    *,
    tol: float,
    residual: Optional[jax.Array] = None,
    matvecs=0,
) -> SolveResult:
    """Residual bookkeeping shared by all solvers. ``tol`` is the solver's own
    relative-residual tolerance, so ``converged`` is meaningful for CG and the
    stochastic solvers alike (it is *not* a fixed constant).

    Solvers that track the residual (CG, AP) pass it as ``residual`` and skip the
    redundant full matvec the seed implementation paid here on every solve;
    ``matvecs`` is the solver's own count of full operator matvecs, incremented
    by one when the residual has to be recomputed.
    """
    if residual is None:
        residual = b - op.mv(v)
        matvecs = matvecs + 1
    rn = jnp.linalg.norm(residual, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    sol = v[:, 0] if squeeze else v
    return SolveResult(
        solution=sol,
        residual_norm=rn,
        rel_residual=rn / bn,
        iterations=jnp.asarray(iterations),
        converged=jnp.all(rn / bn <= tol),
        matvecs=jnp.asarray(matvecs),
    )


Solver = Callable[..., SolveResult]
