"""Shared iterative-solver infrastructure (§2.2.4).

Everything the paper does reduces to solving, for a batch of right-hand sides B,

    A V = B,      B = [y − μ | f_X + ε (s samples) | z_1.. z_p (probes)]

with a positive-definite coefficient matrix that is only ever *touched through
matvecs*. Solvers consume the :class:`~repro.core.operators.LinearOperator`
protocol (``mv``/``shape``/``diag_part``/``noise`` plus the optional row-block
capabilities ``rows_mv``/``rows_t_mv``/``block_at``), so the same cg/sgd/sdd/ap
code drives dense-free Gram operators, inducing-point normal equations, latent
Kronecker structure, and mesh-sharded operators alike. Each solver takes an
optional warm-start V₀ (Ch. 5 §5.3) and returns a ``SolveResult`` that reports
how many full operator matvecs it spent.

``Gram`` and the runtime matvec counters live in core/operators.py and are
re-exported here for backward compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..operators import (  # noqa: F401 (re-exports: legacy import path)
    Gram,
    LinearOperator,
    matvec_counts,
    reset_matvec_counts,
)


# ---------------------------------------------------------------------------
# Per-column diagnostic flags — the solver loops set these *inside* their
# while_loop/scan bodies (see cg.py/sgd.py/sdd.py/ap.py) and ``finalize`` adds a
# final payload check, so no solve() path can return silent NaNs: a non-finite
# payload always comes with FLAG_NONFINITE and ``converged=False``.
# ---------------------------------------------------------------------------

#: non-finite residual/iterate/payload detected (NaN or Inf)
FLAG_NONFINITE = 1
#: CG breakdown: pᵀAp ≤ 0 on an active column (loss of positive-definiteness)
FLAG_BREAKDOWN = 2
#: relative residual stopped improving over the solver's stall window
#: (advisory — the column keeps iterating and may still converge)
FLAG_STAGNATION = 4

#: flags that freeze a column: its updates are zeroed inside the loop so it
#: cannot contaminate the shared multi-RHS matvec (stagnation does not freeze)
FROZEN_FLAGS = FLAG_NONFINITE | FLAG_BREAKDOWN

_FLAG_NAMES = (
    (FLAG_NONFINITE, "nonfinite"),
    (FLAG_BREAKDOWN, "breakdown"),
    (FLAG_STAGNATION, "stagnation"),
)


def flag_names(mask: int) -> tuple:
    """Human-readable names for a single column's flag bitmask."""
    return tuple(name for bit, name in _FLAG_NAMES if int(mask) & bit)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    solution: jax.Array  # (n, s)
    residual_norm: jax.Array  # (s,) final ||A v − b||₂ per RHS
    rel_residual: jax.Array  # (s,) ||A v − b|| / ||b||
    iterations: jax.Array  # () number of iterations executed
    converged: jax.Array  # () bool — all RHS under tolerance AND flag-free
    matvecs: jax.Array = 0  # () full operator matvecs spent (excl. row-block gathers)
    flags: jax.Array = 0  # (s,) int32 per-column diagnostic bitmask (FLAG_*)

    @property
    def healthy(self) -> jax.Array:
        """() bool — no column carries a freezing flag (nonfinite/breakdown)."""
        return jnp.all((jnp.asarray(self.flags) & FROZEN_FLAGS) == 0)


def as_matrix_rhs(b: jax.Array) -> tuple[jax.Array, bool]:
    if b.ndim == 1:
        return b[:, None], True
    return b, False


def finalize(
    op: LinearOperator,
    v: jax.Array,
    b: jax.Array,
    iterations,
    squeeze: bool,
    *,
    tol: float,
    residual: Optional[jax.Array] = None,
    matvecs=0,
    flags: Optional[jax.Array] = None,
) -> SolveResult:
    """Residual bookkeeping shared by all solvers. ``tol`` is the solver's own
    relative-residual tolerance, so ``converged`` is meaningful for CG and the
    stochastic solvers alike (it is *not* a fixed constant).

    Solvers that track the residual (CG, AP) pass it as ``residual`` and skip the
    redundant full matvec the seed implementation paid here on every solve;
    ``matvecs`` is the solver's own count of full operator matvecs, incremented
    by one when the residual has to be recomputed.

    ``flags`` carries the per-column diagnostics the solver's loop raised
    (``FLAG_*`` bitmasks). On top of them this adds the final payload check —
    a non-finite solution or residual column gets ``FLAG_NONFINITE`` — so
    *every* ``solve()`` path (``distributed_solve``, ``solve_batched``, …)
    reports structured diagnostics instead of relying on callers to validate.
    NaN propagates through ``rel <= tol`` as False, and any flag forces
    ``converged=False``, so a non-finite payload can never read as converged.
    """
    if residual is None:
        residual = b - op.mv(v)
        matvecs = matvecs + 1
    rn = jnp.linalg.norm(residual, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    rel = rn / bn
    col_ok = jnp.all(jnp.isfinite(v), axis=0) & jnp.isfinite(rn)
    f = (
        jnp.zeros(jnp.shape(rn), dtype=jnp.int32)
        if flags is None
        else jnp.asarray(flags, dtype=jnp.int32)
    )
    f = f | jnp.where(col_ok, 0, FLAG_NONFINITE).astype(jnp.int32)
    # stagnation is advisory: a column that plateaued but still reached the
    # tolerance with a finite payload is healthy — clear the flag
    f = jnp.where((rel <= tol) & col_ok, f & ~FLAG_STAGNATION, f)
    sol = v[:, 0] if squeeze else v
    return SolveResult(
        solution=sol,
        residual_norm=rn,
        rel_residual=rel,
        iterations=jnp.asarray(iterations),
        converged=jnp.all((rel <= tol) & (f == 0)),
        matvecs=jnp.asarray(matvecs),
        flags=f,
    )


Solver = Callable[..., SolveResult]
