"""Shared iterative-solver infrastructure (§2.2.4).

Everything the paper does reduces to solving, for a batch of right-hand sides B,

    (K_XX + σ² I) V = B,      B = [y − μ | f_X + ε (s samples) | z_1.. z_p (probes)]

with a positive-definite coefficient matrix that is only ever *touched through
matvecs*. ``Gram`` wraps the training inputs + hyperparameters and provides
backend-dispatched matvecs (fused Pallas / chunked JAX / dense — see
kernels/ops.py) and fused row-block matvecs; every solver (cg/sgd/sdd/ap)
consumes this interface, takes an optional warm-start V₀ (Ch. 5 §5.3), and
returns a ``SolveResult`` that reports how many full Gram matvecs it spent.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ...kernels.ops import gram_mv, gram_rows_matvec
from ..kernels_fn import KernelParams, gram


# Runtime (post-compilation) matvec counters, bumped via jax.debug.callback from
# instrumented Gram operators — unlike trace-time counts these reflect what the
# hardware actually executed, including every while_loop/scan iteration.
_RUNTIME_COUNTS = {"mv": 0, "rows": 0}


def reset_matvec_counts() -> None:
    for k in _RUNTIME_COUNTS:
        _RUNTIME_COUNTS[k] = 0


def matvec_counts() -> dict:
    """{"mv": full Gram matvecs, "rows": row-block matvecs} executed by
    instrumented Gram operators since the last reset."""
    return dict(_RUNTIME_COUNTS)


def _bump_mv(_):
    _RUNTIME_COUNTS["mv"] += 1


def _bump_rows(_):
    _RUNTIME_COUNTS["rows"] += 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Gram:
    """The linear operator A = K(X,X) + σ² I, touched only through matvecs.

    ``backend`` selects the matvec implementation (see kernels/ops.py):
    ``"auto"`` (fused Pallas on TPU, chunked JAX elsewhere), ``"pallas"``,
    ``"chunked"``, or ``"dense"``. Solver specs can pin it per solve
    (``CG(backend="pallas")``). ``instrument=True`` counts executed matvecs via
    ``matvec_counts()`` (tests/benchmarks; adds a host callback per matvec).
    """

    x: jax.Array  # (n, d) training inputs
    params: KernelParams
    row_chunk: int = dataclasses.field(default=2048, metadata=dict(static=True))
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))
    block: int = dataclasses.field(default=256, metadata=dict(static=True))
    instrument: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def noise(self) -> jax.Array:
        return self.params.noise

    def _count(self, fn, out: jax.Array) -> None:
        if self.instrument:
            # operand-dependent so the callback stays inside loop bodies
            jax.debug.callback(fn, out.ravel()[0])

    def mv(self, v: jax.Array) -> jax.Array:
        """(K + σ²I) @ v without materialising K. v: (n,) or (n,s)."""
        out = gram_mv(
            self.params, self.x, v, jitter=self.noise, backend=self.backend,
            block=self.block, row_chunk=self.row_chunk,
        )
        self._count(_bump_mv, out)
        return out

    def mv_k(self, v: jax.Array) -> jax.Array:
        """K @ v (no jitter)."""
        out = gram_mv(
            self.params, self.x, v, backend=self.backend, block=self.block,
            row_chunk=self.row_chunk,
        )
        self._count(_bump_mv, out)
        return out

    def rows_mv(self, idx: jax.Array, u: jax.Array) -> jax.Array:
        """K[idx, :] @ u — fused row-block matvec, the panel never materialised.

        The SGD/SDD/AP data-fit primitive: O(|idx|·d) gathered inputs instead of
        an O(|idx|·n) HBM panel. u: (n,) or (n, s) → (|idx|, s-like).
        """
        out = gram_rows_matvec(
            self.params, self.x, idx, u, backend=self.backend, block=self.block,
            row_chunk=self.row_chunk,
        )
        self._count(_bump_rows, out)
        return out

    def rows_t_mv(self, idx: jax.Array, u: jax.Array) -> jax.Array:
        """K[idx, :]ᵀ @ u = K[:, idx] @ u — transposed fused row-block matvec.
        u: (|idx|,) or (|idx|, s) → (n, s-like)."""
        out = gram_rows_matvec(
            self.params, self.x, idx, u, transpose=True, backend=self.backend,
            block=self.block, row_chunk=self.row_chunk,
        )
        self._count(_bump_rows, out)
        return out

    def block_at(self, idx: jax.Array) -> jax.Array:
        """K[idx, idx] — the |idx|×|idx| principal block (AP's exact sub-solve)."""
        return gram(self.params, self.x[idx], self.x[idx])

    def rows(self, idx: jax.Array) -> jax.Array:
        """K[idx, :] materialised — O(|idx|·n) memory. Legacy primitive; solvers
        use the fused ``rows_mv``/``rows_t_mv``/``block_at`` instead."""
        return gram(self.params, self.x[idx], self.x)

    def dense(self) -> jax.Array:
        """Materialised K + σ²I (tests / small-n reference only)."""
        return gram(self.params, self.x) + self.noise * jnp.eye(self.n, dtype=self.x.dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveResult:
    solution: jax.Array  # (n, s)
    residual_norm: jax.Array  # (s,) final ||A v − b||₂ per RHS
    rel_residual: jax.Array  # (s,) ||A v − b|| / ||b||
    iterations: jax.Array  # () number of iterations executed
    converged: jax.Array  # () bool — all RHS under tolerance
    matvecs: jax.Array = 0  # () full Gram matvecs spent (excl. row-block gathers)


def as_matrix_rhs(b: jax.Array) -> tuple[jax.Array, bool]:
    if b.ndim == 1:
        return b[:, None], True
    return b, False


def finalize(
    op: Gram,
    v: jax.Array,
    b: jax.Array,
    iterations,
    squeeze: bool,
    *,
    tol: float,
    residual: Optional[jax.Array] = None,
    matvecs=0,
) -> SolveResult:
    """Residual bookkeeping shared by all solvers. ``tol`` is the solver's own
    relative-residual tolerance, so ``converged`` is meaningful for CG and the
    stochastic solvers alike (it is *not* a fixed constant).

    Solvers that track the residual (CG, AP) pass it as ``residual`` and skip the
    redundant full matvec the seed implementation paid here on every solve;
    ``matvecs`` is the solver's own count of full Gram matvecs, incremented by
    one when the residual has to be recomputed.
    """
    if residual is None:
        residual = b - op.mv(v)
        matvecs = matvecs + 1
    rn = jnp.linalg.norm(residual, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    sol = v[:, 0] if squeeze else v
    return SolveResult(
        solution=sol,
        residual_norm=rn,
        rel_residual=rn / bn,
        iterations=jnp.asarray(iterations),
        converged=jnp.all(rn / bn <= tol),
        matvecs=jnp.asarray(matvecs),
    )


Solver = Callable[..., SolveResult]
