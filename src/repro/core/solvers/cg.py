"""Method of conjugate gradients with optional preconditioning (§2.2.4, Eq. 2.78).

Operator-agnostic: consumes any ``LinearOperator`` through ``mv`` alone — the
dense-free ``Gram``, the inducing-point ``NormalEq``, the latent-Kronecker
operator (Ch. 6), and the mesh-sharded ``ShardedGram`` all run this exact
recursion. Batched over right-hand sides (each RHS runs its own CG recursion;
they share the matvec, so the dominant cost is one fused multi-RHS matvec per
iteration — this is exactly why the Ch. 5 pathwise estimator batches
[y | samples | probes] together). Supports warm starts (Ch. 5 §5.3) and a fixed
iteration budget (§5.4 early stopping).

Matvec economy (this is the library's hottest loop — every full Gram matvec is
O(n²·s) flops):

* zero warm starts skip the initial residual matvec (r₀ = b, not b − A·0);
* the residual norm is carried in the loop state — computed once per iteration,
  not in both ``cond`` and ``body``;
* ``finalize`` reuses the recursion's tracked residual instead of recomputing
  b − A v, saving one more full matvec per solve.

Pytree preconditioners (``core.precond.WoodburyPrecond``) are traced arguments,
so rebuilding a preconditioner of the same rank for new hyperparameters hits the
compiled-solve cache instead of retracing (the seed passed the apply *closure*
as a static argument — every rebuild recompiled the whole solve). Raw callables
still work but retrace per closure identity; ``cg_trace_count()`` exposes the
retrace counter for tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from .base import (  # noqa: F401 (re-export)
    FLAG_BREAKDOWN,
    FLAG_NONFINITE,
    FLAG_STAGNATION,
    FROZEN_FLAGS,
    Gram,
    LinearOperator,
    SolveResult,
    as_matrix_rhs,
    finalize,
)

_TRACE_COUNT = 0  # number of times the jitted CG core has been (re)traced

#: relative improvement of the best-so-far residual that resets the stagnation
#: counter — smaller steady progress than this over ``stall_window`` iterations
#: raises FLAG_STAGNATION (advisory; the column keeps iterating)
_STALL_RTOL = 1e-3


def cg_trace_count() -> int:
    return _TRACE_COUNT


def _cg_impl(op, b2, v0, precond, *, max_iters, tol, x0_is_none, squeeze,
             stall_window):
    global _TRACE_COUNT
    _TRACE_COUNT += 1
    minv = precond if precond is not None else (lambda r: r)

    if x0_is_none:
        r0 = b2  # v0 == 0 ⇒ the initial residual is free (no A·0 matvec)
        init_mv = 0
    else:
        r0 = b2 - op.mv(v0)
        init_mv = 1
    z0 = minv(r0)
    bn = jnp.maximum(jnp.linalg.norm(b2, axis=0), 1e-30)
    rn0 = jnp.linalg.norm(r0, axis=0)
    rz0 = jnp.sum(r0 * z0, axis=0)
    # in-loop health flags, per column: a non-finite initial residual (NaN in b,
    # or in A·x0 on a warm start) is flagged before the first iteration — the
    # IEEE trap here is that NaN > tol is False, so an unflagged NaN column
    # would silently read as converged
    fl0 = jnp.where(
        jnp.isfinite(rn0) & jnp.isfinite(rz0), 0, FLAG_NONFINITE
    ).astype(jnp.int32)

    def live_mask(fl, rn):
        # active columns: not frozen by a health flag, not converged
        return ((fl & FROZEN_FLAGS) == 0) & (rn / bn > tol)

    def cond(state):
        _, _, _, _, t, _, rn, fl, _, _ = state
        return jnp.logical_and(t < max_iters, jnp.any(live_mask(fl, rn)))

    def body(state):
        v, r, z, p, t, rz, rn, fl, best, since = state
        live = live_mask(fl, rn)
        ap = op.mv(p)
        pap = jnp.sum(p * ap, axis=0)
        # in-loop health checks, all on (s,) reductions (no extra matvec, no
        # extra O(n·s) pass): a NaN/Inf anywhere in ap surfaces in pᵀAp, and
        # pᵀAp ≤ 0 on an active column is CG breakdown (A not positive
        # definite for that direction). Flagged columns freeze BEFORE their
        # update is applied, so v/r keep the last healthy iterate and the
        # column stops contaminating nothing but its own lane of the matvec.
        bad_now = live & ~jnp.isfinite(pap)
        breakdown = live & jnp.isfinite(pap) & (pap <= 0)
        fl = (
            fl
            | jnp.where(bad_now, FLAG_NONFINITE, 0).astype(jnp.int32)
            | jnp.where(breakdown, FLAG_BREAKDOWN, 0).astype(jnp.int32)
        )
        live = live & ~bad_now & ~breakdown
        alpha = rz / jnp.where(pap > 0, pap, 1.0)
        # freeze converged and flagged columns (alpha→0) to avoid round-off
        # churn; judged on the carried residual norm — no second norm per step
        alpha = jnp.where(live, alpha, 0.0)
        v = v + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        z = minv(r)
        rz_new = jnp.sum(r * z, axis=0)
        rn_new = jnp.linalg.norm(r, axis=0)
        # the update itself can overflow (Inf elements in ap with finite pᵀAp,
        # a non-finite preconditioner apply): catch it on the same reductions
        post_bad = live & ~(jnp.isfinite(rn_new) & jnp.isfinite(rz_new))
        fl = fl | jnp.where(post_bad, FLAG_NONFINITE, 0).astype(jnp.int32)
        beta = rz_new / jnp.where(rz > 0, rz, 1.0)
        p = z + beta[None, :] * p
        # stagnation watch (advisory): count iterations without a relative
        # improvement of the best residual so far; only active columns count
        improved = rn_new < best * (1.0 - _STALL_RTOL)
        since = jnp.where(live, jnp.where(improved, 0, since + 1), since)
        fl = fl | jnp.where(
            live & (since >= stall_window), FLAG_STAGNATION, 0
        ).astype(jnp.int32)
        best = jnp.minimum(best, rn_new)
        return v, r, z, p, t + 1, rz_new, rn_new, fl, best, since

    state = (
        v0, r0, z0, z0, jnp.asarray(0), rz0, rn0, fl0, rn0,
        jnp.zeros(rn0.shape, dtype=jnp.int32),
    )
    v, r, _, _, t, _, _, fl, _, _ = jax.lax.while_loop(cond, body, state)
    # one matvec per iteration + the optional warm-start residual; the tracked
    # recursion residual r IS b − A v, so finalize adds no extra matvec
    return finalize(
        op, v, b2, t, squeeze, tol=tol, residual=r, matvecs=init_mv + t,
        flags=fl,
    )


_STATICS = ("max_iters", "tol", "x0_is_none", "squeeze", "stall_window")
_cg_jit = jax.jit(_cg_impl, static_argnames=_STATICS)
_cg_jit_closure = jax.jit(_cg_impl, static_argnames=_STATICS + ("precond",))


def solve_cg(
    op: LinearOperator,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    max_iters: int = 1000,
    tol: float = 1e-2,
    precond: Optional[Union[Callable[[jax.Array], jax.Array], object]] = None,
    stall_window: int = 100,
) -> SolveResult:
    """Solve (K+σ²I) V = B. b: (n,) or (n,s). tol is on the *relative* residual.

    ``precond`` is an ``r → M⁻¹r`` apply: a pytree dataclass (e.g.
    ``WoodburyPrecond``) rides through jit as a traced argument — rebuilds of the
    same rank/shape reuse the compiled solve — while a plain closure is a static
    argument and recompiles per identity (legacy behaviour).

    ``stall_window`` controls the advisory FLAG_STAGNATION diagnostic: a column
    whose residual fails to improve by a relative 1e-3 over this many
    consecutive iterations is flagged (it keeps iterating — see
    docs/robustness.md).
    """
    b2, squeeze = as_matrix_rhs(b)
    v0 = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    kw = dict(
        max_iters=max_iters, tol=float(tol), x0_is_none=x0 is None, squeeze=squeeze,
        stall_window=int(stall_window),
    )
    if precond is None or dataclasses.is_dataclass(precond):
        return _cg_jit(op, b2, v0, precond, **kw)
    return _cg_jit_closure(op, b2, v0, precond, **kw)
