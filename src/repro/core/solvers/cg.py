"""Method of conjugate gradients with optional preconditioning (§2.2.4, Eq. 2.78).

Batched over right-hand sides (each RHS runs its own CG recursion; they share the
matvec, so the dominant cost is one fused multi-RHS Gram matvec per iteration — this is
exactly why the Ch. 5 pathwise estimator batches [y | samples | probes] together).
Supports warm starts (Ch. 5 §5.3) and a fixed iteration budget (§5.4 early stopping).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .base import Gram, SolveResult, as_matrix_rhs, finalize  # noqa: F401 (re-export)


@partial(jax.jit, static_argnames=("max_iters", "precond"))
def solve_cg(
    op: Gram,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    max_iters: int = 1000,
    tol: float = 1e-2,
    precond: Optional[Callable[[jax.Array], jax.Array]] = None,
) -> SolveResult:
    """Solve (K+σ²I) V = B. b: (n,) or (n,s). tol is on the *relative* residual."""
    b2, squeeze = as_matrix_rhs(b)
    n, s = b2.shape
    v = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    minv = precond if precond is not None else (lambda r: r)

    r0 = b2 - op.mv(v)
    z0 = minv(r0)
    bn = jnp.maximum(jnp.linalg.norm(b2, axis=0), 1e-30)

    def cond(state):
        _, r, _, _, t, _ = state
        rel = jnp.linalg.norm(r, axis=0) / bn
        return jnp.logical_and(t < max_iters, jnp.any(rel > tol))

    def body(state):
        v, r, z, p, t, rz = state
        ap = op.mv(p)
        pap = jnp.sum(p * ap, axis=0)
        alpha = rz / jnp.where(pap > 0, pap, 1.0)
        # freeze converged columns (alpha→0) to avoid round-off churn
        active = jnp.linalg.norm(r, axis=0) / bn > tol
        alpha = jnp.where(active, alpha, 0.0)
        v = v + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        z = minv(r)
        rz_new = jnp.sum(r * z, axis=0)
        beta = rz_new / jnp.where(rz > 0, rz, 1.0)
        p = z + beta[None, :] * p
        return v, r, z, p, t + 1, rz_new

    state = (v, r0, z0, z0, jnp.asarray(0), jnp.sum(r0 * z0, axis=0))
    v, r, _, _, t, _ = jax.lax.while_loop(cond, body, state)
    return finalize(op, v, b2, t, squeeze, tol=tol)
