"""Escalation ladder on top of ``solve()`` — structured recovery from solver
breakdown (docs/robustness.md).

The solver loops (cg/sgd/sdd/ap) detect per-column trouble *inside* their
while_loop/scan bodies and report it as ``SolveResult.flags`` — non-finite
residuals, CG breakdown (pᵀAp ≤ 0), stagnation — with flagged columns frozen so
they cannot contaminate the shared multi-RHS matvec. ``solve_robust`` is the
layer that *reacts*: it runs the base solve, reads the flags once (the only
happy-path cost — no extra matvec, no payload re-validation), and walks flagged
columns down a configurable rung sequence:

1. **jitter** — re-solve with a noise bump ε·mean(diag A) added to the system,
   the classic GP Cholesky-retry move (Lin et al.; GPML folklore). Recovery is
   judged against the rung's *own* regularised system (K + σ²I + εI): for a
   near-singular K the residual of the ε-regularised solution measured against
   the original operator is Θ(ε/(σ²+ε)) by construction, so re-measuring there
   would declare every jitter rung a failure — the whole point of the rung is
   to accept the nearby well-posed system, exactly as a jittered Cholesky does.
2. **precondition** — attach/upgrade a Nyström preconditioner (operators with
   the ``precond_factor`` capability) and re-run CG.
3. **switch family** — a stochastic spec (SGD/SDD/AP) that diverged re-runs
   flagged columns under preconditioned CG (step-size-free).
4. **dense fallback** — for n ≤ ``dense_fallback_max_n``, materialise the
   operator and Cholesky-solve, escalating jitter until the factorisation
   succeeds. The unconditional last resort.

Only the flagged columns ride the ladder — healthy columns of a batch keep
their base-solve payload untouched — and every rung taken is recorded in the
returned :class:`SolveReport`. This is the serving engine's poison-request
rescue path (serve/engine.py) and usable directly by library callers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..operators import LinearOperator, supports
from .base import (
    FLAG_STAGNATION,
    FROZEN_FLAGS,
    SolveResult,
    as_matrix_rhs,
    flag_names,
)
from .spec import CG, Jacobi, Nystrom, SpecLike, as_spec, solve


# ---------------------------------------------------------------------------
# Policy and report types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EscalationPolicy:
    """Configuration of the rung sequence ``solve_robust`` walks.

    All fields are plain static data; the default policy is the full ladder.
    An empty ladder (``jitter=()``, ``switch_to_cg=False``,
    ``dense_fallback_max_n=0``) degrades ``solve_robust`` to "base solve +
    structured report", which is what the <2% happy-path overhead bound in
    ``bench_robust`` measures.
    """

    #: noise bumps, as multiples of mean(diag A); one rung per entry
    jitter: Tuple[float, ...] = (1e-6, 1e-3)
    #: Nyström rank for the precondition rung (needs ``precond_factor``)
    precond_rank: int = 64
    #: re-run flagged columns of a stochastic solve under CG
    switch_to_cg: bool = True
    #: iteration budget for ladder CG rungs
    cg_max_iters: int = 1000
    #: tolerance for ladder CG rungs; None inherits the spec's own ``tol``
    cg_tol: Optional[float] = None
    #: largest n for which the dense Cholesky fallback is permitted (0 = never)
    dense_fallback_max_n: int = 4096
    #: treat FLAG_STAGNATION columns as escalation candidates (advisory flag)
    escalate_on_stagnation: bool = True
    #: also escalate healthy-but-unconverged columns (off by default: slow
    #: convergence is normal for iteration-budgeted serving solves)
    escalate_on_unconverged: bool = False


@dataclasses.dataclass(frozen=True)
class RungRecord:
    """One rung taken: which columns it attempted and which it recovered."""

    rung: str  # "jitter:1e-06" | "precond:nystrom" | "switch:cg" | "dense:cholesky"
    columns: Tuple[int, ...]  # column indices this rung attempted
    recovered: Tuple[int, ...]  # subset that came back healthy
    flags_before: Tuple[int, ...]  # per attempted column, pre-rung bitmask
    iterations: int
    matvecs: int

    @property
    def flag_names_before(self) -> Tuple[Tuple[str, ...], ...]:
        return tuple(flag_names(m) for m in self.flags_before)


@dataclasses.dataclass(frozen=True)
class SolveReport:
    """What ``solve_robust`` did: the merged result plus the audit trail."""

    result: SolveResult  # merged payload (healthy base columns + rung rescues)
    rungs: Tuple[RungRecord, ...]  # every rung taken, in order (empty = happy path)
    escalated: bool  # any column left the happy path
    recovered: bool  # True iff no column is still flagged after the ladder
    failed_columns: Tuple[int, ...]  # columns still bad after the final rung

    @property
    def ladder(self) -> Tuple[str, ...]:
        return tuple(r.rung for r in self.rungs)


# ---------------------------------------------------------------------------
# The jittered operator wrapper (rung 1)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class _JitteredOp(LinearOperator):
    """``inner + eps·I``: the noise-bump wrapper the jitter rungs solve against.

    Only the σ²I split changes — ``noise``/``mv``/``diag_part`` gain ε, while
    the kernel-side capabilities (``rows_mv``/``rows_t_mv``/``block_at``/
    ``precond_factor``/``x``/``params``…) forward untouched via ``__getattr__``:
    the stochastic solvers add ``op.noise`` themselves, so forwarding the raw
    kernel rows is exactly right. ``hasattr`` capability detection follows the
    forwarding, so the wrapper advertises precisely the inner's capability set.
    """

    inner: Any  # the wrapped LinearOperator (a pytree)
    eps: jax.Array  # () the absolute ridge added

    @property
    def shape(self) -> tuple:
        return self.inner.shape

    @property
    def noise(self) -> jax.Array:
        return self.inner.noise + self.eps

    def mv(self, v: jax.Array) -> jax.Array:
        return self.inner.mv(v) + self.eps * v

    def diag_part(self) -> jax.Array:
        return self.inner.diag_part() + self.eps

    def dense(self) -> jax.Array:
        n = self.inner.shape[0]
        return self.inner.dense() + self.eps * jnp.eye(n)

    def prepare_for_solve(self) -> "_JitteredOp":
        # explicit (not via __getattr__): forwarding would return the prepared
        # *inner* and silently drop the jitter
        prep = getattr(self.inner, "prepare_for_solve", None)
        if callable(prep):
            return dataclasses.replace(self, inner=prep())
        return self

    def __getattr__(self, name: str):
        if name.startswith("__") or name in ("inner", "eps"):
            raise AttributeError(name)
        return getattr(object.__getattribute__(self, "inner"), name)


# ---------------------------------------------------------------------------
# solve_robust
# ---------------------------------------------------------------------------


def _bad_mask(res: SolveResult, tol: float, policy: EscalationPolicy) -> np.ndarray:
    """Host-side boolean mask of escalation-candidate columns. One small
    device→host transfer of the (s,) flags vector — the entire happy-path
    cost of ``solve_robust`` (gated <2% by ``bench_robust``)."""
    fl = np.atleast_1d(jax.device_get(res.flags)).astype(np.int64)
    mask = FROZEN_FLAGS | (FLAG_STAGNATION if policy.escalate_on_stagnation else 0)
    bad = (fl & mask) != 0
    if policy.escalate_on_unconverged:
        rel = np.atleast_1d(np.asarray(jax.device_get(res.rel_residual)))
        bad = bad | ~(rel <= tol)  # NaN-safe: NaN fails the comparison → bad
    return bad


def _pin_backend(op, spec):
    """Replicate solve()'s backend pinning on the *inner* operator, so ladder
    rungs can run with ``backend=None`` specs — ``dataclasses.replace`` on a
    forwarding wrapper would otherwise reject the foreign ``backend`` field."""
    backend = getattr(spec, "backend", None)
    if (
        backend is not None
        and dataclasses.is_dataclass(op)
        and getattr(op, "backend", backend) != backend
    ):
        op = dataclasses.replace(op, backend=backend)
    return op


def _diag_scale(op) -> float:
    return float(jnp.mean(op.diag_part()))


def _ladder(op, spec, policy: EscalationPolicy, key):
    """Yield (rung_name, rung_op, rung_spec) in escalation order. The base
    operator arrives backend-pre-pinned; every rung spec carries
    ``backend=None`` so solve() never tries to replace() a wrapper."""
    scale = None
    cg_tol = policy.cg_tol if policy.cg_tol is not None else float(
        getattr(spec, "tol", 1e-2)
    )
    is_cg = isinstance(spec, CG)
    base_spec = dataclasses.replace(spec, backend=None) if getattr(
        spec, "backend", None
    ) is not None else spec

    for j in policy.jitter:
        if scale is None:
            scale = _diag_scale(op)
        eps = jnp.asarray(j * scale)
        yield f"jitter:{j:g}", _JitteredOp(inner=op, eps=eps), base_spec

    pc_cls = Nystrom if supports(op, "precond_factor") else Jacobi
    pc = pc_cls(rank=policy.precond_rank) if pc_cls is Nystrom else pc_cls()
    if is_cg and getattr(spec, "precond", None) is None:
        yield "precond:" + pc.name, op, dataclasses.replace(
            base_spec, precond=pc, max_iters=max(
                policy.cg_max_iters, base_spec.max_iters
            )
        )
    elif not is_cg and policy.switch_to_cg:
        yield "switch:cg", op, CG(
            max_iters=policy.cg_max_iters, tol=cg_tol, precond=pc
        )


def _dense_rescue(op, b_bad, tol: float, policy: EscalationPolicy):
    """Final rung: materialise + Cholesky, escalating jitter until the
    factorisation holds. Returns (solution, rel, flags, rung_name) or None."""
    n = op.shape[0]
    if n > policy.dense_fallback_max_n or not supports(op, "dense"):
        return None
    a = op.dense()
    if not bool(jnp.all(jnp.isfinite(a))):
        return None  # a poisoned operator has no dense escape
    scale = float(jnp.mean(jnp.diag(a)))
    for j in (0.0,) + tuple(policy.jitter) + (1e-2,):
        aj = a + (j * scale) * jnp.eye(n, dtype=a.dtype)
        l, low = jax.scipy.linalg.cho_factor(aj, lower=True)
        if not bool(jnp.all(jnp.isfinite(l))):
            continue
        x = jax.scipy.linalg.cho_solve((l, low), b_bad)
        # judged against the rung's own (jittered) system, like rung 1
        rn = jnp.linalg.norm(aj @ x - b_bad, axis=0)
        bn = jnp.maximum(jnp.linalg.norm(b_bad, axis=0), 1e-30)
        rel = rn / bn
        ok = jnp.all(jnp.isfinite(x), axis=0) & (rel <= max(tol, 1e-4))
        if bool(jnp.any(ok)):
            flags = jnp.where(ok, 0, FROZEN_FLAGS).astype(jnp.int32)
            return x, rel, flags, f"dense:cholesky(jitter={j:g})"
    return None


def solve_robust(
    op,
    b: jax.Array,
    spec: SpecLike = "cg",
    *,
    key: Optional[jax.Array] = None,
    x0: Optional[jax.Array] = None,
    delta: Optional[jax.Array] = None,
    policy: EscalationPolicy = EscalationPolicy(),
    **overrides: Any,
) -> SolveReport:
    """``solve()`` with breakdown recovery: run the base solve, then walk any
    flagged columns down the escalation ladder.

    Happy path (no flags): exactly one base ``solve()`` plus a single host
    readback of the (s,) flags vector — zero extra matvecs, zero extra O(n·s)
    work (``bench_robust`` gates this at <2% wall-clock overhead).

    On escalation only the flagged columns are re-solved (cold, per rung);
    healthy columns keep their base payload bit-for-bit. The merged
    ``SolveResult`` in the returned report carries the rescued columns'
    residuals *as judged by the rescuing rung's system* (see module docstring
    for why), cleared flags for recovered columns, and the summed matvec bill.
    Columns no rung could save stay flagged (``report.failed_columns``) so
    callers fail them structurally instead of consuming NaNs.
    """
    s = as_spec(spec, **overrides)
    res = solve(op, b, s, key=key, x0=x0, delta=delta)
    tol = float(getattr(s, "tol", 1e-2))
    bad = _bad_mask(res, tol, policy)
    if not bad.any():
        return SolveReport(
            result=res, rungs=(), escalated=False, recovered=True,
            failed_columns=(),
        )

    b2, squeeze = as_matrix_rhs(jnp.asarray(b))
    d2 = None
    if delta is not None:
        d2 = as_matrix_rhs(jnp.asarray(delta))[0]

    # merged payload, host-mutated column-wise then reassembled
    sol = jnp.atleast_2d(res.solution.T).T if squeeze else res.solution
    sol = jnp.array(sol)
    rn = jnp.atleast_1d(res.residual_norm)
    rel = jnp.atleast_1d(res.rel_residual)
    fl = jnp.atleast_1d(jnp.asarray(res.flags, dtype=jnp.int32))
    total_matvecs = int(jax.device_get(res.matvecs))

    pinned = _pin_backend(op, s)
    rungs = []
    rung_key = key if key is not None else jax.random.PRNGKey(0)

    def _attempt(name, rsol, rrel, rflags, riters, rmv):
        """Merge one rung's output for the currently-bad columns."""
        nonlocal sol, rn, rel, fl, bad, total_matvecs
        cols = np.nonzero(bad)[0]
        rres = SolveResult(
            solution=rsol, residual_norm=rrel * 0.0, rel_residual=rrel,
            iterations=jnp.asarray(riters), converged=jnp.asarray(False),
            matvecs=jnp.asarray(rmv), flags=rflags,
        )
        ok = ~_bad_mask(rres, tol, policy)
        recovered_cols = tuple(int(c) for c, o in zip(cols, ok) if o)
        rungs.append(
            RungRecord(
                rung=name,
                columns=tuple(int(c) for c in cols),
                recovered=recovered_cols,
                flags_before=tuple(
                    int(v) for v in np.asarray(jax.device_get(fl))[cols]
                ),
                iterations=int(riters),
                matvecs=int(rmv),
            )
        )
        total_matvecs += int(rmv)
        if recovered_cols:
            idx = jnp.asarray(recovered_cols)
            src = jnp.asarray([int(np.nonzero(cols == c)[0][0]) for c in recovered_cols])
            sol = sol.at[:, idx].set(rsol[:, src])
            rel = rel.at[idx].set(rrel[src])
            rn = rn.at[idx].set(
                rrel[src] * jnp.maximum(jnp.linalg.norm(b2[:, idx], axis=0), 1e-30)
            )
            fl = fl.at[idx].set(rflags[src])
            bad[np.asarray(recovered_cols)] = False

    for name, rung_op, rung_spec in _ladder(pinned, s, policy, rung_key):
        if not bad.any():
            break
        cols = np.nonzero(bad)[0]
        kb = None
        if rung_key is not None:
            rung_key, kb = jax.random.split(rung_key)
        rres = solve(
            rung_op, b2[:, cols], rung_spec, key=kb,
            delta=None if d2 is None else d2[:, cols],
        )
        _attempt(
            name,
            jnp.atleast_2d(rres.solution.T).T,
            jnp.atleast_1d(rres.rel_residual),
            jnp.atleast_1d(jnp.asarray(rres.flags, dtype=jnp.int32)),
            int(jax.device_get(rres.iterations)),
            int(jax.device_get(rres.matvecs)),
        )

    if bad.any():
        cols = np.nonzero(bad)[0]
        rescue = _dense_rescue(pinned, b2[:, cols], tol, policy)
        if rescue is not None:
            x, rrel, rflags, name = rescue
            _attempt(name, x, rrel, rflags, 0, 0)

    failed = tuple(int(c) for c in np.nonzero(bad)[0])
    merged = SolveResult(
        solution=sol[:, 0] if squeeze else sol,
        residual_norm=rn,
        rel_residual=rel,
        iterations=res.iterations,
        converged=jnp.all((rel <= tol) & (fl == 0)),
        matvecs=jnp.asarray(total_matvecs),
        flags=fl,
    )
    return SolveReport(
        result=merged,
        rungs=tuple(rungs),
        escalated=True,
        recovered=not failed,
        failed_columns=failed,
    )
