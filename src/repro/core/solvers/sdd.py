"""Stochastic dual descent (Chapter 4, Algorithm 4.1).

Minimises the *dual* objective L*(α) = ½‖α‖²_{K+σ²I} − αᵀb, which shares the minimiser
α* = (K+σ²I)⁻¹ b with the primal but has Hessian K+σ²I instead of K(K+σ²I):
condition number ≤ 1 + κn/σ² and smallest eigenvalue bounded away from zero ⇒ step
sizes up to ~κn larger and geometric convergence guarantees (Prop. 4.1).

Estimator: **random coordinates** (multiplicative noise — Eq. 4.25), NOT random
features (additive noise — Eq. 4.24): the error of the coordinate estimator is
proportional to ‖α − α*‖, so noise vanishes as the iterate converges (§4.2.2; beware
the "Rao-Blackwellisation trap" — the *whole* gradient is subsampled, including the
σ²α − b part). Nesterov momentum + *geometric* iterate averaging (§4.2.3).

One kernel-row gather per step (``rows_mv`` only — the dual gradient needs no
transposed contraction, so there is nothing for the ``rows_pair_mv`` fusion SGD
uses to pair it with) ⇒ faster per step than Ch. 3 SGD at equal batch size.
Each step's panel is built tile-by-tile (Pallas) or in staged row chunks with a
vectorised covariance map (CPU — see kernels_fn._stationary_apply), and the
spec's ``precision`` field drops the panel contraction to bf16 tiles on request.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (
    FLAG_NONFINITE,
    LinearOperator,
    SolveResult,
    as_matrix_rhs,
    finalize,
)


@partial(jax.jit, static_argnames=("num_steps", "batch_size"))
def solve_sdd(
    op: LinearOperator,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    key: jax.Array,
    num_steps: int = 20_000,
    batch_size: int = 512,
    step_size_times_n: float = 50.0,
    momentum: float = 0.9,
    averaging: Optional[float] = None,
    tol: float = 1e-2,
) -> SolveResult:
    """Solve (K+σ²I)V = b by stochastic dual descent. b: (n,) or (n,s)."""
    b2, squeeze = as_matrix_rhs(b)
    n, s = b2.shape
    sigma2 = op.noise
    beta = step_size_times_n / n
    r = (100.0 / num_steps) if averaging is None else averaging  # §4.2.3: r = 100/t_max

    a0 = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)

    def step(carry, t):
        alpha, vel, avg, fl = carry
        idx = jax.random.randint(jax.random.fold_in(key, t), (batch_size,), 0, n)
        look = alpha + momentum * vel  # Nesterov lookahead
        # (k_i + σ² e_i)ᵀ look − b_i   (full dual gradient coordinate — Eq. 4.25);
        # fused row-block matvec: the (p, n) panel k_i never hits HBM
        resid = op.rows_mv(idx, look) + sigma2 * look[idx] - b2[idx]  # (p, s)
        # in-loop health check on the (p, s) block residual: a NaN/Inf in a
        # column flags and freezes it (updates masked), so a poisoned RHS or a
        # diverging step size cannot contaminate the rest of the batch
        ok = jnp.all(jnp.isfinite(resid), axis=0)
        healthy = (fl & FLAG_NONFINITE) == 0
        fl = fl | jnp.where(healthy & ~ok, FLAG_NONFINITE, 0).astype(jnp.int32)
        apply = (healthy & ok)[None, :]
        g_scaled = (n / batch_size) * resid
        vel_new = momentum * vel
        vel_new = vel_new.at[idx].add(-beta * g_scaled)
        vel = jnp.where(apply, vel_new, vel)
        alpha = jnp.where(apply, alpha + vel, alpha)
        # geometric iterate averaging, frozen with the iterate
        avg = jnp.where(apply, r * alpha + (1.0 - r) * avg, avg)
        return (alpha, vel, avg, fl), None

    fl0 = jnp.zeros((s,), dtype=jnp.int32)
    init = (a0, jnp.zeros_like(a0), a0, fl0)
    (alpha, _, avg, fl), _ = jax.lax.scan(step, init, jnp.arange(num_steps))
    return finalize(op, avg, b2, num_steps, squeeze, tol=tol, flags=fl)
