"""Stochastic gradient descent solver (Chapter 3).

Minimises the primal kernel-ridge objective (Eq. 3.2/3.3)

    L(v) = ½‖b_data − K v‖² + σ²/2 ‖v − δ‖²_K

whose minimiser is v* = (K+σ²I)⁻¹(b_data + σ²δ). The δ-shift is the paper's
variance-reduction trick for *sampling* (Eq. 3.6): for a posterior sample the naive
target f_X + ε puts the noise ε in the data-fit term (noisy targets ⇒ high mini-batch
gradient variance); moving it into the regulariser as δ = ε/σ² keeps the gradient
identical in expectation but with multiplicatively-scaled noise.

Gradient estimator (Eq. 3.3 / 4.29): mini-batch of kernel-matrix rows for the data-fit
term + fresh random Fourier features each step for the regulariser:

    ĝ(v) = (n/p) Σ_{i∈I} k_i (k_iᵀ v − b_i)  +  σ² Φ (Φᵀ (v − δ))

Uses Nesterov momentum + arithmetic tail (Polyak) averaging, per §3.3.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels_fn import spectral_sample
from .base import LinearOperator, SolveResult, as_matrix_rhs, finalize


@partial(
    jax.jit,
    static_argnames=("num_steps", "batch_size", "num_features", "average_tail"),
)
def solve_sgd(
    op: LinearOperator,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    key: jax.Array,
    num_steps: int = 20_000,
    batch_size: int = 512,
    num_features: int = 100,
    step_size_times_n: float = 0.5,
    momentum: float = 0.9,
    average_tail: float = 0.5,
    delta: Optional[jax.Array] = None,
    grad_clip: float = 0.1,
    tol: float = 1e-2,
) -> SolveResult:
    """Solve (K+σ²I)V = b_data + σ²δ by primal SGD. b/delta: (n,) or (n,s)."""
    b2, squeeze = as_matrix_rhs(b)
    n, s = b2.shape
    d = op.x.shape[1]
    sigma2 = op.noise
    delta2 = jnp.zeros_like(b2) if delta is None else (
        delta[:, None] if delta.ndim == 1 else delta
    )
    v0 = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    lr = step_size_times_n / n
    tail_start = int(num_steps * (1.0 - average_tail))

    def step(carry, t):
        v, mom, avg, cnt = carry
        kb = jax.random.fold_in(key, t)
        ki, kf = jax.random.split(kb)
        idx = jax.random.randint(ki, (batch_size,), 0, n)
        look = v + momentum * mom  # Nesterov lookahead
        # fused row-block matvecs: the (p, n) panel K[idx, :] is never
        # materialised — one forward and one transposed contraction per step
        err = op.rows_mv(idx, look) - b2[idx]  # (p, s)
        g_fit = (n / batch_size) * op.rows_t_mv(idx, err)
        omega = spectral_sample(op.params, kf, num_features, d)
        phi = jnp.sqrt(op.params.signal / num_features) * jnp.concatenate(
            [jnp.sin(op.x @ omega.T), jnp.cos(op.x @ omega.T)], axis=-1
        )  # (n, 2q): unbiased ΦΦᵀ ≈ K
        g_reg = sigma2 * (phi @ (phi.T @ (look - delta2)))
        g = g_fit + g_reg
        gn = jnp.linalg.norm(g, axis=0, keepdims=True)
        g = g * jnp.minimum(1.0, grad_clip * n / jnp.maximum(gn, 1e-30))
        mom = momentum * mom - lr * g
        v = v + mom
        in_tail = t >= tail_start
        cnt = cnt + in_tail.astype(jnp.float32)
        avg = jnp.where(in_tail, avg + (v - avg) / jnp.maximum(cnt, 1.0), avg)
        return (v, mom, avg, cnt), None

    init = (v0, jnp.zeros_like(v0), jnp.zeros_like(v0), jnp.asarray(0.0))
    (v, _, avg, cnt), _ = jax.lax.scan(step, init, jnp.arange(num_steps))
    v_out = jnp.where(cnt > 0, avg, v)
    return finalize(op, v_out, b2 + sigma2 * delta2, num_steps, squeeze, tol=tol)
