"""Stochastic gradient descent solver (Chapter 3).

Minimises the primal kernel-ridge objective (Eq. 3.2/3.3)

    L(v) = ½‖b_data − K v‖² + σ²/2 ‖v − δ‖²_K

whose minimiser is v* = (K+σ²I)⁻¹(b_data + σ²δ). The δ-shift is the paper's
variance-reduction trick for *sampling* (Eq. 3.6): for a posterior sample the naive
target f_X + ε puts the noise ε in the data-fit term (noisy targets ⇒ high mini-batch
gradient variance); moving it into the regulariser as δ = ε/σ² keeps the gradient
identical in expectation but with multiplicatively-scaled noise.

Gradient estimator (Eq. 3.3 / 4.29): mini-batch of kernel-matrix rows for the data-fit
term + fresh random Fourier features each step for the regulariser:

    ĝ(v) = (n/p) Σ_{i∈I} k_i (k_iᵀ v − b_i)  +  σ² Φ (Φᵀ (v − δ))

Both terms run as *pair* primitives — one dispatch each per step instead of two.
The data-fit term uses the operator's ``rows_pair_mv`` capability when present
(``err = K[idx,:] @ look − b``, ``g = K[idx,:]ᵀ @ err`` off a single panel
build; see kernels/ops.gram_rows_pair), falling back to the ``rows_mv`` +
``rows_t_mv`` composition on operators without it. The regulariser runs through
``phi_pair_mv`` — Φ(Φᵀ(v − δ)) as ONE fused kernel whose (2q, s) intermediate
never leaves VMEM on the Pallas backend, and one materialise-once contraction
pair elsewhere — dispatched through the same backend/precision as the
operator's Gram matvecs (fresh features every step made this the dominant
non-row cost). Mesh-sharded operators declare ``wrap_features`` and the fresh
draw is shard_map-wrapped over the mesh (ShardedFourierFeatures): the fused
pair step runs per shard with a psum-reduced transpose — the (n, 2q) feature
matrix never materialises, distributed included. Because the features are a
pytree with step-independent shapes, the fused path stages once for the whole
scan.

Uses Nesterov momentum + arithmetic tail (Polyak) averaging, per §3.3.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels_fn import spectral_sample
from ..operators import supports
from ..rff import FourierFeatures
from .base import (
    FLAG_NONFINITE,
    LinearOperator,
    SolveResult,
    as_matrix_rhs,
    finalize,
)


@partial(
    jax.jit,
    static_argnames=("num_steps", "batch_size", "num_features", "average_tail"),
)
def solve_sgd(
    op: LinearOperator,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    key: jax.Array,
    num_steps: int = 20_000,
    batch_size: int = 512,
    num_features: int = 100,
    step_size_times_n: float = 0.5,
    momentum: float = 0.9,
    average_tail: float = 0.5,
    delta: Optional[jax.Array] = None,
    grad_clip: float = 0.1,
    tol: float = 1e-2,
) -> SolveResult:
    """Solve (K+σ²I)V = b_data + σ²δ by primal SGD. b/delta: (n,) or (n,s)."""
    b2, squeeze = as_matrix_rhs(b)
    n, s = b2.shape
    d = op.x.shape[1]
    sigma2 = op.noise
    delta2 = jnp.zeros_like(b2) if delta is None else (
        delta[:, None] if delta.ndim == 1 else delta
    )
    v0 = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    lr = step_size_times_n / n
    tail_start = int(num_steps * (1.0 - average_tail))
    # the regulariser's feature matvecs follow the operator's backend (pinned by
    # the spec through solve(), like the Gram matvecs). Mesh-sharded operators
    # declare the ``wrap_features`` capability: the fresh feature draw is
    # shard_map-wrapped over the operator's mesh so the fused pair step runs
    # per shard (psum-reduced transpose, custom VJPs intact) — same fused path,
    # distributed, no materialised-feature fallback.
    feat_backend = getattr(op, "backend", "auto") or "auto"
    feat_precision = getattr(op, "precision", "fp32") or "fp32"
    fused_pair = supports(op, "rows_pair_mv")
    wrap = op.wrap_features if supports(op, "wrap_features") else (lambda ff: ff)

    def step(carry, t):
        v, mom, avg, cnt, fl = carry
        kb = jax.random.fold_in(key, t)
        ki, kf = jax.random.split(kb)
        idx = jax.random.randint(ki, (batch_size,), 0, n)
        look = v + momentum * mom  # Nesterov lookahead
        # data-fit pair step: the (p, n) panel K[idx, :] is never materialised,
        # and with rows_pair_mv it is built ONCE for both contractions
        if fused_pair:
            _, g_raw = op.rows_pair_mv(idx, look, b2[idx])
        else:
            err = op.rows_mv(idx, look) - b2[idx]  # (p, s)
            g_raw = op.rows_t_mv(idx, err)
        g_fit = (n / batch_size) * g_raw
        # fresh unbiased feature draw (ΦΦᵀ ≈ K): ONE fused pair feature matvec
        # (phi_pair_mv) — Φ (n, 2q) never materialised on pallas, and the
        # (2q, s) intermediate t = Φᵀ(look − δ) never leaves VMEM
        ff = wrap(FourierFeatures(
            omega=spectral_sample(op.params, kf, num_features, d),
            phase=jnp.zeros((num_features,)),
            signal=op.params.signal,
            backend=feat_backend,
            precision=feat_precision,
        ))
        g_reg = sigma2 * ff.phi_pair_mv(op.x, look - delta2)
        g = g_fit + g_reg
        gn = jnp.linalg.norm(g, axis=0, keepdims=True)
        # in-loop health check on an (s,)-sized reduction already computed for
        # gradient clipping: a NaN/Inf anywhere in a column's gradient surfaces
        # in its norm. Flagged columns freeze (updates masked to the previous
        # iterate), so one poisoned RHS cannot contaminate the shared batch.
        ok = jnp.isfinite(gn[0])
        healthy = (fl & FLAG_NONFINITE) == 0
        fl = fl | jnp.where(healthy & ~ok, FLAG_NONFINITE, 0).astype(jnp.int32)
        apply = (healthy & ok)[None, :]
        g = g * jnp.minimum(1.0, grad_clip * n / jnp.maximum(gn, 1e-30))
        mom = jnp.where(apply, momentum * mom - lr * g, mom)
        v = jnp.where(apply, v + mom, v)
        in_tail = t >= tail_start
        cnt = cnt + in_tail.astype(jnp.float32)
        avg_new = avg + (v - avg) / jnp.maximum(cnt, 1.0)
        avg = jnp.where(jnp.logical_and(in_tail, apply[0])[None, :], avg_new, avg)
        return (v, mom, avg, cnt, fl), None

    fl0 = jnp.zeros((s,), dtype=jnp.int32)
    init = (v0, jnp.zeros_like(v0), jnp.zeros_like(v0), jnp.asarray(0.0), fl0)
    (v, _, avg, cnt, fl), _ = jax.lax.scan(step, init, jnp.arange(num_steps))
    v_out = jnp.where(cnt > 0, avg, v)
    return finalize(
        op, v_out, b2 + sigma2 * delta2, num_steps, squeeze, tol=tol, flags=fl
    )
