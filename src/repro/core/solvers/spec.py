"""Declarative solver configuration and the library's single ``solve()`` entry point.

The paper's thesis is that *every* expensive GP computation — pathwise posterior
samples (Ch. 3), MLL gradients (Ch. 5), Thompson steps (§3.3.2), latent-Kronecker
posteriors (Ch. 6), distributed solves — reduces to one batched multi-RHS linear
solve against interchangeable iterative solvers. This module makes that
interchangeability a first-class API instead of an accident of call sites:

* frozen, pytree-registered spec dataclasses describe *how* to solve
  (``CG``, ``SGD``, ``SDD``, ``AP``) and how to precondition (``Nystrom``,
  ``PivotedCholesky``);
* a registry maps string names (``"cg"``/``"sgd"``/``"sdd"``/``"ap"``) to spec
  classes so configs, CLIs and serialized runs can name solvers;
* ``solve(op, b, spec, key=..., x0=..., delta=...)`` uniformly handles PRNG keys,
  warm starts and preconditioner construction for all of them, for ANY
  :class:`~repro.core.operators.LinearOperator` — ``Gram``, ``NormalEq``,
  ``LatentKroneckerOp``, ``ShardedGram``, or a third-party operator.

Specs declare the operator capabilities they consume (``SolverSpec.needs``) and
``solve()`` verifies them up front: a spec requesting row blocks from a
matvec-only operator raises a clear ``TypeError`` naming the missing capability.

The system solved is always

    (K + σ²I) V = b + σ² δ

where ``delta`` is an optional extra channel: pathwise sampling passes δ = ε/σ² so
SGD can keep the noise draw out of its mini-batch data-fit term (the Eq. 3.6
variance-reduction shift); solvers without a native δ channel fold σ²δ into the
right-hand side, which is algebraically identical.

Specs carry only static (hashable) configuration, so they can cross ``jax.jit``
boundaries as static arguments and serve as cache keys for compiled solves.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, ClassVar, Dict, Optional, Tuple, Type, Union

import jax

import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from ...kernels.ops import BACKENDS, FEATURE_BACKENDS, PRECISIONS
from ..operators import require_capabilities
from ..precond import jacobi_preconditioner, woodbury_from_factor
from .ap import solve_ap
from .base import SolveResult, as_matrix_rhs
from .cg import solve_cg
from .sdd import solve_sdd
from .sgd import solve_sgd


def _static(default):
    return dataclasses.field(default=default, metadata=dict(static=True))


# ---------------------------------------------------------------------------
# Preconditioner specs (§2.2.4; built on core/precond.py)
# ---------------------------------------------------------------------------

_PRECOND_REGISTRY: Dict[str, type] = {}


def register_precond(name: str, cls: Optional[type] = None):
    """Register a preconditioner spec class under a string name (decorator)."""

    def deco(c: type) -> type:
        c.name = name
        _PRECOND_REGISTRY[name] = c
        return c

    return deco(cls) if cls is not None else deco


def get_precond(name: str) -> type:
    try:
        return _PRECOND_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown preconditioner {name!r}; registered: {sorted(_PRECOND_REGISTRY)}"
        ) from None


def registered_preconds() -> tuple:
    return tuple(sorted(_PRECOND_REGISTRY))


class _JsonSpecMixin:
    """``to_json``/``from_json`` shared by solver and preconditioner specs.

    Specs are static dataclasses, so serialization is just their fields; nested
    preconditioner specs are tagged dicts. Prebuilt apply callables are runtime
    objects and refuse to serialize.
    """

    def to_json(self, **dumps_kwargs: Any) -> str:
        return json.dumps(_spec_to_dict(self), **dumps_kwargs)

    @staticmethod
    def from_json(s: str) -> "Any":
        return spec_from_dict(json.loads(s))


class _FactorPrecondSpec(_JsonSpecMixin):
    """Preconditioner specs built from an operator's ``precond_factor``
    capability: L = op.precond_factor(rank, method=...) with K ≈ LLᵀ, wrapped in
    the Woodbury apply (LLᵀ + σ²I)⁻¹."""

    method: ClassVar[str] = "?"

    def build(self, op, key: Optional[jax.Array] = None) -> Callable:
        require_capabilities(
            op, ("precond_factor",), consumer=f"the {self.name!r} preconditioner"
        )
        l = op.precond_factor(self.rank, key=key, method=self.method)
        return woodbury_from_factor(l, op.noise)


@register_precond("nystrom")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Nystrom(_FactorPrecondSpec):
    """Uniform-subset Nyström preconditioner: rank-m surrogate + Woodbury apply."""

    method: ClassVar[str] = "nystrom"
    rank: int = _static(100)


@register_precond("pivoted_cholesky")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PivotedCholesky(_FactorPrecondSpec):
    """Greedy pivoted-Cholesky preconditioner (paper fidelity; sequential build)."""

    method: ClassVar[str] = "pivoted_cholesky"
    rank: int = _static(100)


@register_precond("rff")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RFF(_FactorPrecondSpec):
    """Random-feature (feature-space) preconditioner: L = Φ(x), E[LLᵀ] = K.

    The surrogate is the same feature expansion pathwise conditioning uses for
    the prior (§2.2.2), so the preconditioner and the sampler share one
    approximation family. ``rank`` counts feature *columns* (must be even:
    paired sin/cos). On an :class:`~repro.core.operators.RFFGram` operator the
    factor is the operator's own Φ and Woodbury becomes the exact inverse.
    """

    method: ClassVar[str] = "rff"
    rank: int = _static(256)


@register_precond("jacobi")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Jacobi(_JsonSpecMixin):
    """Diagonal (Jacobi) preconditioner built from the protocol's *required*
    ``diag_part()`` — the cheap fallback for operators without a
    ``precond_factor`` capability (``LatentKroneckerOp``, ``NormalEq``). Needs
    no optional capability, so ``CG(precond=Jacobi())`` works on every operator
    ``solve()`` accepts."""

    def build(self, op, key: Optional[jax.Array] = None) -> Callable:
        return jacobi_preconditioner(op)


PrecondSpec = Union[Nystrom, PivotedCholesky, RFF, Jacobi]
# a raw ``r -> M⁻¹r`` callable is also accepted wherever a PrecondSpec fits
PrecondLike = Union[Nystrom, PivotedCholesky, RFF, Jacobi, Callable]


# ---------------------------------------------------------------------------
# Solver specs + registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["SolverSpec"]] = {}


def register_solver(name: str, cls: Optional[type] = None):
    """Register a spec class under a string name (usable as a decorator).

    Third-party solvers plug in the same way the built-ins do: subclass
    ``SolverSpec``, implement ``run``, and ``register_solver("mine", MySpec)`` —
    every consumer (``posterior_functions``, ``mll_grad``, ``thompson_step``, …)
    then accepts ``spec="mine"`` without being edited.
    """

    def deco(c: type) -> type:
        c.name = name
        _REGISTRY[name] = c
        return c

    return deco(cls) if cls is not None else deco


def get_solver(name: str) -> Type["SolverSpec"]:
    """String → spec class lookup for configs/CLIs; raises on unknown names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered solvers: {sorted(_REGISTRY)}"
        ) from None


def registered_solvers() -> tuple:
    return tuple(sorted(_REGISTRY))


class SolverSpec(_JsonSpecMixin):
    """Base class for declarative solver configs.

    Subclasses are frozen dataclasses whose fields are all static (hashable), so a
    spec instance can be a ``jax.jit`` static argument or a dict key. ``run`` maps
    the spec onto the underlying solver function; consumers never call it directly
    — they go through ``solve()``.

    ``needs`` declares the operator capabilities the solver consumes beyond the
    required ``mv``/``shape``/``diag_part``/``noise`` (see core/operators.py) —
    ``solve()`` verifies them before dispatch, so an SGD spec pointed at a
    matvec-only operator fails with a capability error, not an ``AttributeError``
    inside a scan.

    All built-in specs carry a ``backend`` field pinning the kernel-matvec
    backend (``"pallas"``/``"chunked"``/``"dense"``/``"auto"``; ``None`` inherits
    the operator's own setting) — ``solve()`` applies it to any operator with a
    ``backend`` field (``Gram``, ``ShardedGram``), so ``CG(backend="pallas")``
    runs every matvec of the solve through the fused differentiable Pallas
    kernel, including through the shards of a distributed solve.

    ``precision`` pins the tile precision of the kernel contractions the same
    way (``"fp32"``/``"bf16"``; ``None`` inherits the operator's setting —
    fp32 everywhere by default). bf16 tiles halve MXU operand traffic while
    accumulating in fp32; the stochastic solvers tolerate the extra tile noise
    (it is dominated by mini-batch variance), so ``SGD(precision="bf16")`` is
    the intended opt-in — exact CG convergence is precision-sensitive and
    stays fp32 unless explicitly pinned. See docs/kernels.md.
    """

    name: ClassVar[str] = "?"
    requires_key: ClassVar[bool] = False  # stochastic solvers need a PRNG key
    needs: ClassVar[Tuple[str, ...]] = ()  # operator capabilities beyond mv

    def run(
        self,
        op,
        b: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        x0: Optional[jax.Array] = None,
        delta: Optional[jax.Array] = None,
    ) -> SolveResult:
        raise NotImplementedError


def _fold_delta(op, b: jax.Array, delta: Optional[jax.Array]) -> jax.Array:
    """Fold the δ channel into the RHS: (K+σ²I)V = b + σ²δ."""
    return b if delta is None else b + op.noise * delta


@register_solver("cg")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CG(SolverSpec):
    """Conjugate gradients (§2.2.4), optionally preconditioned.

    ``precond`` is a preconditioner spec (built fresh per solve, since it depends
    on the hyperparameters) or a prebuilt ``r -> M⁻¹r`` apply. Spec builds
    call the operator's ``precond_factor`` capability and return
    ``WoodburyPrecond`` pytrees, which ride through the jitted CG as traced
    arguments — rebuilding one of the same rank reuses the compiled solve, so
    spec-valued preconds are safe inside hot outer loops. Only raw closures
    (legacy) are static arguments and recompile per identity.
    """

    max_iters: int = _static(1000)
    tol: float = _static(1e-2)
    precond: Optional[PrecondLike] = _static(None)
    backend: Optional[str] = _static(None)
    precision: Optional[str] = _static(None)
    # iterations without relative residual improvement before FLAG_STAGNATION
    # is raised on a column (advisory — see docs/robustness.md)
    stall_window: int = _static(100)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        pc = self.precond
        if pc is not None and not callable(pc):
            pc = pc.build(op, key)
        return solve_cg(
            op, _fold_delta(op, b, delta), x0,
            max_iters=self.max_iters, tol=self.tol, precond=pc,
            stall_window=self.stall_window,
        )


@register_solver("sgd")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SGD(SolverSpec):
    """Primal stochastic gradient descent (Ch. 3).

    The only solver with a *native* δ channel: δ stays in the regulariser
    (Eq. 3.6) instead of being folded into the data-fit targets, which is the
    paper's variance-reduction trick for posterior sampling.

    Beyond row-block access, the RFF regulariser samples frequencies from the
    operator's kernel and evaluates features on its inputs, so the operator must
    also expose ``x`` and ``params`` (``Gram`` and ``ShardedGram`` do).
    """

    requires_key: ClassVar[bool] = True
    needs: ClassVar[Tuple[str, ...]] = ("rows_mv", "rows_t_mv", "x", "params")

    num_steps: int = _static(20_000)
    batch_size: int = _static(512)
    num_features: int = _static(100)
    step_size_times_n: float = _static(0.5)
    momentum: float = _static(0.9)
    average_tail: float = _static(0.5)
    grad_clip: float = _static(0.1)
    tol: float = _static(1e-2)
    backend: Optional[str] = _static(None)
    precision: Optional[str] = _static(None)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        return solve_sgd(
            op, b, x0, key=key,
            num_steps=self.num_steps, batch_size=self.batch_size,
            num_features=self.num_features,
            step_size_times_n=self.step_size_times_n, momentum=self.momentum,
            average_tail=self.average_tail, grad_clip=self.grad_clip,
            delta=delta, tol=self.tol,
        )


@register_solver("sdd")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SDD(SolverSpec):
    """Stochastic dual descent (Ch. 4, Algorithm 4.1)."""

    requires_key: ClassVar[bool] = True
    needs: ClassVar[Tuple[str, ...]] = ("rows_mv",)

    num_steps: int = _static(20_000)
    batch_size: int = _static(512)
    step_size_times_n: float = _static(50.0)
    momentum: float = _static(0.9)
    averaging: Optional[float] = _static(None)
    tol: float = _static(1e-2)
    backend: Optional[str] = _static(None)
    precision: Optional[str] = _static(None)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        return solve_sdd(
            op, _fold_delta(op, b, delta), x0, key=key,
            num_steps=self.num_steps, batch_size=self.batch_size,
            step_size_times_n=self.step_size_times_n, momentum=self.momentum,
            averaging=self.averaging, tol=self.tol,
        )


@register_solver("ap")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AP(SolverSpec):
    """Alternating projections / randomised block-coordinate descent (§5.1.1)."""

    requires_key: ClassVar[bool] = True
    needs: ClassVar[Tuple[str, ...]] = ("rows_t_mv", "block_at")

    num_steps: int = _static(2000)
    block_size: int = _static(512)
    tol: float = _static(1e-2)
    backend: Optional[str] = _static(None)
    precision: Optional[str] = _static(None)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        return solve_ap(
            op, _fold_delta(op, b, delta), x0, key=key,
            num_steps=self.num_steps, block_size=self.block_size, tol=self.tol,
        )


# ---------------------------------------------------------------------------
# JSON serialization — run configs, CLIs and the benchmark harness are
# file-drivable (ROADMAP item): every spec is a tagged dict of its fields.
# ---------------------------------------------------------------------------


def _spec_to_dict(spec) -> Dict[str, Any]:
    if not dataclasses.is_dataclass(spec):
        raise TypeError(f"expected a spec dataclass, got {spec!r}")
    tag = "precond" if type(spec) in _PRECOND_REGISTRY.values() else "solver"
    if spec.name not in (_PRECOND_REGISTRY if tag == "precond" else _REGISTRY):
        raise TypeError(
            f"{type(spec).__name__} is not a registered spec; register it with "
            f"register_{tag}(name) before serializing"
        )
    d: Dict[str, Any] = {tag: spec.name}
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        if f.name == "precond" and v is not None:
            if callable(v) and not dataclasses.is_dataclass(v):
                raise TypeError(
                    "a prebuilt preconditioner apply is a runtime object and "
                    "cannot be serialized; use a Nystrom/PivotedCholesky spec"
                )
            v = _spec_to_dict(v)
        d[f.name] = v
    return d


def spec_to_dict(spec) -> Dict[str, Any]:
    """Spec (solver or preconditioner) → plain JSON-compatible dict."""
    return _spec_to_dict(spec)


def spec_from_dict(d: Dict[str, Any]):
    """Tagged dict → spec instance (inverse of :func:`spec_to_dict`)."""
    d = dict(d)
    if "solver" in d:
        cls: type = get_solver(d.pop("solver"))
    elif "precond" in d:
        cls = get_precond(d.pop("precond"))
    else:
        raise ValueError(
            "spec dict must be tagged with a 'solver' or 'precond' name; "
            f"got keys {sorted(d)}"
        )
    if isinstance(d.get("precond"), dict):
        d["precond"] = spec_from_dict(d["precond"])
    return cls(**d)


def spec_to_json(spec, **dumps_kwargs: Any) -> str:
    return json.dumps(_spec_to_dict(spec), **dumps_kwargs)


def spec_from_json(s: str):
    return spec_from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Normalisation: names / classes / instances
# ---------------------------------------------------------------------------

SpecLike = Union[str, SolverSpec, Type[SolverSpec]]


def as_spec(spec: SpecLike, **overrides: Any) -> SolverSpec:
    """Normalise a spec instance, spec class, or registered name to an instance.

    ``overrides`` are spec fields applied on top (``as_spec("cg", max_iters=50)``).
    """
    if isinstance(spec, str):
        spec = get_solver(spec)
    if isinstance(spec, type) and issubclass(spec, SolverSpec):
        return spec(**overrides)
    if isinstance(spec, SolverSpec):
        return dataclasses.replace(spec, **overrides) if overrides else spec
    raise TypeError(
        f"expected a SolverSpec, spec class, or registered solver name; got {spec!r}"
    )


# ---------------------------------------------------------------------------
# The single entry point
# ---------------------------------------------------------------------------


def _validate_x0(op, b: jax.Array, x0: jax.Array) -> None:
    """Warm-start sanity checks, up front and with a clear error.

    A stale warm-start cache (serving engine, MLL outer loop) otherwise surfaces
    as an opaque XLA broadcast/shape error deep inside the solver's
    while_loop/scan; here it names the mismatch at the ``solve()`` boundary.
    The rule is strict: ``x0`` must match ``b``'s shape exactly — a 1-D ``x0``
    against a multi-column ``b`` is refused rather than silently broadcast,
    because it almost always means a cached single-RHS solution is being reused
    for a differently-batched solve.
    """
    b_shape, x_shape = jnp.shape(b), jnp.shape(x0)
    if x_shape != b_shape:
        n = op.shape[0]
        raise ValueError(
            f"warm start x0 has shape {x_shape} but the right-hand side has "
            f"shape {b_shape} (operator is {n}×{n}); x0 must match b exactly — "
            f"a stale warm-start cache entry (old n after new observations, or "
            f"a different RHS column batch) is the usual cause. Drop x0 for a "
            f"cold solve, or re-key the cache."
        )
    b_dtype = jnp.result_type(b)
    x_dtype = jnp.result_type(x0)
    if x_dtype != b_dtype:
        raise TypeError(
            f"warm start x0 has dtype {x_dtype.name} but the right-hand side "
            f"has dtype {b_dtype.name}; pass x0 in the RHS dtype — a silent "
            f"promotion here would retrace the compiled solve and mask cache "
            f"bugs."
        )


def solve(
    op,
    b: jax.Array,
    spec: SpecLike = "cg",
    *,
    key: Optional[jax.Array] = None,
    x0: Optional[jax.Array] = None,
    delta: Optional[jax.Array] = None,
    **overrides: Any,
) -> SolveResult:
    """Solve (K+σ²I)V = b + σ²δ with any registered solver on any operator.

    Args:
        op: a :class:`~repro.core.operators.LinearOperator` — ``Gram``,
            ``NormalEq``, ``LatentKroneckerOp``, ``ShardedGram``, or any
            operator implementing the protocol. Capability dispatch: the spec's
            ``needs`` (row-block access for SGD/SDD/AP, ``precond_factor`` for
            preconditioner builds) are verified up front with a clear error.
        b: right-hand side(s), ``(n,)`` or ``(n, s)``.
        spec: a ``SolverSpec`` instance, spec class, or registered name
            (``"cg"``, ``"sgd"``, ``"sdd"``, ``"ap"``).
        key: PRNG key; required by stochastic solvers, used by CG only to draw the
            Nyström preconditioner subset.
        x0: optional warm start (Ch. 5 §5.3), same shape as ``b``.
        delta: optional δ channel, same shape as ``b`` — the system solved becomes
            ``(K+σ²I)V = b + σ²δ``, with SGD keeping δ in its regulariser
            (Eq. 3.6) and everything else folding it into the RHS.
        **overrides: spec-field overrides, e.g. ``solve(op, b, "cg", max_iters=50)``.
    """
    s = as_spec(spec, **overrides)
    backend = getattr(s, "backend", None)
    if backend is not None:
        # Gram backend names plus the feature names ("features" pins the
        # materialised path on feature-backed operators like RFFGram; the Gram
        # dispatch rejects it with its own error if pinned on a Gram operator)
        known = BACKENDS + tuple(b for b in FEATURE_BACKENDS if b not in BACKENDS)
        if backend not in known:
            raise ValueError(f"unknown backend {backend!r}; expected one of {known}")
        if (
            dataclasses.is_dataclass(op)
            and getattr(op, "backend", backend) != backend
        ):
            # the spec pins the kernel-matvec backend for this solve
            op = dataclasses.replace(op, backend=backend)
    precision = getattr(s, "precision", None)
    if precision is not None:
        if precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {precision!r}; expected one of {PRECISIONS}"
            )
        if (
            dataclasses.is_dataclass(op)
            and getattr(op, "precision", precision) != precision
        ):
            # the spec pins the kernel tile precision for this solve
            op = dataclasses.replace(op, precision=precision)
    if s.requires_key and key is None:
        raise ValueError(
            f"solver {s.name!r} is stochastic: solve(..., key=jax.random.PRNGKey(...))"
            " is required"
        )
    if x0 is not None:
        _validate_x0(op, b, x0)
    require_capabilities(op, s.needs, consumer=f"solver {s.name!r}")
    prep = getattr(op, "prepare_for_solve", None)
    if callable(prep):
        # per-solve setup hook, run once outside the solver's while_loop/scan —
        # e.g. ShardedGram(gather_once=True) gathers its sharded inputs here
        # instead of all-gathering on every matvec
        op = prep()
    return s.run(op, b, key=key, x0=x0, delta=delta)


# ---------------------------------------------------------------------------
# Multi-RHS coalescing on top of solve() — the serving engine's primitive
# ---------------------------------------------------------------------------


def solve_batched(
    op,
    blocks,
    spec: SpecLike = "cg",
    *,
    key: Optional[jax.Array] = None,
    x0_blocks=None,
    delta_blocks=None,
    pad_columns_to: Optional[int] = None,
    **overrides: Any,
) -> list:
    """Coalesce per-consumer RHS column blocks into ONE multi-RHS solve.

    This is the paper's continuous-batching primitive made explicit: k callers
    each bring a small RHS block against the *same* operator, the blocks are
    stacked column-wise, solved in one call to :func:`solve` (one matvec stream
    serves everyone — CG's per-iteration cost is one fused multi-RHS matvec
    regardless of k), and the result is scattered back as one ``SolveResult``
    per block. ``iterations``/``matvecs`` on each returned result are the
    *shared* batch totals — that sharing is the whole point — while
    ``residual_norm``/``rel_residual``/``converged`` are per-block.

    Args:
        blocks: sequence of RHS blocks, each ``(n,)`` or ``(n, s_i)``.
        x0_blocks: optional warm starts, one per block (``None`` entries are
            cold and solved from zero); if every entry is ``None`` the batch is
            a cold solve.
        delta_blocks: optional δ channels, one per block (``None`` entries get
            δ = 0).
        pad_columns_to: pad the stacked RHS with zero columns up to this count —
            the serving engine's fixed bucket shapes, so batches of 3 and 5
            requests hit the same compiled solve. Zero columns converge
            immediately (CG freezes them on the spot) and are sliced off.

    Returns:
        ``[SolveResult, ...]``, one per input block, in order; solutions are
        squeezed back to 1-D for 1-D input blocks.
    """
    s = as_spec(spec, **overrides)
    blocks = list(blocks)
    if not blocks:
        return []
    mats, squeezes = [], []
    for blk in blocks:
        m, sq = as_matrix_rhs(jnp.asarray(blk))
        mats.append(m)
        squeezes.append(sq)
    widths = [m.shape[1] for m in mats]
    offsets = [0]
    for w in widths:
        offsets.append(offsets[-1] + w)
    total = offsets[-1]
    n = mats[0].shape[0]

    def _stack(maybe_blocks, what):
        if maybe_blocks is None:
            return None
        maybe_blocks = list(maybe_blocks)
        if len(maybe_blocks) != len(blocks):
            raise ValueError(
                f"{what} has {len(maybe_blocks)} blocks for {len(blocks)} RHS "
                f"blocks; pass one entry per block (None for missing)"
            )
        if all(e is None for e in maybe_blocks):
            return None
        cols = []
        for e, w in zip(maybe_blocks, widths):
            if e is None:
                cols.append(jnp.zeros((n, w), dtype=mats[0].dtype))
            else:
                cols.append(as_matrix_rhs(jnp.asarray(e))[0])
        return jnp.concatenate(cols, axis=1)

    b = jnp.concatenate(mats, axis=1)
    x0 = _stack(x0_blocks, "x0_blocks")
    delta = _stack(delta_blocks, "delta_blocks")
    if pad_columns_to is not None and pad_columns_to > total:
        pad = pad_columns_to - total
        zeros = jnp.zeros((n, pad), dtype=b.dtype)
        b = jnp.concatenate([b, zeros], axis=1)
        if x0 is not None:
            x0 = jnp.concatenate([x0, zeros], axis=1)
        if delta is not None:
            delta = jnp.concatenate([delta, zeros], axis=1)

    res = solve(op, b, s, key=key, x0=x0, delta=delta)
    tol = float(getattr(s, "tol", 1e-2))
    flags_full = jnp.asarray(res.flags, dtype=jnp.int32)
    if flags_full.ndim == 0:
        flags_full = jnp.broadcast_to(flags_full, res.rel_residual.shape)
    out = []
    for (lo, hi), sq in zip(zip(offsets[:-1], offsets[1:]), squeezes):
        sol = res.solution[:, lo:hi]
        rel = res.rel_residual[lo:hi]
        fl = flags_full[lo:hi]
        out.append(
            SolveResult(
                solution=sol[:, 0] if sq else sol,
                residual_norm=res.residual_norm[lo:hi],
                rel_residual=rel,
                iterations=res.iterations,
                # per-block convergence is flag-aware, like finalize():
                # a flagged column in THIS block fails this block only
                converged=jnp.all((rel <= tol) & (fl == 0)),
                matvecs=res.matvecs,
                flags=fl,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Bordered-system (rank-k) extension on top of solve_batched — the serving
# engine's incremental-update primitive
# ---------------------------------------------------------------------------


def solve_bordered(
    op,
    b_cols: jax.Array,
    c_new: jax.Array,
    rhs_new: jax.Array,
    sol_old: jax.Array,
    spec: SpecLike = "cg",
    *,
    key: Optional[jax.Array] = None,
    x0: Optional[jax.Array] = None,
    **overrides: Any,
) -> Tuple[jax.Array, SolveResult]:
    """Extend a solved system by k rows via the bordered-system identity.

    Given ``sol_old`` with (A = K_old + σ²I)·sol_old ≈ rhs_old already solved,
    and k appended inputs with cross-covariance block B = K(X_old, X_new)
    (``b_cols``, (n, k)), new-block covariance C = K(X_new, X_new) (``c_new``,
    (k, k), WITHOUT noise — σ²I is added here, from ``op.noise``), and bottom
    RHS rows ``rhs_new`` ((k, m)), the extended system

        [ A   B ] [u]   [rhs_old]
        [ Bᵀ  C+σ²I ] [w] = [rhs_new]

    is solved without ever touching the (n+k)-operator:

        Z = A⁻¹ B                       (ONE multi-RHS solve, k columns, old n)
        S = (C + σ²I) − Bᵀ Z            (k×k Schur complement, Cholesky)
        w = S⁻¹ (rhs_new − Bᵀ sol_old)  (closed-form back-substitution)
        u = sol_old − Z w

    so the iterative cost is k correction columns against the OLD operator —
    independent of how many RHS columns m ride the update (they all share Z) —
    instead of a fresh m-column solve at n+k. The Z solve goes through
    :func:`solve_batched`, so it is warm-startable (``x0``, e.g. the Z of a
    previous update at nearby hyperparameters) and its iteration/matvec
    accounting comes back as a standard per-block :class:`SolveResult`.

    Exactness: with Z and sol_old exact, the returned solution satisfies the
    extended system exactly (the identity is algebra, not approximation); with
    iterative Z/sol_old, the top-block residual is r_old − (B − AZ)·w, so
    accumulated drift is observable with ONE extended-operator matvec — see
    ``serve.state.update_state_lowrank``, which certifies exactly that way.

    Returns ``(solution (n+k, m), z_result)`` where ``z_result`` is the
    correction solve's :class:`SolveResult` (its per-column ``flags``/
    ``rel_residual`` refer to the k Z columns).
    """
    s = as_spec(spec, **overrides)
    b_cols = jnp.asarray(b_cols)
    if b_cols.ndim != 2:
        raise ValueError(f"b_cols must be (n, k); got shape {jnp.shape(b_cols)}")
    n, k = b_cols.shape
    c_new = jnp.asarray(c_new)
    if c_new.shape != (k, k):
        raise ValueError(
            f"c_new must be ({k}, {k}) to match b_cols' {k} columns; got "
            f"{c_new.shape}"
        )
    sol_old, _ = as_matrix_rhs(jnp.asarray(sol_old))
    rhs_new, _ = as_matrix_rhs(jnp.asarray(rhs_new))
    if sol_old.shape[0] != n or rhs_new.shape[0] != k:
        raise ValueError(
            f"sol_old rows ({sol_old.shape[0]}) must match the old n ({n}) and "
            f"rhs_new rows ({rhs_new.shape[0]}) the k new rows ({k})"
        )
    (z_result,) = solve_batched(
        op, [b_cols], s, key=key,
        x0_blocks=None if x0 is None else [x0],
    )
    z = z_result.solution  # (n, k) = A⁻¹ B
    schur = c_new + op.noise * jnp.eye(k, dtype=b_cols.dtype) - b_cols.T @ z
    # symmetrise the fp drift from the iterative Z before factorizing — S is
    # S.P.D. by the Schur-complement theorem whenever the extended Gram is
    schur = 0.5 * (schur + schur.T)
    cho = cho_factor(schur, lower=True)
    w = cho_solve(cho, rhs_new - b_cols.T @ sol_old)  # (k, m)
    u = sol_old - z @ w  # (n, m)
    return jnp.concatenate([u, w], axis=0), z_result
