"""Declarative solver configuration and the library's single ``solve()`` entry point.

The paper's thesis is that *every* expensive GP computation — pathwise posterior
samples (Ch. 3), MLL gradients (Ch. 5), Thompson steps (§3.3.2) — reduces to one
batched multi-RHS linear solve against interchangeable iterative solvers. This module
makes that interchangeability a first-class API instead of an accident of call sites:

* frozen, pytree-registered spec dataclasses describe *how* to solve
  (``CG``, ``SGD``, ``SDD``, ``AP``) and how to precondition (``Nystrom``,
  ``PivotedCholesky``);
* a registry maps string names (``"cg"``/``"sgd"``/``"sdd"``/``"ap"``) to spec
  classes so configs, CLIs and serialized runs can name solvers;
* ``solve(op, b, spec, key=..., x0=..., delta=...)`` uniformly handles PRNG keys,
  warm starts and preconditioner construction for all of them.

The system solved is always

    (K + σ²I) V = b + σ² δ

where ``delta`` is an optional extra channel: pathwise sampling passes δ = ε/σ² so
SGD can keep the noise draw out of its mini-batch data-fit term (the Eq. 3.6
variance-reduction shift); solvers without a native δ channel fold σ²δ into the
right-hand side, which is algebraically identical.

Specs carry only static (hashable) configuration, so they can cross ``jax.jit``
boundaries as static arguments and serve as cache keys for compiled solves.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, ClassVar, Dict, Optional, Type, Union

import jax

from ..precond import nystrom_preconditioner, pivoted_cholesky_preconditioner
from .ap import solve_ap
from .base import Gram, SolveResult
from .cg import solve_cg
from .sdd import solve_sdd
from .sgd import solve_sgd


def _static(default):
    return dataclasses.field(default=default, metadata=dict(static=True))


def _require_gram(op, what: str):
    if not isinstance(op, Gram):
        raise TypeError(
            f"{what} needs the training inputs and kernel hyperparameters, which "
            f"only a Gram operator carries; got {type(op).__name__}"
        )


# ---------------------------------------------------------------------------
# Preconditioner specs (§2.2.4; built on core/precond.py)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Nystrom:
    """Uniform-subset Nyström preconditioner: rank-m surrogate + Woodbury apply."""

    rank: int = _static(100)

    def build(self, op: Gram, key: Optional[jax.Array] = None) -> Callable:
        _require_gram(op, "the Nyström preconditioner")
        key = jax.random.PRNGKey(0) if key is None else key
        return nystrom_preconditioner(op.params, op.x, key, rank=self.rank)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PivotedCholesky:
    """Greedy pivoted-Cholesky preconditioner (paper fidelity; sequential build)."""

    rank: int = _static(100)

    def build(self, op: Gram, key: Optional[jax.Array] = None) -> Callable:
        _require_gram(op, "the pivoted-Cholesky preconditioner")
        return pivoted_cholesky_preconditioner(op.params, op.x, rank=self.rank)


PrecondSpec = Union[Nystrom, PivotedCholesky]
# a raw ``r -> M⁻¹r`` callable is also accepted wherever a PrecondSpec fits
PrecondLike = Union[Nystrom, PivotedCholesky, Callable]


# ---------------------------------------------------------------------------
# Solver specs + registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["SolverSpec"]] = {}


def register_solver(name: str, cls: Optional[type] = None):
    """Register a spec class under a string name (usable as a decorator).

    Third-party solvers plug in the same way the built-ins do: subclass
    ``SolverSpec``, implement ``run``, and ``register_solver("mine", MySpec)`` —
    every consumer (``posterior_functions``, ``mll_grad``, ``thompson_step``, …)
    then accepts ``spec="mine"`` without being edited.
    """

    def deco(c: type) -> type:
        c.name = name
        _REGISTRY[name] = c
        return c

    return deco(cls) if cls is not None else deco


def get_solver(name: str) -> Type["SolverSpec"]:
    """String → spec class lookup for configs/CLIs; raises on unknown names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered solvers: {sorted(_REGISTRY)}"
        ) from None


def registered_solvers() -> tuple:
    return tuple(sorted(_REGISTRY))


class SolverSpec:
    """Base class for declarative solver configs.

    Subclasses are frozen dataclasses whose fields are all static (hashable), so a
    spec instance can be a ``jax.jit`` static argument or a dict key. ``run`` maps
    the spec onto the underlying solver function; consumers never call it directly
    — they go through ``solve()``.
    """

    name: ClassVar[str] = "?"
    requires_key: ClassVar[bool] = False  # stochastic solvers need a PRNG key
    needs_rows: ClassVar[bool] = False  # needs op.rows (kernel row gathers)

    def run(
        self,
        op,
        b: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        x0: Optional[jax.Array] = None,
        delta: Optional[jax.Array] = None,
    ) -> SolveResult:
        raise NotImplementedError


def _fold_delta(op, b: jax.Array, delta: Optional[jax.Array]) -> jax.Array:
    """Fold the δ channel into the RHS: (K+σ²I)V = b + σ²δ."""
    return b if delta is None else b + op.noise * delta


@register_solver("cg")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CG(SolverSpec):
    """Conjugate gradients (§2.2.4), optionally preconditioned.

    ``precond`` is a preconditioner spec (built fresh per solve, since it depends
    on the hyperparameters) or a prebuilt ``r -> M⁻¹r`` callable. A spec-valued
    ``precond`` makes every solve pass a fresh closure to the jitted CG (closures
    hash by identity as static args ⇒ recompile per call); inside a hot outer
    loop with *fixed* hyperparameters, prebuild the callable once and pass that
    instead.
    """

    max_iters: int = _static(1000)
    tol: float = _static(1e-2)
    precond: Optional[PrecondLike] = _static(None)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        pc = self.precond
        if pc is not None and not callable(pc):
            pc = pc.build(op, key)
        return solve_cg(
            op, _fold_delta(op, b, delta), x0,
            max_iters=self.max_iters, tol=self.tol, precond=pc,
        )


@register_solver("sgd")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SGD(SolverSpec):
    """Primal stochastic gradient descent (Ch. 3).

    The only solver with a *native* δ channel: δ stays in the regulariser
    (Eq. 3.6) instead of being folded into the data-fit targets, which is the
    paper's variance-reduction trick for posterior sampling.
    """

    requires_key: ClassVar[bool] = True
    needs_rows: ClassVar[bool] = True

    num_steps: int = _static(20_000)
    batch_size: int = _static(512)
    num_features: int = _static(100)
    step_size_times_n: float = _static(0.5)
    momentum: float = _static(0.9)
    average_tail: float = _static(0.5)
    grad_clip: float = _static(0.1)
    tol: float = _static(1e-2)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        return solve_sgd(
            op, b, x0, key=key,
            num_steps=self.num_steps, batch_size=self.batch_size,
            num_features=self.num_features,
            step_size_times_n=self.step_size_times_n, momentum=self.momentum,
            average_tail=self.average_tail, grad_clip=self.grad_clip,
            delta=delta, tol=self.tol,
        )


@register_solver("sdd")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SDD(SolverSpec):
    """Stochastic dual descent (Ch. 4, Algorithm 4.1)."""

    requires_key: ClassVar[bool] = True
    needs_rows: ClassVar[bool] = True

    num_steps: int = _static(20_000)
    batch_size: int = _static(512)
    step_size_times_n: float = _static(50.0)
    momentum: float = _static(0.9)
    averaging: Optional[float] = _static(None)
    tol: float = _static(1e-2)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        return solve_sdd(
            op, _fold_delta(op, b, delta), x0, key=key,
            num_steps=self.num_steps, batch_size=self.batch_size,
            step_size_times_n=self.step_size_times_n, momentum=self.momentum,
            averaging=self.averaging, tol=self.tol,
        )


@register_solver("ap")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AP(SolverSpec):
    """Alternating projections / randomised block-coordinate descent (§5.1.1)."""

    requires_key: ClassVar[bool] = True
    needs_rows: ClassVar[bool] = True

    num_steps: int = _static(2000)
    block_size: int = _static(512)
    tol: float = _static(1e-2)

    def run(self, op, b, *, key=None, x0=None, delta=None) -> SolveResult:
        return solve_ap(
            op, _fold_delta(op, b, delta), x0, key=key,
            num_steps=self.num_steps, block_size=self.block_size, tol=self.tol,
        )


# ---------------------------------------------------------------------------
# Normalisation: names / classes / instances / legacy `solver=fn` calls
# ---------------------------------------------------------------------------

SpecLike = Union[str, SolverSpec, Type[SolverSpec]]

# legacy-shim mapping: old-style `solver=<function>` arguments → spec class
_LEGACY_SOLVERS: Dict[Callable, Type[SolverSpec]] = {
    solve_cg: CG,
    solve_sgd: SGD,
    solve_sdd: SDD,
    solve_ap: AP,
}


def as_spec(spec: SpecLike, **overrides: Any) -> SolverSpec:
    """Normalise a spec instance, spec class, or registered name to an instance.

    ``overrides`` are spec fields applied on top (``as_spec("cg", max_iters=50)``).
    """
    if isinstance(spec, str):
        spec = get_solver(spec)
    if isinstance(spec, type) and issubclass(spec, SolverSpec):
        return spec(**overrides)
    if isinstance(spec, SolverSpec):
        return dataclasses.replace(spec, **overrides) if overrides else spec
    raise TypeError(
        f"expected a SolverSpec, spec class, or registered solver name; got {spec!r}"
    )


def coerce_spec(
    spec: Optional[SpecLike] = None,
    *,
    solver: Optional[Callable] = None,
    default: SpecLike = "cg",
    **overrides: Any,
) -> SolverSpec:
    """Resolve new-style ``spec=...`` and legacy ``solver=fn, **kwargs`` arguments.

    Consumers (``posterior_functions``, ``mll_grad``, ``thompson_step``, …) route
    their keyword surface through this single function: the legacy path warns and
    maps the solver function to its spec class; extra keyword arguments become
    spec-field overrides in both worlds.
    """
    if solver is not None:
        if spec is not None:
            raise TypeError("pass either spec=... or the legacy solver=...; not both")
        cls = _LEGACY_SOLVERS.get(solver)
        if cls is None:
            raise TypeError(
                f"unrecognised legacy solver function {solver!r}; pass a SolverSpec "
                f"or one of the registered names {sorted(_REGISTRY)} instead"
            )
        warnings.warn(
            f"solver=solve_{cls.name} with per-solver keyword arguments is "
            f"deprecated; pass spec={cls.__name__}(...) or spec={cls.name!r} instead",
            DeprecationWarning,
            stacklevel=3,
        )
        spec = cls
    return as_spec(default if spec is None else spec, **overrides)


# ---------------------------------------------------------------------------
# The single entry point
# ---------------------------------------------------------------------------


def solve(
    op,
    b: jax.Array,
    spec: SpecLike = "cg",
    *,
    key: Optional[jax.Array] = None,
    x0: Optional[jax.Array] = None,
    delta: Optional[jax.Array] = None,
    **overrides: Any,
) -> SolveResult:
    """Solve (K+σ²I)V = b + σ²δ with any registered solver.

    Args:
        op: linear operator — a ``Gram``, or any matvec-only operator with ``mv``
            (and ``noise`` when ``delta`` is used) for CG-family specs.
        b: right-hand side(s), ``(n,)`` or ``(n, s)``.
        spec: a ``SolverSpec`` instance, spec class, or registered name
            (``"cg"``, ``"sgd"``, ``"sdd"``, ``"ap"``).
        key: PRNG key; required by stochastic solvers, used by CG only to draw the
            Nyström preconditioner subset.
        x0: optional warm start (Ch. 5 §5.3), same shape as ``b``.
        delta: optional δ channel, same shape as ``b`` — the system solved becomes
            ``(K+σ²I)V = b + σ²δ``, with SGD keeping δ in its regulariser
            (Eq. 3.6) and everything else folding it into the RHS.
        **overrides: spec-field overrides, e.g. ``solve(op, b, "cg", max_iters=50)``.
    """
    s = as_spec(spec, **overrides)
    if s.requires_key and key is None:
        raise ValueError(
            f"solver {s.name!r} is stochastic: solve(..., key=jax.random.PRNGKey(...))"
            " is required"
        )
    if s.needs_rows and not hasattr(op, "rows"):
        raise TypeError(
            f"solver {s.name!r} needs kernel-row access (op.rows); operator "
            f"{type(op).__name__} only supports matvecs — use a CG spec"
        )
    return s.run(op, b, key=key, x0=x0, delta=delta)
