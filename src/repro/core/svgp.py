"""Sparse variational GP baselines (§2.2.1) — the paper's main comparison methods.

* ``sgpr``: Titsias (2009) collapsed bound  L_SGPR(Z) = log N(y|0, Q_XX+σ²I) − tr-term
  (Eq. 2.47) with the exact optimal q; predictive Eqs. 2.49/2.50.
* ``sgpr_iterative``: the same posterior with every application of the Titsias
  matrix B = K_ZZ + σ⁻²K_ZX K_XZ routed through the unified ``solve()`` on the
  matvec-only :class:`~repro.core.operators.NormalEq` operator (note
  σ²·B = K_ZX K_XZ + σ²K_ZZ) — the n×m cross-covariance and the m×m B are never
  materialised, so the dense-Cholesky O(n·m²) assembly becomes O(n·m) per solver
  iteration and any CG-family SolverSpec (warm starts, matvec accounting, JSON
  configs) drives it.
* ``svgp_fit``: Hensman et al. (2013) stochastic variational inference with explicit
  (m, S) posterior and natural-gradient steps (Eqs. 2.53/2.54) on mini-batches.

Pathwise sampling from the SVGP posterior uses Eq. 3.13 machinery via core/inducing.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams, gram, matvec
from .operators import NormalEq
from .solvers.spec import CG, SolverSpec, SpecLike, as_spec, solve


class SGPRPosterior(NamedTuple):
    params: KernelParams
    z: jax.Array
    chol_b: jax.Array  # chol(K_ZZ + σ⁻²K_ZX K_XZ)
    chol_kzz: jax.Array
    proj_y: jax.Array  # σ⁻² (K_ZZ + σ⁻²K_ZX K_XZ)⁻¹ K_ZX y

    def mean(self, xs: jax.Array) -> jax.Array:
        return gram(self.params, xs, self.z) @ self.proj_y

    def var(self, xs: jax.Array) -> jax.Array:
        kxz = gram(self.params, xs, self.z)  # (n*, m)
        a = jax.scipy.linalg.solve_triangular(self.chol_kzz, kxz.T, lower=True)
        b = jax.scipy.linalg.solve_triangular(self.chol_b, kxz.T, lower=True)
        kss = self.params.signal * jnp.ones(xs.shape[0])
        return kss - jnp.sum(a * a, axis=0) + jnp.sum(b * b, axis=0)


def sgpr(params: KernelParams, x: jax.Array, y: jax.Array, z: jax.Array) -> SGPRPosterior:
    m = z.shape[0]
    sigma2 = params.noise
    kzz = gram(params, z) + 1e-5 * params.signal * jnp.eye(m)
    kzx = gram(params, z, x)
    b = kzz + (kzx @ kzx.T) / sigma2
    # fp32 rounding in K_ZX K_XZ can push the smallest eigenvalue slightly negative
    # (scale ~ n·κ/σ²); ridge proportional to the matrix scale keeps chol finite
    b = b + (3e-5 * jnp.trace(b) / m) * jnp.eye(m)
    chol_b = jnp.linalg.cholesky(b)
    proj_y = jax.scipy.linalg.cho_solve((chol_b, True), kzx @ y) / sigma2
    return SGPRPosterior(
        params=params,
        z=z,
        chol_b=chol_b,
        chol_kzz=jnp.linalg.cholesky(kzz),
        proj_y=proj_y,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IterativeSGPRPosterior:
    """SGPR posterior whose B⁻¹ applications run through ``solve(NormalEq, …)``.

    Predictive equations (Eqs. 2.49/2.50) need B⁻¹ twice: once for the
    projected-mean weights (done at construction) and once per prediction batch
    for the variance quadratic k_sZ B⁻¹ k_Zs. Both are iterative solves against
    the m×m normal-equations operator — only K_ZZ's m×m Cholesky (for the
    Q_XX-correction term) is ever factorised densely.
    """

    params: KernelParams
    z: jax.Array  # (m, d) inducing inputs
    chol_kzz: jax.Array  # (m, m) lower Cholesky of K_ZZ (+ stabilising jitter)
    proj_y: jax.Array  # (m,) = σ⁻² B⁻¹ K_ZX y, via solve(NormalEq, K_ZX y)
    op: NormalEq  # σ²·B, touched only through matvecs
    spec: SolverSpec  # CG-family spec driving the B⁻¹ applications

    def mean(self, xs: jax.Array) -> jax.Array:
        return gram(self.params, xs, self.z) @ self.proj_y

    def var(self, xs: jax.Array) -> jax.Array:
        kxz = gram(self.params, xs, self.z)  # (n*, m)
        a = jax.scipy.linalg.solve_triangular(self.chol_kzz, kxz.T, lower=True)
        # k_sZ B⁻¹ k_Zs = σ² · k_sZ (σ²B)⁻¹ k_Zs — one batched NormalEq solve
        u = solve(self.op, kxz.T, self.spec).solution  # (m, n*)
        quad = self.params.noise * jnp.sum(kxz.T * u, axis=0)
        kss = self.params.signal * jnp.ones(xs.shape[0])
        return kss - jnp.sum(a * a, axis=0) + quad


def sgpr_iterative(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    z: jax.Array,
    *,
    spec: Optional[SpecLike] = None,
    key: Optional[jax.Array] = None,
    row_chunk: int = 4096,
) -> IterativeSGPRPosterior:
    """Titsias posterior via iterative solves — the ``solve()``-backed SGPR path.

    ``spec`` must be a matvec-only (CG-family) spec; the default
    ``CG(max_iters=400, tol=1e-6)`` is deliberately tight because the
    normal-equations operator is ill-conditioned (κ(K_XZ)²-ish) and a loose
    per-column tolerance stops refinement long before the *prediction-space*
    error is small.
    """
    s = as_spec(CG(max_iters=400, tol=1e-6) if spec is None else spec)
    m = z.shape[0]
    op = NormalEq(x=x, z=z, params=params, row_chunk=row_chunk)
    # reproduce the dense path's fp32-stabilising ridge on B exactly:
    # B_r = B + 3e-5·tr(B)/m · I  ⇔  σ²B_r = NormalEq + 3e-5·tr(NormalEq)/m · I
    op = dataclasses.replace(op, ridge=3e-5 * jnp.sum(op.diag_part()) / m)
    rhs = matvec(params, z, y, z=x, row_chunk=row_chunk)  # K_ZX y, chunked
    proj_y = solve(op, rhs, s, key=key).solution  # = σ⁻² B⁻¹ K_ZX y
    kzz = gram(params, z) + 1e-5 * params.signal * jnp.eye(m)
    return IterativeSGPRPosterior(
        params=params, z=z, chol_kzz=jnp.linalg.cholesky(kzz), proj_y=proj_y,
        op=op, spec=s,
    )


def sgpr_elbo(params: KernelParams, x: jax.Array, y: jax.Array, z: jax.Array) -> jax.Array:
    """Collapsed bound (Eq. 2.47): log N(y|0, Q+σ²I) − tr(K−Q)/(2σ²)."""
    n, m = x.shape[0], z.shape[0]
    sigma2 = params.noise
    kzz = gram(params, z) + 1e-5 * params.signal * jnp.eye(m)
    kzx = gram(params, z, x)
    lz = jnp.linalg.cholesky(kzz)
    a = jax.scipy.linalg.solve_triangular(lz, kzx, lower=True) / jnp.sqrt(sigma2)  # (m,n)
    b = jnp.eye(m) + a @ a.T
    lb = jnp.linalg.cholesky(b)
    c = jax.scipy.linalg.solve_triangular(lb, a @ y, lower=True) / jnp.sqrt(sigma2)
    log_det = jnp.sum(jnp.log(jnp.diag(lb))) + 0.5 * n * jnp.log(sigma2)
    quad = 0.5 * (jnp.dot(y, y) / sigma2 - jnp.dot(c, c))
    trace = 0.5 / sigma2 * (params.signal * n - sigma2 * jnp.sum(a * a))
    return -log_det - quad - 0.5 * n * jnp.log(2 * jnp.pi) - trace


@dataclasses.dataclass
class SVGPState:
    theta1: jax.Array  # S⁻¹ m natural parameter (m,)
    theta2: jax.Array  # −½ S⁻¹ (m, m)


def svgp_natgrad_step(
    params: KernelParams,
    x_batch: jax.Array,
    y_batch: jax.Array,
    z: jax.Array,
    state: SVGPState,
    n_total: int,
    lr: float = 0.5,
) -> SVGPState:
    """One natural-gradient step (Eqs. 2.53/2.54), mini-batch scaled."""
    m = z.shape[0]
    sigma2 = params.noise
    kzz = gram(params, z) + 1e-5 * params.signal * jnp.eye(m)
    chol = jnp.linalg.cholesky(kzz)
    kzb = gram(params, z, x_batch)  # (m, b)
    # K_ZZ⁻¹ applied via cholesky solves (fp32 inv() of an ill-conditioned SE gram
    # corrupts the natural-gradient target by O(0.5) in prediction space)
    a = jax.scipy.linalg.cho_solve((chol, True), kzb)  # K_ZZ⁻¹ K_Zb  (m, b)
    scale = n_total / x_batch.shape[0]
    lam = (a @ a.T) * (scale / sigma2) + jax.scipy.linalg.cho_solve(
        (chol, True), jnp.eye(m))
    t1_target = (a @ y_batch) * (scale / sigma2)
    theta1 = state.theta1 + lr * (t1_target - state.theta1)
    theta2 = state.theta2 + lr * (-0.5 * lam - state.theta2)
    return SVGPState(theta1=theta1, theta2=theta2)


def svgp_mean_var(params: KernelParams, z: jax.Array, state: SVGPState, xs: jax.Array):
    prec = -2.0 * state.theta2
    prec = prec + (1e-6 * jnp.trace(prec) / prec.shape[0]) * jnp.eye(prec.shape[0])
    chol_p = jnp.linalg.cholesky(prec)
    s_cov = jax.scipy.linalg.cho_solve((chol_p, True), jnp.eye(prec.shape[0]))
    mu = jax.scipy.linalg.cho_solve((chol_p, True), state.theta1)
    m = z.shape[0]
    kzz = gram(params, z) + 1e-5 * params.signal * jnp.eye(m)
    chol = jnp.linalg.cholesky(kzz)
    ksz = gram(params, xs, z)
    a = jax.scipy.linalg.cho_solve((chol, True), ksz.T).T  # K_sZ K_ZZ⁻¹
    mean = a @ mu
    var = (
        params.signal
        - jnp.sum(a * ksz, axis=1)
        + jnp.sum((a @ s_cov) * a, axis=1)
    )
    return mean, var
