"""Large-scale parallel Thompson sampling (§3.3.2, Fig. 3.6/3.7; §4.3.2 Fig. 4.4).

Each acquisition step draws `acq_batch` posterior *function* samples via pathwise
conditioning (one batched solve), then maximises every sample with the paper's
multi-start strategy: explore (uniform) + exploit (perturbed incumbents) candidates →
top-k by sample value → Adam ascent on the sample function → acquire the argmaxes.
Pathwise conditioning is what makes this possible: each sample is a cheap
deterministic function evaluable at every Adam iterate.

The ascent differentiates through the posterior samples — prior feature matvec
Φ(x)w plus cross-covariance matvec — and both primitives carry custom VJPs
(kernels/rff_matvec.py, kernels/gram_matvec.py), so on TPU every one of the
thousands of Adam gradient evaluations runs through fused Pallas tiles without
materialising features or cross-Gram panels (the FeatureOperator protocol,
docs/features.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .kernels_fn import KernelParams
from .pathwise import PosteriorFunctions, posterior_functions
from .solvers.spec import SpecLike, as_spec


@dataclasses.dataclass
class ThompsonState:
    x: jax.Array  # (n, d) observed inputs
    y: jax.Array  # (n,)
    best: float


def _maximise_samples(
    post: PosteriorFunctions,
    y: jax.Array,
    key: jax.Array,
    *,
    num_candidates: int,
    num_top: int,
    ascent_steps: int,
    lr: float,
    exploit_frac: float = 0.9,
    lengthscale: float = 0.2,
) -> jax.Array:
    """Maximise each posterior sample on [0,1]^d → (s, d) acquisition points."""
    d = post.x.shape[1]
    s = post.num_samples
    ku, ke, kp = jax.random.split(key, 3)
    n_exploit = int(num_candidates * exploit_frac)
    uniform = jax.random.uniform(ku, (num_candidates - n_exploit, d))
    # exploitation: resample incumbents ∝ observed value, perturb with ℓ/2 noise (§3.3.2)
    probs = jax.nn.softmax(y)
    pick = jax.random.choice(ke, post.x.shape[0], (n_exploit,), p=probs)
    near = post.x[pick] + (lengthscale / 2.0) * jax.random.normal(kp, (n_exploit, d))
    cands = jnp.clip(jnp.concatenate([uniform, near], axis=0), 0.0, 1.0)

    vals = post(cands)  # (n_cand, s)
    top = jnp.argsort(-vals, axis=0)[:num_top]  # (top, s)
    x0 = cands[top]  # (top, s, d)

    def value(xs_flat):  # xs_flat: (top*s, d) → per-sample values
        v = post(xs_flat)  # (top*s, s)
        v = v.reshape(num_top, s, s)
        return jnp.sum(jnp.einsum("tss->ts", v))

    xs = x0.reshape(num_top * s, d)
    m = jnp.zeros_like(xs)
    vv = jnp.zeros_like(xs)

    def step(carry, t):
        xs, m, vv = carry
        g = jax.grad(value)(xs)
        m = 0.9 * m + 0.1 * g
        vv = 0.999 * vv + 0.001 * g * g
        mh = m / (1 - 0.9 ** (t + 1.0))
        vh = vv / (1 - 0.999 ** (t + 1.0))
        xs = jnp.clip(xs + lr * mh / (jnp.sqrt(vh) + 1e-8), 0.0, 1.0)
        return (xs, m, vv), None

    (xs, _, _), _ = jax.lax.scan(step, (xs, m, vv), jnp.arange(ascent_steps))
    final = post(xs).reshape(num_top, s, s)
    per = jnp.einsum("tss->ts", final)  # value of candidate t for sample s
    best_t = jnp.argmax(per, axis=0)  # (s,)
    xs3 = xs.reshape(num_top, s, d)
    return xs3[best_t, jnp.arange(s)]  # (s, d)


def thompson_step(
    params: KernelParams,
    state: ThompsonState,
    objective: Callable[[jax.Array], jax.Array],
    key: jax.Array,
    *,
    acq_batch: int = 50,
    num_features: int = 1024,
    spec: Optional[SpecLike] = None,
    num_candidates: int = 2000,
    num_top: int = 5,
    ascent_steps: int = 30,
    lr: float = 1e-3,
    **spec_overrides,
) -> ThompsonState:
    """One acquisition round. ``spec`` is any registered SolverSpec (defaults to
    SDD, the paper's Thompson workhorse); extra keyword arguments are spec-field
    overrides."""
    s = as_spec("sdd" if spec is None else spec, **spec_overrides)
    kd, km, ko = jax.random.split(key, 3)
    post = posterior_functions(
        params,
        state.x,
        state.y,
        kd,
        num_samples=acq_batch,
        num_features=num_features,
        spec=s,
    )
    x_new = _maximise_samples(
        post,
        state.y,
        km,
        num_candidates=num_candidates,
        num_top=num_top,
        ascent_steps=ascent_steps,
        lr=lr,
        lengthscale=float(jnp.mean(params.lengthscale)),
    )
    y_new = objective(x_new) + jnp.sqrt(params.noise) * jax.random.normal(
        ko, (x_new.shape[0],)
    )
    x = jnp.concatenate([state.x, x_new], axis=0)
    y = jnp.concatenate([state.y, y_new], axis=0)
    return ThompsonState(x=x, y=y, best=float(jnp.max(y)))
