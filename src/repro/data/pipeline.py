"""Deterministic synthetic data pipelines (offline container — DESIGN.md §6.3).

Everything is a pure function of (seed, step/index): the pipeline carries NO state,
so checkpoint-restart resumes exactly (the trainer only stores the step counter) and
any host can materialise its own shard of any batch (elastic re-sharding is free).

  token_batch        — LM pretraining batches with a planted bigram structure so the
                       loss measurably falls (examples/lm_pretrain.py).
  regression_dataset — UCI-shaped synthetic regression (matched n/d per paper table).
  grid_curves        — learning-curve grids for the latent-Kronecker GP (Ch. 6):
                       per-config power-law curves with a random observation mask.
  molecule_fingerprints — sparse count vectors + synthetic docking scores for the
                       Tanimoto-kernel task (Ch. 4 §4.3.3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ tokens ----


def token_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int) -> dict:
    """Stateless LM batch: tokens follow a seeded bigram chain + noise, labels are
    the next token. Learnable structure, zero I/O."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    # planted bigram: next = (a * cur + c) mod V with prob 0.8, uniform otherwise
    a, c = 31, 17
    start = jax.random.randint(k1, (batch, 1), 0, vocab)

    def chain(cur, k):
        nxt_det = (a * cur + c) % vocab
        nxt_rnd = jax.random.randint(k, cur.shape, 0, vocab)
        coin = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.8, cur.shape)
        nxt = jnp.where(coin, nxt_det, nxt_rnd)
        return nxt, nxt

    keys = jax.random.split(k2, seq_len)
    _, toks = jax.lax.scan(chain, start[:, 0], keys)
    tokens = jnp.concatenate([start, toks.T], axis=1)  # (b, s+1)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


# -------------------------------------------------------------- regression ----

# name → (n, d) matching the paper's Table 3.1/4.1 datasets (synthetic stand-ins)
UCI_SHAPES = {
    "pol": (15_000, 26),
    "elevators": (16_599, 18),
    "bike": (17_379, 17),
    "protein": (45_730, 9),
    "keggdirected": (48_827, 20),
    "3droad": (434_874, 3),
    "song": (515_345, 90),
    "buzz": (583_250, 77),
    "houseelectric": (2_049_280, 11),
}


def regression_dataset(name_or_n, d: Optional[int] = None, seed: int = 0,
                       noise: float = 0.1, n_test: int = 1024):
    """Synthetic regression with UCI-matched shapes: y = sum of random sinusoids
    (stationary, medium lengthscale) + Gaussian noise. Returns dict of arrays."""
    if isinstance(name_or_n, str):
        n, d = UCI_SHAPES[name_or_n]
    else:
        n = int(name_or_n)
        assert d is not None
    rng = np.random.default_rng(seed)
    # frequency scale ∝ 1/√d keeps the function's total variation moderate in any
    # dimension (otherwise high-d targets are white-noise-hard and every method
    # degenerates to the mean predictor — no method differences visible)
    w = rng.normal(size=(d, 16)) * (1.5 / np.sqrt(d))
    b = rng.uniform(0, 2 * np.pi, size=16)
    amp = rng.normal(size=16) / np.sqrt(16)

    def f(x):
        return np.cos(x @ w + b) @ amp

    x = rng.normal(size=(n, d)).astype(np.float32)
    xt = rng.normal(size=(n_test, d)).astype(np.float32)
    y = (f(x) + noise * rng.normal(size=n)).astype(np.float32)
    yt = f(xt).astype(np.float32)
    mu, sd = y.mean(), y.std() + 1e-12
    return {
        "x": jnp.asarray(x), "y": jnp.asarray((y - mu) / sd),
        "x_test": jnp.asarray(xt), "y_test": jnp.asarray((yt - mu) / sd),
        "n": n, "d": d,
    }


# ------------------------------------------------------------------- grids ----


def grid_curves(n_configs: int = 64, n_steps: int = 50, density: float = 0.7,
                seed: int = 0):
    """Learning-curve grid (configs × steps) with missing values (Ch. 6 §6.3.2):
    loss_ij = a_i · (t_j+1)^(−b_i) + c_i + noise; a fraction `density` observed
    (curves observed as prefixes — like real partially-trained runs)."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 2.0, n_configs)
    bexp = rng.uniform(0.3, 0.8, n_configs)
    c = rng.uniform(0.1, 0.5, n_configs)
    t = np.arange(1, n_steps + 1, dtype=np.float32)
    curves = a[:, None] * t[None, :] ** (-bexp[:, None]) + c[:, None]
    curves += 0.01 * rng.normal(size=curves.shape)
    # prefix observation mask: config i observed up to a random cut
    cuts = rng.integers(int(density * n_steps * 0.5), n_steps + 1, n_configs)
    mask = t[None, :] <= cuts[:, None]
    x1 = rng.normal(size=(n_configs, 4)).astype(np.float32)  # config features
    x2 = np.log(t)[:, None].astype(np.float32)  # step feature
    return {
        "curves": jnp.asarray(curves.astype(np.float32)),
        "mask": jnp.asarray(mask),
        "grid1": jnp.asarray(x1),
        "grid2": jnp.asarray(x2),
    }


# --------------------------------------------------------------- molecules ----


def molecule_fingerprints(n: int = 4096, dim: int = 1024, seed: int = 0,
                          n_test: int = 512):
    """Sparse count 'fingerprints' + synthetic binding scores. Score depends on the
    presence of a few pharmacophore bit-patterns, so Tanimoto similarity is the
    right inductive bias (Ch. 4 §4.3.3)."""
    rng = np.random.default_rng(seed)
    ntot = n + n_test
    x = (rng.random((ntot, dim)) < 0.05).astype(np.float32)
    x += (rng.random((ntot, dim)) < 0.01).astype(np.float32)  # counts ∈ {0,1,2}
    motifs = (rng.random((8, dim)) < 0.08).astype(np.float32)
    wm = rng.normal(size=8)
    overlap = (x @ motifs.T) / (motifs.sum(1, keepdims=True).T + 1e-9)
    y = overlap @ wm + 0.05 * rng.normal(size=ntot)
    y = np.minimum(y, np.quantile(y, 0.95))  # paper clips docking scores at 5
    mu, sd = y[:n].mean(), y[:n].std() + 1e-12
    y = (y - mu) / sd
    return {
        "x": jnp.asarray(x[:n]), "y": jnp.asarray(y[:n].astype(np.float32)),
        "x_test": jnp.asarray(x[n:]), "y_test": jnp.asarray(y[n:].astype(np.float32)),
    }
