"""Trace-time block-size autotuning for the Pallas tile kernels.

The fused Gram/RFF kernels take ``block_m``/``block_n`` tile sizes that trade
VMEM footprint against MXU utilisation and grid overhead. Hardcoding 256
everywhere (the pre-autotune default) is wrong at both ends: tiny problems pay
for padding up to a tile nobody fills, and wide-``d`` problems blow the VMEM
budget a smaller tile would respect. This module resolves ``block="auto"``
requests *at trace time* — shapes are static under ``jit``, so the lookup runs
in Python and returns a plain ``int``; re-tracing never happens because the
resolved block feeds the same static ``pallas_call`` arguments every time (see
tests/test_autotune.py).

Resolution order:

1. **Committed table** (``results/AUTOTUNE_gram.json``, overridable via the
   ``REPRO_AUTOTUNE_TABLE`` env var): keys are
   ``"<family>|n<bucket>|d<bucket>|<dtype>"`` over the shape grid swept by
   ``benchmarks/bench_gram_kernel.py`` (which emits the artifact — see
   docs/kernels.md for how to regenerate it). Shapes bucket to the
   nearest-lower grid point, so any (n, d) resolves to a swept neighbourhood.
2. **VMEM-budget heuristic** for unseen keys or a missing table: the largest
   candidate block whose estimated per-tile footprint fits ``VMEM_BUDGET_BYTES``
   and that does not out-pad the problem (never a 512 tile for 300 rows).

``check_matvecs.py`` gates table freshness: if the committed table's keys drift
from the grid this module expects (``expected_keys()``), CI fails until the
sweep is re-run.
"""
from __future__ import annotations

import functools
import json
import os

#: Kernel families with distinct tile-footprint shapes.
FAMILIES = ("gram", "rff")

#: Training-set-size buckets (rows of the padded operand). Nearest-lower match.
N_GRID = (1024, 4096, 16384, 65536)

#: Input-dimension buckets. Nearest-lower match.
D_GRID = (2, 8, 32, 128)

#: Operand dtypes the table distinguishes (tile precision halves bf16 traffic).
DTYPES = ("float32", "bfloat16")

#: Blocks the sweep tries, largest first — the heuristic walks this list too.
CANDIDATE_BLOCKS = (512, 256, 128)

#: Per-kernel-invocation VMEM budget for the heuristic (half of a typical
#: 16 MB/core, leaving room for double buffering).
VMEM_BUDGET_BYTES = 8 * 2 ** 20

#: Assumed RHS width for footprint estimates (the solvers' pathwise multi-RHS
#: batch is num_samples + 1 ≈ 16; the estimate is deliberately round).
RHS_WIDTH_ESTIMATE = 16

#: Environment variable overriding the committed table path.
AUTOTUNE_ENV = "REPRO_AUTOTUNE_TABLE"

#: Default committed-table location (repo-root relative; the bench emits it).
DEFAULT_TABLE_PATH = "results/AUTOTUNE_gram.json"


def _bucket(grid: tuple, v: int) -> int:
    lower = [g for g in grid if g <= v]
    return max(lower) if lower else grid[0]


def table_key(family: str, n: int, d: int, dtype: str = "float32") -> str:
    """Bucketed lookup key for a (family, n, d, dtype) shape."""
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; expected {FAMILIES}")
    if dtype not in DTYPES:
        raise ValueError(f"unknown tile dtype {dtype!r}; expected {DTYPES}")
    return f"{family}|n{_bucket(N_GRID, n)}|d{_bucket(D_GRID, d)}|{dtype}"


def expected_keys() -> set:
    """Every key the committed table must cover — the sweep's shape grid."""
    return {
        table_key(f, n, d, t)
        for f in FAMILIES for n in N_GRID for d in D_GRID for t in DTYPES
    }


def vmem_bytes(
    family: str, bm: int, bn: int, d: int,
    s: int = RHS_WIDTH_ESTIMATE, dtype: str = "float32",
) -> int:
    """Estimated VMEM footprint of one tile step (operands + tile + accumulator).

    Operand tiles land at the tile dtype; the pair/matvec accumulators and the
    in-flight (bm, bn) tile stay fp32 (the kernels accumulate in fp32 even when
    the MXU operands are bf16).
    """
    el = 2 if dtype == "bfloat16" else 4
    if family == "gram":
        # x (bm,d) + z (bn,d) + v (bn,s) operands; k tile (bm,bn) + acc (bm,s)
        return el * (bm * d + bn * d + bn * s) + 4 * (bm * bn + bm * s)
    # rff: x (bm,d) + ω (bn,d) + both w halves (2·bn·s); proj tile + acc
    return el * (bm * d + bn * d + 2 * bn * s) + 4 * (bm * bn + bm * s)


def heuristic_block(
    family: str, n: int, d: int, dtype: str = "float32",
    s: int = RHS_WIDTH_ESTIMATE,
) -> int:
    """Largest candidate block that fits the VMEM budget without out-padding n."""
    for b in CANDIDATE_BLOCKS:
        if b > max(CANDIDATE_BLOCKS[-1], n):
            continue  # padding a small problem up to b wastes every extra row
        if vmem_bytes(family, b, b, d, s=s, dtype=dtype) <= VMEM_BUDGET_BYTES:
            return b
    return CANDIDATE_BLOCKS[-1]


@functools.lru_cache(maxsize=8)
def _load_table(path: str) -> tuple:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return ()
    table = data.get("table", data) if isinstance(data, dict) else {}
    return tuple(sorted((str(k), int(v)) for k, v in table.items()))


def load_table(path: str | None = None) -> dict:
    """The committed autotune table as {key: block}; {} if absent/unreadable.

    Cached per path — call ``load_table.cache_clear()`` (forwarded to the inner
    cache) after regenerating the artifact in-process.
    """
    path = path or os.environ.get(AUTOTUNE_ENV) or DEFAULT_TABLE_PATH
    return dict(_load_table(path))


load_table.cache_clear = _load_table.cache_clear  # type: ignore[attr-defined]


def resolve_block(
    family: str, n: int, d: int, *,
    precision: str = "fp32", table: dict | None = None,
    s: int = RHS_WIDTH_ESTIMATE,
) -> int:
    """Resolve ``block="auto"`` to a concrete static tile size.

    Pure trace-time Python on static shapes: committed-table lookup first,
    VMEM-budget heuristic fallback. Always returns a plain ``int``.
    """
    dtype = "bfloat16" if precision == "bf16" else "float32"
    if table is None:
        table = load_table()
    blk = table.get(table_key(family, n, d, dtype))
    # a key bucketed DOWN from a larger n can still advise a tile bigger than
    # this problem (n=192 buckets to n1024); never out-pad on table advice
    if blk is not None and int(blk) <= max(CANDIDATE_BLOCKS[-1], n):
        return int(blk)
    return heuristic_block(family, n, d, dtype=dtype, s=s)
