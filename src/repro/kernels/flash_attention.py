"""Causal flash attention Pallas TPU kernel (LM substrate hot-spot).

Online-softmax tiling: for each (batch·head, q-block) the kernel streams kv-blocks,
keeping running max m, normaliser l, and the (bq × dh) output accumulator in VMEM.
Causal masking skips fully-masked kv blocks. GQA is handled in ops.py by indexing kv
heads (no materialised head broadcast).

TARGET: TPU (MXU 128-aligned bq/bk). Validated via interpret=True against
ref.flash_attention_ref; the dry-run/train path uses the pure-jnp reference on CPU
and this kernel when backend == "tpu" (models/attention.py flag).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, nkv,
                  causal, scale, kv_len):
    qi, kj = pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(jnp.asarray(run))
    def _block():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        if kv_len is not None:  # padding mask (S padded to a block multiple)
            s = jnp.where(cols < kv_len, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = corr[:, None] * acc_ref[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == nkv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "kv_len", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    kv_len=None,
    interpret: bool = False,
) -> jax.Array:
    """q,k,v: (BH, S, D) — batch·heads flattened, kv already GQA-expanded indices.

    S must be a multiple of the block sizes (ops.py pads and passes the true
    length via kv_len so padded keys are masked out)."""
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0
    nq, nkv = s // block_q, s // block_k
    scale = d**-0.5
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            bq=block_q,
            bk=block_k,
            nkv=nkv,
            causal=causal,
            scale=scale,
            kv_len=kv_len,
        ),
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
