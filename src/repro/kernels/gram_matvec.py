"""Fused Gram matvec Pallas TPU kernel (DESIGN.md §2).

Computes O = (σ_f²·k(X, Z) + jitter·I) @ V *without materialising K in HBM*:
each (bm × bn) tile of K is built in VMEM — the −2·x·zᵀ inner-product term on the MXU
(distance-as-matmul), the elementwise covariance map on the VPU — and immediately
contracted against the V tile into a VMEM accumulator. HBM traffic is O(n(d+s))
instead of O(n·m); arithmetic intensity rises from ~0.5 flop/byte (materialised K,
memory-bound) to ~bn·s/(d+s) — compute-bound for the solver's multi-RHS batches.

Grid: (rows n/bm, cols m/bn), cols innermost ("arbitrary") so the output tile stays
resident in VMEM across the full accumulation. Block shapes default to 256×256
(MXU-aligned multiples of 128; VMEM footprint ≈ bm·bn·4 + (bm+bn)·(d+s)·4 ≈ 0.5 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SQRT3 = 1.7320508075688772
_SQRT5 = 2.23606797749979


def _cov_map(d2, kind: str):
    if kind == "se":
        return jnp.exp(-0.5 * d2)
    r = jnp.sqrt(d2 + 1e-36)
    if kind == "matern12":
        return jnp.exp(-r)
    if kind == "matern32":
        s = _SQRT3 * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == "matern52":
        s = _SQRT5 * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(kind)


def _gram_matvec_kernel(x_ref, z_ref, v_ref, o_ref, acc_ref, *, kind, signal, jitter, ncols):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, d)
    z = z_ref[...]  # (bn, d)
    v = v_ref[...]  # (bn, s)
    xn = jnp.sum(x * x, axis=-1)[:, None]
    zn = jnp.sum(z * z, axis=-1)[None, :]
    inner = jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # MXU: (bm, bn)
    d2 = jnp.maximum(xn + zn - 2.0 * inner, 0.0)
    k = signal * _cov_map(d2, kind)
    acc_ref[...] += jax.lax.dot_general(
        k, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    if jitter:
        # square blocking (bm == bn): diagonal tiles contribute jitter·I @ v = jitter·v
        @pl.when(i == j)
        def _diag():
            acc_ref[...] += jitter * v

    @pl.when(j == ncols - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "signal", "jitter", "block_m", "block_n", "interpret"),
)
def gram_matvec_pallas(
    x: jax.Array,
    z: jax.Array,
    v: jax.Array,
    *,
    kind: str = "se",
    signal: float = 1.0,
    jitter: float = 0.0,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x:(n,d) z:(m,d) v:(m,s) → (n,s). Inputs pre-scaled by 1/lengthscale.

    Caller must pad n,m to multiples of the block sizes (ops.py does this).
    """
    n, d = x.shape
    m, s = z.shape[0], v.shape[1]
    assert n % block_m == 0 and m % block_n == 0, (n, m, block_m, block_n)
    if jitter:
        assert block_m == block_n and n == m, "jitter requires square blocking"
    ncols = m // block_n
    grid = (n // block_m, ncols)
    return pl.pallas_call(
        functools.partial(
            _gram_matvec_kernel,
            kind=kind,
            signal=signal,
            jitter=jitter,
            ncols=ncols,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), v.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, s), jnp.float32)],
        interpret=interpret,
    )(x, z, v)
