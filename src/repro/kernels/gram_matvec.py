"""Fused Gram matvec Pallas TPU kernel (DESIGN.md §2) — forward AND backward.

Computes O = (σ_f²·k(X, Z) + jitter·I) @ V *without materialising K in HBM*:
each (bm × bn) tile of K is built in VMEM — the −2·x·zᵀ inner-product term on the MXU
(distance-as-matmul), the elementwise covariance map on the VPU — and immediately
contracted against the V tile into a VMEM accumulator. HBM traffic is O(n(d+s))
instead of O(n·m); arithmetic intensity rises from ~0.5 flop/byte (materialised K,
memory-bound) to ~bn·s/(d+s) — compute-bound for the solver's multi-RHS batches.

Grid: (rows n/bm, cols m/bn), cols innermost ("arbitrary") so the output tile stays
resident in VMEM across the full accumulation. Block shapes default to 256×256
(MXU-aligned multiples of 128; VMEM footprint ≈ bm·bn·4 + (bm+bn)·(d+s)·4 ≈ 0.5 MB).

``gram_matvec_fused`` wraps the kernel in a ``jax.custom_vjp`` so MLL gradients
(Lin et al. 2024) run end-to-end through fused tiles. The backward pass is itself
two fused Pallas contractions over the same tiling:

  * ∂/∂v  = K̃(z, x) @ ḡ               — the forward kernel, transposed operands;
  * ∂/∂x  = 2·(x ⊙ Σⱼ W − W @ z),  W_ij = κ'(d²_ij)·(ḡ_i·v_j)
    (and ∂/∂z by symmetry with x↔z, ḡ↔v swapped) — ``_gram_matvec_bwd_kernel``
    builds the κ' tile exactly like the forward builds the κ tile and contracts
    it against z on the MXU; the n×m matrix W never exists in HBM.

σ_f² and the jitter are *not* baked into the fused core: the callers in ops.py
apply ``signal * core(x/ℓ, z/ℓ, v) + jitter·v`` in plain JAX, so gradients w.r.t.
signal, noise, and lengthscale flow through ordinary autodiff around the VJP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SQRT3 = 1.7320508075688772
_SQRT5 = 2.23606797749979

# kernel kinds the fused Pallas path supports (tanimoto has no distance form)
PALLAS_KINDS = ("se", "matern12", "matern32", "matern52")

#: Tile-operand precisions. ``"fp32"`` is the default everywhere; ``"bf16"``
#: casts the MXU contraction operands (points, kernel tiles, RHS tiles) to
#: bfloat16 on load while every accumulation stays fp32
#: (``preferred_element_type``) and the elementwise covariance map runs on fp32
#: squared distances. Halves tile memory traffic and doubles MXU throughput;
#: the stochastic solvers opt in (their estimators are minibatch-noise
#: dominated — see docs/kernels.md for accuracy guidance).
TILE_PRECISIONS = ("fp32", "bf16")


def _cast_mxu(a, precision: str):
    """Cast an MXU contraction operand per the tile precision (no-op on fp32)."""
    if precision == "bf16":
        return a.astype(jnp.bfloat16)
    if precision != "fp32":
        raise ValueError(
            f"unknown tile precision {precision!r}; expected one of {TILE_PRECISIONS}"
        )
    return a


def _pair_dists(x, z, precision: str):
    """Squared-distance tile via the matmul identity, honouring the precision.

    The inner product runs on (possibly bf16-cast) MXU operands with fp32
    accumulation; the norms are computed in fp32 *from the cast values* so the
    three terms of ||x−z||² = ||x||² + ||z||² − 2x·z see the same rounding and
    the cancellation stays consistent (d² ≥ 0 up to fp32 roundoff, as in fp32).
    """
    xc = _cast_mxu(x, precision)
    zc = _cast_mxu(z, precision)
    xf = xc.astype(jnp.float32)
    zf = zc.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=-1)[:, None]
    zn = jnp.sum(zf * zf, axis=-1)[None, :]
    inner = jax.lax.dot_general(
        xc, zc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    return xn + zn - 2.0 * inner


def _cov_map(d2, kind: str):
    if kind == "se":
        return jnp.exp(-0.5 * d2)
    r = jnp.sqrt(d2 + 1e-36)
    if kind == "matern12":
        return jnp.exp(-r)
    if kind == "matern32":
        s = _SQRT3 * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == "matern52":
        s = _SQRT5 * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(
        f"kernel kind {kind!r} has no fused Pallas covariance map; "
        f"supported kinds: {PALLAS_KINDS} — use the chunked backend instead"
    )


def _dcov_map(d2, kind: str):
    """dκ/d(d²) — same ε-regularised r as ``_cov_map`` so the VJP matches plain
    autodiff through the dense reference bit-for-bit in structure."""
    if kind == "se":
        return -0.5 * jnp.exp(-0.5 * d2)
    r = jnp.sqrt(d2 + 1e-36)
    if kind == "matern12":
        return -jnp.exp(-r) / (2.0 * r)
    if kind == "matern32":
        return -1.5 * jnp.exp(-_SQRT3 * r)
    if kind == "matern52":
        s = _SQRT5 * r
        return -(5.0 / 6.0) * (1.0 + s) * jnp.exp(-s)
    raise ValueError(
        f"kernel kind {kind!r} has no fused Pallas covariance derivative; "
        f"supported kinds: {PALLAS_KINDS} — use the chunked backend instead"
    )


def _gram_matvec_kernel(
    x_ref, z_ref, v_ref, o_ref, acc_ref, *, kind, signal, jitter, ncols, precision
):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = v_ref[...]  # (bn, s)
    d2 = jnp.maximum(_pair_dists(x_ref[...], z_ref[...], precision), 0.0)
    k = signal * _cov_map(d2, kind)
    acc_ref[...] += jax.lax.dot_general(
        _cast_mxu(k, precision), _cast_mxu(v, precision),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    if jitter:
        # square blocking (bm == bn): diagonal tiles contribute jitter·I @ v = jitter·v
        @pl.when(i == j)
        def _diag():
            acc_ref[...] += jitter * v

    @pl.when(j == ncols - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kind", "signal", "jitter", "block_m", "block_n", "interpret", "precision"
    ),
)
def gram_matvec_pallas(
    x: jax.Array,
    z: jax.Array,
    v: jax.Array,
    *,
    kind: str = "se",
    signal: float = 1.0,
    jitter: float = 0.0,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
) -> jax.Array:
    """x:(n,d) z:(m,d) v:(m,s) → (n,s). Inputs pre-scaled by 1/lengthscale.

    Caller must pad n,m to multiples of the block sizes (ops.py does this).
    """
    n, d = x.shape
    m, s = z.shape[0], v.shape[1]
    assert n % block_m == 0 and m % block_n == 0, (n, m, block_m, block_n)
    if jitter:
        assert block_m == block_n and n == m, "jitter requires square blocking"
    ncols = m // block_n
    grid = (n // block_m, ncols)
    return pl.pallas_call(
        functools.partial(
            _gram_matvec_kernel,
            kind=kind,
            signal=signal,
            jitter=jitter,
            ncols=ncols,
            precision=precision,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), v.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, s), jnp.float32)],
        interpret=interpret,
    )(x, z, v)


def _gram_matvec_bwd_kernel(
    x_ref, z_ref, rowv_ref, colv_ref, o_ref, acc_wz_ref, acc_ws_ref,
    *, kind, ncols, precision
):
    """Accumulates dx_i = 2 Σ_j W_ij (x_i − z_j) with W_ij = κ'(d²_ij)·(rowv_i·colv_j).

    Per tile: the κ' block on the VPU (same distance-as-matmul trick as the
    forward), the rank-s outer product rowv·colvᵀ on the MXU, then W @ z on the
    MXU — three fused contractions, W never leaves VMEM.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_wz_ref[...] = jnp.zeros_like(acc_wz_ref)
        acc_ws_ref[...] = jnp.zeros_like(acc_ws_ref)

    x = x_ref[...]  # (bm, d)
    z = z_ref[...]  # (bn, d)
    raw = _pair_dists(x, z, precision)
    kp = _dcov_map(jnp.maximum(raw, 0.0), kind)
    if kind == "matern12":
        # Matérn-1/2 is non-differentiable at coincident points (κ' ~ 1/r → ∞);
        # plain autodiff through sqrt(d²+ε) yields unbounded garbage on the
        # diagonal of symmetric Grams. Adopt the symmetric-limit convention: the
        # pair contributes nothing at exactly zero distance.
        mask = (raw > 0.0).astype(jnp.float32)
    else:
        # replicate autodiff's max(·, 0) clamp convention: 1 above, ½ at, 0 below
        mask = jnp.where(raw > 0.0, 1.0, jnp.where(raw == 0.0, 0.5, 0.0))
    gv = jax.lax.dot_general(
        _cast_mxu(rowv_ref[...], precision), _cast_mxu(colv_ref[...], precision),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bm, bn) = ḡ_i · v_j
    w = kp * mask * gv
    acc_ws_ref[...] += jnp.sum(w, axis=1, keepdims=True)
    acc_wz_ref[...] += jax.lax.dot_general(
        _cast_mxu(w, precision), _cast_mxu(z, precision),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    @pl.when(j == ncols - 1)
    def _flush():
        o_ref[...] = (2.0 * (x * acc_ws_ref[...] - acc_wz_ref[...])).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "block_m", "block_n", "interpret", "precision")
)
def gram_matvec_bwd_pallas(
    x: jax.Array,
    z: jax.Array,
    rowv: jax.Array,
    colv: jax.Array,
    *,
    kind: str = "se",
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
) -> jax.Array:
    """Input cotangent dx (n,d) of v ↦ K̃(x,z)@v at rowv=ḡ (n,s), colv=v (m,s).

    With (x,z,rowv,colv) = (z,x,v,ḡ) the same kernel yields dz by symmetry.
    """
    n, d = x.shape
    m = z.shape[0]
    assert n % block_m == 0 and m % block_n == 0, (n, m, block_m, block_n)
    ncols = m // block_n
    return pl.pallas_call(
        functools.partial(
            _gram_matvec_bwd_kernel, kind=kind, ncols=ncols, precision=precision
        ),
        grid=(n // block_m, ncols),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, rowv.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, colv.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, d), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, z, rowv, colv)


# ---------------------------------------------------------------------------
# Differentiable fused core: K̃(x, z) @ v with a custom VJP (signal/jitter-free;
# ops.py scales by σ_f² and adds jitter·v outside, in plain JAX).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def gram_matvec_fused(kind, block_m, block_n, interpret, precision, x, z, v):
    """K̃(x, z) @ v (unit signal, no jitter), differentiable w.r.t. x, z, v.

    x:(n,d) z:(m,d) v:(m,s), all pre-scaled by 1/lengthscale and pre-padded to
    block multiples. Every pass — forward and both backward contractions — runs
    through fused Pallas tiles; the n×m Gram block never exists in HBM.
    """
    return gram_matvec_pallas(
        x, z, v, kind=kind, signal=1.0, jitter=0.0,
        block_m=block_m, block_n=block_n, interpret=interpret,
        precision=precision,
    )


def _gram_matvec_fused_fwd(kind, block_m, block_n, interpret, precision, x, z, v):
    out = gram_matvec_fused(kind, block_m, block_n, interpret, precision, x, z, v)
    return out, (x, z, v)


def _gram_matvec_fused_bwd(kind, block_m, block_n, interpret, precision, res, g):
    x, z, v = res
    kw = dict(kind=kind, interpret=interpret, precision=precision)
    # ∂v: the transposed fused matvec K̃(z, x) @ ḡ — note the swapped block sizes
    dv = gram_matvec_pallas(
        z, x, g, signal=1.0, jitter=0.0,
        block_m=block_n, block_n=block_m, **kw,
    )
    dx = gram_matvec_bwd_pallas(x, z, g, v, block_m=block_m, block_n=block_n, **kw)
    dz = gram_matvec_bwd_pallas(z, x, v, g, block_m=block_n, block_n=block_m, **kw)
    return dx, dz, dv


gram_matvec_fused.defvjp(_gram_matvec_fused_fwd, _gram_matvec_fused_bwd)


# ---------------------------------------------------------------------------
# Fused stochastic pair step: err = K̃(xi, x) @ look − b and g = K̃(xi, x)ᵀ @ err
# in ONE kernel launch — the SGD fit-term primitive (Lin et al. 2024 run the
# row-panel forward and its pullback as separate passes; fusing them keeps the
# (p, s) error block in VMEM between the two contractions).
# ---------------------------------------------------------------------------


def _gram_rows_pair_kernel(
    xi_ref, x_ref, look_ref, b_ref, err_ref, o_ref, acc_ref,
    *, kind, ncols, p_true, precision
):
    """Two-phase grid (phase outermost, column tiles innermost).

    Phase 0 sweeps the column tiles of the panel A = K̃(xi, x), accumulating
    err = A @ look − b into a VMEM scratch that persists across the whole grid;
    at the last column tile the rows belonging to row padding are zeroed
    (padded xi rows are all-zero points, whose kernel values k(0, ·) ≠ 0 would
    otherwise leak garbage into phase 1) and the finished error block is
    emitted. Phase 1 revisits the same column tiles, rebuilding each A tile and
    writing g_j = A_jᵀ @ err straight to the j-th output block — err never
    round-trips HBM, and the launch (plus its operand DMAs) happens once
    instead of twice. Output blocks mapped during phase 0 flush whatever the
    buffer holds, which is dead: phase 1 fully overwrites every block.
    """
    ph, j = pl.program_id(0), pl.program_id(1)
    d2 = jnp.maximum(_pair_dists(xi_ref[...], x_ref[...], precision), 0.0)
    k = _cast_mxu(_cov_map(d2, kind), precision)  # (bp, bn) panel tile

    @pl.when(ph == 0)
    def _accumulate():
        @pl.when(j == 0)
        def _init():
            acc_ref[...] = -b_ref[...].astype(jnp.float32)

        acc_ref[...] += jax.lax.dot_general(
            k, _cast_mxu(look_ref[...], precision),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

        @pl.when(j == ncols - 1)
        def _finalize():
            rows = jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
            acc_ref[...] = jnp.where(rows < p_true, acc_ref[...], 0.0)
            err_ref[...] = acc_ref[...].astype(err_ref.dtype)

    @pl.when(ph == 1)
    def _contract():
        o_ref[...] = jax.lax.dot_general(
            k, _cast_mxu(acc_ref[...], precision),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "block_n", "interpret", "precision", "p_true")
)
def gram_rows_pair_pallas(
    xi: jax.Array,
    x: jax.Array,
    look: jax.Array,
    b: jax.Array,
    *,
    kind: str = "se",
    block_n: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
    p_true: int | None = None,
) -> tuple:
    """err = K̃(xi, x) @ look − b, g = K̃(xi, x)ᵀ @ err — one fused launch.

    xi:(p,d) x:(n,d) look:(n,s) b:(p,s) → (err:(p,s), g:(n,s)). Unit signal;
    inputs pre-scaled by 1/lengthscale, n pre-padded to a block_n multiple and
    p to a 128 multiple (the whole row block is one tile). ``p_true`` masks the
    padded error rows (default: no padding).
    """
    p, d = xi.shape
    n, s = look.shape
    assert n % block_n == 0 and p % 128 == 0, (n, p, block_n)
    assert b.shape == (p, s)
    p_true = p if p_true is None else p_true
    ncols = n // block_n
    return pl.pallas_call(
        functools.partial(
            _gram_rows_pair_kernel,
            kind=kind, ncols=ncols, p_true=p_true, precision=precision,
        ),
        grid=(2, ncols),
        in_specs=[
            pl.BlockSpec((p, d), lambda ph, j: (0, 0)),
            pl.BlockSpec((block_n, d), lambda ph, j: (j, 0)),
            pl.BlockSpec((block_n, s), lambda ph, j: (j, 0)),
            pl.BlockSpec((p, s), lambda ph, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((p, s), lambda ph, j: (0, 0)),
            pl.BlockSpec((block_n, s), lambda ph, j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, s), look.dtype),
            jax.ShapeDtypeStruct((n, s), look.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        interpret=interpret,
    )(xi, x, look, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def gram_rows_pair_fused(kind, block_n, interpret, precision, p_true, xi, x, look, b):
    """Differentiable fused pair step (unit signal — ops.py folds σ_f² outside).

    Returns (err, g) = (A@look − b, Aᵀ@err) for A = K̃(xi, x). The VJP is a
    composition of the existing fused primitives: with ê = ē + A ḡ (masked to
    the true rows), dlook = Aᵀ ê, db = −ê, and dA = ê lookᵀ + err ḡᵀ — a
    rank-2s outer-product pair handled by ``gram_matvec_bwd_pallas`` on the
    concatenated factors. No pass materialises the panel in HBM.
    """
    return gram_rows_pair_pallas(
        xi, x, look, b, kind=kind, block_n=block_n, interpret=interpret,
        precision=precision, p_true=p_true,
    )


def _gram_rows_pair_fused_fwd(kind, block_n, interpret, precision, p_true,
                              xi, x, look, b):
    err, g = gram_rows_pair_fused(
        kind, block_n, interpret, precision, p_true, xi, x, look, b
    )
    return (err, g), (xi, x, look, b, err)


def _gram_rows_pair_fused_bwd(kind, block_n, interpret, precision, p_true, res, cts):
    xi, x, look, b = res[:4]
    err = res[4]
    e_bar, g_bar = cts
    p = xi.shape[0]
    kw = dict(kind=kind, interpret=interpret, precision=precision)
    # ê = ē + A ḡ — cotangent of err through BOTH outputs (g = Aᵀ err depends
    # on err); masked exactly like the forward masks the padded error rows
    ag = gram_matvec_pallas(
        xi, x, g_bar, signal=1.0, jitter=0.0,
        block_m=p, block_n=block_n, **kw,
    )
    rows = jnp.arange(p)[:, None]
    ehat = jnp.where(rows < p_true, e_bar + ag, 0.0)
    dlook = gram_matvec_pallas(
        x, xi, ehat, signal=1.0, jitter=0.0,
        block_m=block_n, block_n=p, **kw,
    )
    db = -ehat
    # dA = ê lookᵀ + err ḡᵀ: stack the rank-s factors and reuse the Gram
    # backward kernel on the (·, 2s) concatenations
    rowv = jnp.concatenate([ehat, err], axis=1)  # (p, 2s)
    colv = jnp.concatenate([look, g_bar], axis=1)  # (n, 2s)
    dxi = gram_matvec_bwd_pallas(xi, x, rowv, colv, block_m=p, block_n=block_n, **kw)
    dx = gram_matvec_bwd_pallas(x, xi, colv, rowv, block_m=block_n, block_n=p, **kw)
    return dxi, dx, dlook, db


gram_rows_pair_fused.defvjp(_gram_rows_pair_fused_fwd, _gram_rows_pair_fused_bwd)
