"""Jit'd public wrappers around the Pallas kernels: padding, lengthscale folding,
GQA head expansion, and interpret-mode dispatch (CPU validation vs TPU execution).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gram_matvec import gram_matvec_pallas
from .rff_matvec import rff_matvec_pallas
from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    return a if pad == 0 else jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))


def gram_matvec(params, x, v, z=None, *, jitter=None, block=256, interpret=None):
    """(σ_f² k(x,z) + jitter I) @ v — Pallas fused Gram matvec (see gram_matvec.py).

    params: core.kernels_fn.KernelParams. v: (m,) or (m,s).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    ls = params.lengthscale
    xs = x / ls
    zs = xs if z is None else z / ls
    n, m = xs.shape[0], zs.shape[0]
    jit_val = 0.0 if jitter is None else float(jitter)
    xp = _pad_rows(xs, block)
    zp = _pad_rows(zs, block)
    vp = _pad_rows(v2, block)
    out = gram_matvec_pallas(
        xp,
        zp,
        vp,
        kind=params.kind,
        signal=float(params.signal),
        jitter=jit_val,
        block_m=block,
        block_n=block,
        interpret=interpret,
    )[:n]
    return out[:, 0] if squeeze else out


def rff_matvec(x, omega, w, *, signal=1.0, block=256, interpret=None):
    """Φ(x) @ w (paired sin/cos RFF) — fused, feature matrix never in HBM."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = x.shape[0]
    m_true = omega.shape[0]
    xp = _pad_rows(x, block)
    pad_f = (-m_true) % block
    if pad_f:
        # padded ω rows give cos→1 features, but the matching padded w rows are zero,
        # so their contribution vanishes; only the 1/m normalisation needs fixing.
        omega = jnp.pad(omega, ((0, pad_f), (0, 0)))
        w = jnp.concatenate(
            [
                jnp.pad(w[:m_true], ((0, pad_f), (0, 0))),
                jnp.pad(w[m_true:], ((0, pad_f), (0, 0))),
            ],
            axis=0,
        )
    m_pad = m_true + pad_f
    signal_adj = float(signal) * m_pad / m_true  # sqrt(adj/m_pad) == sqrt(signal/m_true)
    out = rff_matvec_pallas(
        xp, omega, w, signal=signal_adj, block_m=block, block_f=block,
        interpret=interpret,
    )[:n]
    return out


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None):
    """q: (b, s, hq, d), k/v: (b, s, hkv, d) with hq % hkv == 0 (GQA) → (b, s, hq, d)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    # GQA: index kv heads per q head (gather, no broadcast materialisation pre-kernel)
    head_map = jnp.arange(hq) // group
    kq = k[:, :, head_map]  # (b, s, hq, d)
    vq = v[:, :, head_map]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = kq.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    vf = vq.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    pad = (-s) % max(block_q, block_k)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, block_q=block_q, block_k=block_k,
        kv_len=(s if pad else None), interpret=interpret,
    )[:, :s]
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
