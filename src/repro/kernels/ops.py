"""Jit'd public wrappers around the Pallas kernels — and the library's Gram-matvec
backend-selection layer.

Every Gram-matvec in the library routes through :func:`gram_mv` (full matvecs) or
:func:`gram_rows_matvec` (row-block matvecs), which dispatch on a ``backend``
string:

* ``"pallas"``  — the fused, differentiable Pallas kernel (gram_matvec.py):
  K tiles built in VMEM, never materialised in HBM. Compiled on TPU, interpret
  mode elsewhere. Raises for kernels without a distance-as-matmul form
  (``tanimoto``).
* ``"chunked"`` — the pure-JAX row-chunked matvec (core/kernels_fn.py):
  O(chunk·m) memory, any kernel kind, autodiff throughout.
* ``"dense"``   — materialise K and multiply (small-n reference / tests).
* ``"auto"``    — Pallas when running on TPU (interpret mode is slower than
  chunked XLA on CPU), chunked otherwise; always chunked for ``tanimoto``.

All paths are differentiable w.r.t. the hyperparameters: the Pallas path wraps a
``jax.custom_vjp`` whose backward pass is itself fused Pallas contractions, with
σ_f², lengthscale and jitter folded in *outside* the custom-VJP core so their
gradients flow through ordinary autodiff.

``MATVEC_TRACE_COUNTS`` records how many Gram matvecs each backend dispatched
(counted when the op is staged, i.e. per trace or eager call) — used by tests and
benchmarks to prove the hot path never silently falls back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gram_matvec import PALLAS_KINDS, gram_matvec_fused
from .rff_matvec import rff_matvec_pallas
from .flash_attention import flash_attention_pallas

BACKENDS = ("auto", "pallas", "chunked", "dense")

# backend -> number of Gram matvecs dispatched (staged into a trace or run
# eagerly). A solve that never touches "chunked" proves the fused path is the
# hot path — see tests/test_backends_and_counts.py.
MATVEC_TRACE_COUNTS = {"pallas": 0, "chunked": 0, "dense": 0}


def reset_matvec_trace_counts() -> None:
    for k in MATVEC_TRACE_COUNTS:
        MATVEC_TRACE_COUNTS[k] = 0


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str = "auto", kind: str = "se") -> str:
    """Normalise a backend request to a concrete backend for kernel ``kind``.

    ``auto`` picks the fused Pallas kernel on TPU and the chunked JAX matvec
    elsewhere, and silently falls back to chunked for kinds the Pallas kernel
    cannot express (``tanimoto`` has no distance-as-matmul form). Requesting
    ``pallas`` explicitly for such a kind is an error.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        if kind not in PALLAS_KINDS:
            return "chunked"
        return "pallas" if _on_tpu() else "chunked"
    if backend == "pallas" and kind not in PALLAS_KINDS:
        raise ValueError(
            f"kernel kind {kind!r} is not supported by the fused Pallas backend "
            f"(no distance-as-matmul form); supported kinds: {PALLAS_KINDS}. "
            f"Use backend='chunked', or backend='auto' to fall back automatically."
        )
    return backend


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    return a if pad == 0 else jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))


def _pallas_gram_mv(params, x, v2, z, block, interpret):
    interpret = (not _on_tpu()) if interpret is None else interpret
    ls = params.lengthscale
    xs = x / ls
    zs = None if z is None else z / ls
    n = xs.shape[0]
    xp = _pad_rows(xs, block)
    zp = xp if zs is None else _pad_rows(zs, block)
    vp = _pad_rows(v2, block)
    out = gram_matvec_fused(params.kind, block, block, bool(interpret), xp, zp, vp)
    return params.signal * out[:n]


def gram_mv(
    params,
    x: jax.Array,
    v: jax.Array,
    z=None,
    *,
    jitter=None,
    backend: str = "auto",
    block: int = 256,
    row_chunk: int = 2048,
    interpret=None,
) -> jax.Array:
    """(σ_f² k(x, z) + jitter·I) @ v through the selected backend — THE Gram
    matvec entry point; differentiable w.r.t. ``params`` on every backend.

    params: core.kernels_fn.KernelParams. v: (m,) or (m, s). ``jitter`` (typically
    σ²) is applied as ``out + jitter·v`` outside the kernels, valid only for the
    symmetric z-is-None case.
    """
    from ..core.kernels_fn import gram, matvec  # deferred: avoid core<->kernels cycle

    if jitter is not None and z is not None:
        raise ValueError(
            "jitter adds jitter·I, which only makes sense for the symmetric "
            "K(x, x) operator — drop jitter for cross-Gram matvecs (z given)"
        )
    bk = resolve_backend(backend, params.kind)
    MATVEC_TRACE_COUNTS[bk] += 1
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    if bk == "pallas":
        out = _pallas_gram_mv(params, x, v2, z, block, interpret)
    elif bk == "chunked":
        out = matvec(params, x, v2, z=z, row_chunk=row_chunk)
    else:
        out = gram(params, x, z) @ v2
    if jitter is not None:
        out = out + jitter * v2
    return out[:, 0] if squeeze else out


def gram_rows_matvec(
    params,
    x: jax.Array,
    idx: jax.Array,
    u: jax.Array,
    *,
    transpose: bool = False,
    backend: str = "auto",
    block: int = 256,
    row_chunk: int = 2048,
    interpret=None,
) -> jax.Array:
    """Fused row-block matvec: K[idx, :] @ u, or K[idx, :]ᵀ @ u with ``transpose``.

    The SGD/SDD/AP primitive (Wu et al. 2023). On the Pallas backend the |idx|×n
    row panel never exists in HBM — only the gathered x[idx] (|idx|×d) does, and
    the panel is built tile-by-tile in VMEM. The chunked/dense backends
    materialise the panel once per call (a solver batch is small, |idx| ≪ n, so
    this is the seed's memory envelope and avoids recomputing kernel entries —
    fusion only pays when HBM bandwidth is the bottleneck). u: (n, s) (or
    (|idx|, s) with ``transpose``).
    """
    from ..core.kernels_fn import gram  # deferred: avoid core<->kernels cycle

    bk = resolve_backend(backend, params.kind)
    xi = x[idx]
    if bk == "pallas":
        if transpose:
            return gram_mv(
                params, x, u, z=xi, backend="pallas", block=block,
                interpret=interpret,
            )
        return gram_mv(
            params, xi, u, z=x, backend="pallas", block=block, interpret=interpret,
        )
    MATVEC_TRACE_COUNTS[bk] += 1
    panel = gram(params, xi, x)  # (|idx|, n)
    return panel.T @ u if transpose else panel @ u


def gram_matvec(params, x, v, z=None, *, jitter=None, block=256, interpret=None):
    """(σ_f² k(x,z) + jitter I) @ v — Pallas fused Gram matvec (see gram_matvec.py).

    Thin ``backend="pallas"`` pin over :func:`gram_mv`, kept as the conventional
    name for kernel tests and benchmarks.
    """
    return gram_mv(
        params, x, v, z=z, jitter=jitter, backend="pallas", block=block,
        interpret=interpret,
    )


def rff_matvec(x, omega, w, *, signal=1.0, block=256, interpret=None):
    """Φ(x) @ w (paired sin/cos RFF) — fused, feature matrix never in HBM.

    ``signal`` (σ_f²) may be a traced array: the kernel runs with unit signal
    and the √(σ_f²/m) normalisation is applied outside, in plain JAX.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = x.shape[0]
    m_true = omega.shape[0]
    xp = _pad_rows(x, block)
    pad_f = (-m_true) % block
    if pad_f:
        # padded ω rows give cos→1 features, but the matching padded w rows are zero,
        # so their contribution vanishes; only the 1/m normalisation needs fixing.
        omega = jnp.pad(omega, ((0, pad_f), (0, 0)))
        w = jnp.concatenate(
            [
                jnp.pad(w[:m_true], ((0, pad_f), (0, 0))),
                jnp.pad(w[m_true:], ((0, pad_f), (0, 0))),
            ],
            axis=0,
        )
    m_pad = m_true + pad_f
    out = rff_matvec_pallas(
        xp, omega, w, signal=1.0, block_m=block, block_f=block,
        interpret=interpret,
    )[:n]
    # kernel scale is sqrt(1/m_pad); rescale to sqrt(signal/m_true)
    return out * jnp.sqrt(signal * (m_pad / m_true))


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None):
    """q: (b, s, hq, d), k/v: (b, s, hkv, d) with hq % hkv == 0 (GQA) → (b, s, hq, d)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    # GQA: index kv heads per q head (gather, no broadcast materialisation pre-kernel)
    head_map = jnp.arange(hq) // group
    kq = k[:, :, head_map]  # (b, s, hq, d)
    vq = v[:, :, head_map]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = kq.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    vf = vq.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    pad = (-s) % max(block_q, block_k)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, block_q=block_q, block_k=block_k,
        kv_len=(s if pad else None), interpret=interpret,
    )[:, :s]
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
