"""Jit'd public wrappers around the Pallas kernels — and the library's matvec
backend-selection layer, for Gram *and* feature-map (RFF) contractions.

Every Gram-matvec in the library routes through :func:`gram_mv` (full matvecs) or
:func:`gram_rows_matvec` (row-block matvecs), which dispatch on a ``backend``
string:

* ``"pallas"``  — the fused, differentiable Pallas kernel (gram_matvec.py):
  K tiles built in VMEM, never materialised in HBM. Compiled on TPU, interpret
  mode elsewhere. Raises for kernels without a distance-as-matmul form
  (``tanimoto``).
* ``"chunked"`` — the pure-JAX row-chunked matvec (core/kernels_fn.py):
  O(chunk·m) memory, any kernel kind, autodiff throughout.
* ``"dense"``   — materialise K and multiply (small-n reference / tests).
* ``"auto"``    — Pallas when running on TPU (interpret mode is slower than
  chunked XLA on CPU), chunked otherwise; always chunked for ``tanimoto``.

Every feature-map matvec routes through :func:`rff_mv` (Φ(x) @ w) or
:func:`rff_t_mv` (Φ(x)ᵀ @ u) — the ``FeatureOperator`` twins of ``gram_mv``,
dispatching on ``"pallas"`` (fused, (n × 2m) feature matrix never in HBM) /
``"features"`` (materialise Φ and matmul) / ``"auto"``. The Gram backend names
``"chunked"``/``"dense"`` coerce to ``"features"``, so one spec-level ``backend``
field pins both sides of a solve.

All paths are differentiable w.r.t. the hyperparameters: the Pallas paths wrap
``jax.custom_vjp``\\ s whose backward passes are themselves fused Pallas
contractions, with σ_f², lengthscale and jitter folded in *outside* the
custom-VJP cores so their gradients flow through ordinary autodiff.

``MATVEC_TRACE_COUNTS`` / ``FEATURE_TRACE_COUNTS`` record how many Gram/feature
matvecs each backend dispatched (counted when the op is staged, i.e. per trace or
eager call) — used by tests and benchmarks to prove the hot paths never silently
fall back (see tests/test_backends_and_counts.py, tests/test_features.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .autotune import resolve_block as _autotune_block
from .gram_matvec import (
    PALLAS_KINDS,
    TILE_PRECISIONS,
    gram_matvec_fused,
    gram_rows_pair_fused,
)
from .rff_matvec import rff_matvec_fused, rff_pair_fused, rff_t_matvec_fused
from .flash_attention import flash_attention_pallas

BACKENDS = ("auto", "pallas", "chunked", "dense")

#: Tile/contration precisions (re-exported from gram_matvec): ``"fp32"``
#: everywhere by default; ``"bf16"`` casts contraction operands to bfloat16
#: with fp32 accumulation. Threaded ``SolverSpec`` → ``solve()`` → operators →
#: here, exactly like ``backend``. On the chunked/dense backends precision
#: applies to the panel/feature *contractions* (the panel itself and the
#: covariance map stay fp32); on Pallas it also covers the distance matmul.
PRECISIONS = TILE_PRECISIONS

#: Feature-map (RFF) backends: fused Pallas vs materialised features. ``auto``
#: is pallas on TPU, features elsewhere; Gram backend names coerce (see
#: :func:`resolve_feature_backend`).
FEATURE_BACKENDS = ("auto", "pallas", "features")

# backend -> number of Gram matvecs dispatched (staged into a trace or run
# eagerly). A solve that never touches "chunked" proves the fused path is the
# hot path — see tests/test_backends_and_counts.py.
MATVEC_TRACE_COUNTS = {"pallas": 0, "chunked": 0, "dense": 0}

# backend -> number of feature matvecs (Φw / Φᵀu) dispatched. A solve whose
# "features" count stays zero provably never materialised an (n, 2m) feature
# matrix — the acceptance check for the fused SGD regulariser.
FEATURE_TRACE_COUNTS = {"pallas": 0, "features": 0}


def reset_matvec_trace_counts() -> None:
    for k in MATVEC_TRACE_COUNTS:
        MATVEC_TRACE_COUNTS[k] = 0


def reset_feature_trace_counts() -> None:
    for k in FEATURE_TRACE_COUNTS:
        FEATURE_TRACE_COUNTS[k] = 0


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str = "auto", kind: str = "se") -> str:
    """Normalise a backend request to a concrete backend for kernel ``kind``.

    ``auto`` picks the fused Pallas kernel on TPU and the chunked JAX matvec
    elsewhere, and silently falls back to chunked for kinds the Pallas kernel
    cannot express (``tanimoto`` has no distance-as-matmul form). Requesting
    ``pallas`` explicitly for such a kind is an error.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        if kind not in PALLAS_KINDS:
            return "chunked"
        return "pallas" if _on_tpu() else "chunked"
    if backend == "pallas" and kind not in PALLAS_KINDS:
        raise ValueError(
            f"kernel kind {kind!r} is not supported by the fused Pallas backend "
            f"(no distance-as-matmul form); supported kinds: {PALLAS_KINDS}. "
            f"Use backend='chunked', or backend='auto' to fall back automatically."
        )
    return backend


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    return a if pad == 0 else jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))


def _check_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}"
        )
    return precision


def _resolve_block(block, family: str, n: int, d: int, precision: str) -> int:
    """``"auto"`` → the autotuned/heuristic tile size; ints pass through.

    Runs at trace time on static shapes, so the result is a plain Python int
    and a repeated call with the same shapes re-traces nothing.
    """
    if block == "auto":
        return _autotune_block(family, n, d, precision=precision)
    return int(block)


def _dot(a: jax.Array, b: jax.Array, precision: str) -> jax.Array:
    """a @ b honouring the tile precision: bf16 operands, fp32 accumulation.

    The fp32 path stays a plain ``@`` so existing results are bit-identical.
    """
    if precision == "bf16":
        return jax.lax.dot_general(
            a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
    return a @ b


def _pallas_gram_mv(params, x, v2, z, block, interpret, precision="fp32"):
    interpret = (not _on_tpu()) if interpret is None else interpret
    ls = params.lengthscale
    xs = x / ls
    zs = None if z is None else z / ls
    n = xs.shape[0]
    block = _resolve_block(block, "gram", n, x.shape[1], precision)
    xp = _pad_rows(xs, block)
    zp = xp if zs is None else _pad_rows(zs, block)
    vp = _pad_rows(v2, block)
    out = gram_matvec_fused(
        params.kind, block, block, bool(interpret), precision, xp, zp, vp
    )
    return params.signal * out[:n]


def gram_mv(
    params,
    x: jax.Array,
    v: jax.Array,
    z=None,
    *,
    jitter=None,
    backend: str = "auto",
    block="auto",
    row_chunk: int = 2048,
    interpret=None,
    precision: str = "fp32",
) -> jax.Array:
    """(σ_f² k(x, z) + jitter·I) @ v through the selected backend — THE Gram
    matvec entry point; differentiable w.r.t. ``params`` on every backend.

    params: core.kernels_fn.KernelParams. v: (m,) or (m, s). ``jitter`` (typically
    σ²) is applied as ``out + jitter·v`` outside the kernels, valid only for the
    symmetric z-is-None case.
    """
    from ..core.kernels_fn import gram, matvec  # deferred: avoid core<->kernels cycle

    if jitter is not None and z is not None:
        raise ValueError(
            "jitter adds jitter·I, which only makes sense for the symmetric "
            "K(x, x) operator — drop jitter for cross-Gram matvecs (z given)"
        )
    bk = resolve_backend(backend, params.kind)
    _check_precision(precision)
    MATVEC_TRACE_COUNTS[bk] += 1
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    if bk == "pallas":
        out = _pallas_gram_mv(params, x, v2, z, block, interpret, precision)
    elif bk == "chunked":
        out = matvec(params, x, v2, z=z, row_chunk=row_chunk)
    else:
        out = gram(params, x, z) @ v2
    if jitter is not None:
        out = out + jitter * v2
    return out[:, 0] if squeeze else out


def gram_rows_matvec(
    params,
    x: jax.Array,
    idx: jax.Array,
    u: jax.Array,
    *,
    transpose: bool = False,
    backend: str = "auto",
    block="auto",
    row_chunk: int = 2048,
    interpret=None,
    precision: str = "fp32",
) -> jax.Array:
    """Fused row-block matvec: K[idx, :] @ u, or K[idx, :]ᵀ @ u with ``transpose``.

    The SGD/SDD/AP primitive (Wu et al. 2023). On the Pallas backend the |idx|×n
    row panel never exists in HBM — only the gathered x[idx] (|idx|×d) does, and
    the panel is built tile-by-tile in VMEM. The chunked/dense backends
    materialise the panel once per call (a solver batch is small, |idx| ≪ n, so
    this is the seed's memory envelope and avoids recomputing kernel entries —
    fusion only pays when HBM bandwidth is the bottleneck). u: (n, s) (or
    (|idx|, s) with ``transpose``).
    """
    from ..core.kernels_fn import gram  # deferred: avoid core<->kernels cycle

    bk = resolve_backend(backend, params.kind)
    _check_precision(precision)
    xi = x[idx]
    if bk == "pallas":
        if transpose:
            return gram_mv(
                params, x, u, z=xi, backend="pallas", block=block,
                interpret=interpret, precision=precision,
            )
        return gram_mv(
            params, xi, u, z=x, backend="pallas", block=block,
            interpret=interpret, precision=precision,
        )
    MATVEC_TRACE_COUNTS[bk] += 1
    panel = gram(params, xi, x)  # (|idx|, n)
    return _dot(panel.T, u, precision) if transpose else _dot(panel, u, precision)


def gram_rows_pair(
    params,
    x: jax.Array,
    idx: jax.Array,
    look: jax.Array,
    b: jax.Array,
    *,
    backend: str = "auto",
    block="auto",
    interpret=None,
    precision: str = "fp32",
) -> tuple:
    """Fused stochastic pair step: err = K[idx,:] @ look − b and
    g = K[idx,:]ᵀ @ err in one dispatch — the SGD fit-gradient primitive.

    The unfused path launches two independent row-block matvecs that each
    rebuild the same kernel panel from scratch; here the chunked/dense backends
    build the panel ONCE and reuse it for both contractions, and the Pallas
    backend runs the two-phase ``gram_rows_pair`` kernel (gram_matvec.py) whose
    (|idx|, s) error block never leaves VMEM between the two passes. Counts as
    TWO row-block matvecs — the work of the two calls it replaces — so the
    solver-layer accounting is unchanged. look: (n, s); b: (|idx|, s).
    Differentiable w.r.t. ``params`` on every backend.
    """
    from ..core.kernels_fn import gram  # deferred: avoid core<->kernels cycle

    bk = resolve_backend(backend, params.kind)
    _check_precision(precision)
    MATVEC_TRACE_COUNTS[bk] += 2
    xi = x[idx]
    if bk == "pallas":
        interpret = (not _on_tpu()) if interpret is None else interpret
        ls = params.lengthscale
        p, n = xi.shape[0], x.shape[0]
        bn = _resolve_block(block, "gram", n, x.shape[1], precision)
        xip = _pad_rows(xi / ls, 128)
        xp = _pad_rows(x / ls, bn)
        lookp = _pad_rows(look, bn)
        # unit-signal core: err = σ_f²·err_u with err_u = A_u@look − b/σ_f²,
        # and g = Aᵀ err = σ_f²·A_uᵀ·(σ_f²·err_u) = σ_f⁴·g_u — σ_f² gradients
        # flow through the plain-JAX scaling, like every other fused core
        bp = _pad_rows(b / params.signal, 128)
        err_u, g_u = gram_rows_pair_fused(
            params.kind, bn, bool(interpret), precision, p, xip, xp, lookp, bp
        )
        return params.signal * err_u[:p], (params.signal ** 2) * g_u[:n]
    panel = gram(params, xi, x)  # (|idx|, n) — built once, used twice
    err = _dot(panel, look, precision) - b
    return err, _dot(panel.T, err, precision)


def gram_matvec(params, x, v, z=None, *, jitter=None, block="auto", interpret=None,
                precision: str = "fp32"):
    """(σ_f² k(x,z) + jitter I) @ v — Pallas fused Gram matvec (see gram_matvec.py).

    Thin ``backend="pallas"`` pin over :func:`gram_mv`, kept as the conventional
    name for kernel tests and benchmarks.
    """
    return gram_mv(
        params, x, v, z=z, jitter=jitter, backend="pallas", block=block,
        interpret=interpret, precision=precision,
    )


def resolve_feature_backend(backend: str = "auto", paired: bool = True) -> str:
    """Normalise a backend request to a concrete feature-matvec backend.

    Accepts the feature names (``auto``/``pallas``/``features``) plus the Gram
    names — ``chunked``/``dense`` coerce to ``features`` and the legacy
    ``fused`` alias to ``pallas`` — so a solver spec's single ``backend`` field
    pins the Gram *and* feature sides of a solve consistently. The fused kernel
    only implements the paired sin/cos map: ``auto`` silently falls back to
    ``features`` for the cos-only variant; explicit ``pallas`` raises.
    """
    if backend in ("chunked", "dense"):
        backend = "features"
    elif backend == "fused":
        backend = "pallas"
    if backend not in FEATURE_BACKENDS:
        raise ValueError(
            f"unknown feature backend {backend!r}; expected one of "
            f"{FEATURE_BACKENDS} (or a Gram backend name, coerced to 'features')"
        )
    if backend == "auto":
        return "pallas" if (_on_tpu() and paired) else "features"
    if backend == "pallas" and not paired:
        raise ValueError(
            "the fused RFF kernels only implement the paired sin/cos feature "
            "map; use paired features or backend='features'"
        )
    return backend


def _pad_rff_operands(x, omega, halves, block):
    """Zero-pad x rows, the ω feature rows, and any per-frequency ``halves``
    (sin/cos weight blocks) to block multiples. Padded ω rows give cos→1
    features, but the matching padded weight/cotangent rows are zero, so their
    contribution vanishes; only the 1/m normalisation needs fixing (the caller
    rescales by √(m_pad/m_true)). All pads are plain ``jnp.pad``, so their
    transposes slice the padded cotangents off again under autodiff."""
    m_true = omega.shape[0]
    pad_f = (-m_true) % block
    if pad_f:
        omega = jnp.pad(omega, ((0, pad_f), (0, 0)))
        halves = tuple(jnp.pad(h, ((0, pad_f), (0, 0))) for h in halves)
    return _pad_rows(x, block), omega, halves, m_true + pad_f


def rff_matvec(x, omega, w, *, signal=1.0, block="auto", interpret=None,
               precision: str = "fp32"):
    """Φ(x) @ w (paired sin/cos RFF) — fused, feature matrix never in HBM;
    differentiable w.r.t. ``x``, ``omega``, ``w`` and ``signal`` (custom VJP,
    every pass a fused Pallas contraction — kernels/rff_matvec.py).

    ``signal`` (σ_f²) may be a traced array: the kernel runs with unit signal
    and the √(σ_f²/m) normalisation is applied outside, in plain JAX.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = x.shape[0]
    m_true = omega.shape[0]
    block = _resolve_block(block, "rff", n, x.shape[1], precision)
    xp, omega, (w_sin, w_cos), m_pad = _pad_rff_operands(
        x, omega, (w[:m_true], w[m_true:]), block
    )
    wp = jnp.concatenate([w_sin, w_cos], axis=0)
    out = rff_matvec_fused(
        block, block, bool(interpret), precision, xp, omega, wp
    )[:n]
    # kernel scale is sqrt(1/m_pad); rescale to sqrt(signal/m_true)
    return out * jnp.sqrt(signal * (m_pad / m_true))


def rff_t_matvec(x, omega, u, *, signal=1.0, block="auto", interpret=None,
                 precision: str = "fp32"):
    """Φ(x)ᵀ @ u (paired sin/cos RFF) → (2m, s) — the transposed fused matvec,
    sin/cos halves accumulated per feature tile; differentiable throughout.

    The SGD regulariser pullback primitive (Eq. 3.3): Φᵀ(v − δ) without the
    (n × 2m) feature matrix in HBM.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    m_true = omega.shape[0]
    block = _resolve_block(block, "rff", x.shape[0], x.shape[1], precision)
    xp, omega, _, m_pad = _pad_rff_operands(x, omega, (), block)
    up = _pad_rows(u, block)  # padded rows are zero ⇒ contribute nothing to Φᵀu
    out = rff_t_matvec_fused(block, block, bool(interpret), precision, xp, omega, up)
    out = jnp.concatenate([out[:m_true], out[m_pad:m_pad + m_true]], axis=0)
    return out * jnp.sqrt(signal * (m_pad / m_true))


def _materialised_features(x, omega, signal):
    m = omega.shape[0]
    proj = x @ omega.T  # (n, m)
    return jnp.sqrt(signal / m) * jnp.concatenate(
        [jnp.sin(proj), jnp.cos(proj)], axis=-1
    )  # (n, 2m)


def rff_mv(
    x: jax.Array,
    omega: jax.Array,
    w: jax.Array,
    *,
    signal=1.0,
    backend: str = "auto",
    block="auto",
    interpret=None,
    precision: str = "fp32",
) -> jax.Array:
    """Φ(x) @ w through the selected feature backend — THE feature matvec entry
    point (the ``FeatureOperator`` twin of :func:`gram_mv`); differentiable on
    every backend. x:(n,d) ω:(m,d) w:(2m,) or (2m,s) → (n, s-like)."""
    bk = resolve_feature_backend(backend)
    _check_precision(precision)
    FEATURE_TRACE_COUNTS[bk] += 1
    squeeze = w.ndim == 1
    w2 = w[:, None] if squeeze else w
    if bk == "pallas":
        out = rff_matvec(x, omega, w2, signal=signal, block=block,
                         interpret=interpret, precision=precision)
    else:
        out = _dot(_materialised_features(x, omega, signal), w2, precision)
    return out[:, 0] if squeeze else out


def rff_t_mv(
    x: jax.Array,
    omega: jax.Array,
    u: jax.Array,
    *,
    signal=1.0,
    backend: str = "auto",
    block="auto",
    interpret=None,
    precision: str = "fp32",
) -> jax.Array:
    """Φ(x)ᵀ @ u through the selected feature backend — the transposed feature
    matvec entry point. x:(n,d) ω:(m,d) u:(n,) or (n,s) → (2m, s-like)."""
    bk = resolve_feature_backend(backend)
    _check_precision(precision)
    FEATURE_TRACE_COUNTS[bk] += 1
    squeeze = u.ndim == 1
    u2 = u[:, None] if squeeze else u
    if bk == "pallas":
        out = rff_t_matvec(x, omega, u2, signal=signal, block=block,
                           interpret=interpret, precision=precision)
    else:
        out = _dot(_materialised_features(x, omega, signal).T, u2, precision)
    return out[:, 0] if squeeze else out


def rff_pair_mv(
    x: jax.Array,
    omega: jax.Array,
    u: jax.Array,
    *,
    signal=1.0,
    backend: str = "auto",
    block="auto",
    interpret=None,
    precision: str = "fp32",
) -> jax.Array:
    """Φ(x) (Φ(x)ᵀ u) — the SGD regulariser composition (Eq. 3.3) in ONE
    dispatch. On the features backend Φ is materialised once and reused for
    both contractions; on Pallas the two-phase ``rff_pair`` kernel keeps the
    (2m, s) intermediate in VMEM for its whole lifetime (rff_matvec.py).
    Counts as TWO feature matvecs — the work of the Φᵀ/Φ pair it replaces.
    x:(n,d) ω:(m,d) u:(n,) or (n,s) → (n, s-like); differentiable throughout.
    """
    bk = resolve_feature_backend(backend)
    _check_precision(precision)
    FEATURE_TRACE_COUNTS[bk] += 2
    squeeze = u.ndim == 1
    u2 = u[:, None] if squeeze else u
    if bk == "pallas":
        interpret = (not _on_tpu()) if interpret is None else interpret
        n = x.shape[0]
        m_true = omega.shape[0]
        bm = _resolve_block(block, "rff", n, x.shape[1], precision)
        xp = _pad_rows(x, bm)
        om = _pad_rows(omega, 128)
        up = _pad_rows(u2, bm)
        raw = rff_pair_fused(bm, bool(interpret), precision, m_true, xp, om, up)
        # core normalisation is 1/m_pad (both Φ̃ factors); want signal/m_true
        out = raw[:n] * (signal * (om.shape[0] / m_true))
    else:
        feats = _materialised_features(x, omega, signal)  # built once, used twice
        out = _dot(feats, _dot(feats.T, u2, precision), precision)
    return out[:, 0] if squeeze else out


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None):
    """q: (b, s, hq, d), k/v: (b, s, hkv, d) with hq % hkv == 0 (GQA) → (b, s, hq, d)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    # GQA: index kv heads per q head (gather, no broadcast materialisation pre-kernel)
    head_map = jnp.arange(hq) // group
    kq = k[:, :, head_map]  # (b, s, hq, d)
    vq = v[:, :, head_map]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = kq.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    vf = vq.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    pad = (-s) % max(block_q, block_k)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, block_q=block_q, block_k=block_k,
        kv_len=(s if pad else None), interpret=interpret,
    )[:, :s]
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
