"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; tests sweep shapes/dtypes and assert_allclose the Pallas
kernels (interpret=True on CPU) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _stationary_map(d2: jax.Array, kind: str) -> jax.Array:
    if kind == "se":
        return jnp.exp(-0.5 * d2)
    r = jnp.sqrt(d2 + 1e-36)
    if kind == "matern12":
        return jnp.exp(-r)
    if kind == "matern32":
        s = jnp.sqrt(3.0) * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == "matern52":
        s = jnp.sqrt(5.0) * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(kind)


def gram_matvec_ref(
    x: jax.Array,
    z: jax.Array,
    v: jax.Array,
    *,
    kind: str = "se",
    signal: float = 1.0,
    jitter: float = 0.0,
) -> jax.Array:
    """(signal·k(x,z) + jitter·I_square) @ v. x:(n,d) z:(m,d) v:(m,s) → (n,s).

    Inputs are assumed already lengthscale-scaled (x/ℓ).
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    zn = jnp.sum(z * z, axis=-1)[None, :]
    d2 = jnp.maximum(xn + zn - 2.0 * (x @ z.T), 0.0)
    k = signal * _stationary_map(d2, kind)
    out = k @ v
    if jitter:
        assert x.shape[0] == z.shape[0]
        out = out + jitter * v
    return out


def rff_matvec_ref(
    x: jax.Array, omega: jax.Array, w: jax.Array, *, signal: float = 1.0
) -> jax.Array:
    """Φ(x) @ w with paired sin/cos features. x:(n,d) ω:(m,d) w:(2m,s) → (n,s)."""
    m = omega.shape[0]
    proj = x @ omega.T
    phi = jnp.sqrt(signal / m) * jnp.concatenate([jnp.sin(proj), jnp.cos(proj)], -1)
    return phi @ w


def rff_t_matvec_ref(
    x: jax.Array, omega: jax.Array, u: jax.Array, *, signal: float = 1.0
) -> jax.Array:
    """Φ(x)ᵀ @ u with paired sin/cos features. x:(n,d) ω:(m,d) u:(n,s) → (2m,s)."""
    m = omega.shape[0]
    proj = x @ omega.T
    phi = jnp.sqrt(signal / m) * jnp.concatenate([jnp.sin(proj), jnp.cos(proj)], -1)
    return phi.T @ u


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """Reference attention. q,k,v: (b, s, h, dh) → (b, s, h, dh)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    if causal:
        s_q, s_k = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((s_q, s_k), bool), k=s_k - s_q)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
