"""Fused random-Fourier-feature matvec Pallas kernels — forward, transpose, backward.

Forward: O = Φ(X) @ W with Φ(x) = sqrt(σ_f²/m)·[sin(xΩᵀ) | cos(xΩᵀ)] without
materialising the (n × 2m) feature matrix in HBM: each (bm × bf) projection tile is
built on the MXU, the sin/cos map applied in VREGs, and both halves contracted
against the corresponding W rows into a VMEM accumulator.

Transpose (``rff_t_matvec_pallas``): Φ(X)ᵀ @ U with the sin/cos halves accumulated
per feature tile — the SGD regulariser pullback (Eq. 3.3) and the ∂W rule of the
forward. Backward (``rff_bwd_pallas``): cotangents w.r.t. X and Ω via the identity

    ∂L/∂proj_ij = scale·(cos(proj_ij)·(ḡ_i·Wsin_j) − sin(proj_ij)·(ḡ_i·Wcos_j))
    ∂x_i = Σ_j (∂L/∂proj_ij)·ω_j        ∂ω_j = Σ_i (∂L/∂proj_ij)·x_i

— one kernel accumulating the cos/sin-weighted contractions per tile, the n×m
weight matrix never leaving VMEM (same design as the Gram backward kernel).

``rff_matvec_fused`` / ``rff_t_matvec_fused`` wrap the kernels in ``jax.custom_vjp``
so every pass — forward, transpose, and both input cotangents — runs through fused
tiles. The σ_f² signal scale is folded *outside* the cores (ops.py), like the Gram
kernel, so its gradient flows through ordinary autodiff; the cores carry only the
static √(1/m) normalisation.

Used by RFF prior-function evaluation (core/rff.py), the SGD regulariser term
(core/solvers/sgd.py), and every differentiated posterior-sample evaluation
(Thompson ascent) — the dominant non-Gram cost at the paper's scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gram_matvec import _cast_mxu


def _proj(x, om, precision):
    """The (bm, bf) projection tile x Ωᵀ — MXU operands cast per the tile
    precision, fp32 accumulation (see gram_matvec.TILE_PRECISIONS)."""
    return jax.lax.dot_general(
        _cast_mxu(x, precision), _cast_mxu(om, precision),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )


def _rff_kernel(
    x_ref, om_ref, wsin_ref, wcos_ref, o_ref, acc_ref, *, scale, nfeat, precision
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    proj = _proj(x_ref[...], om_ref[...], precision)  # (bm, bf)
    wsin = _cast_mxu(wsin_ref[...], precision)
    wcos = _cast_mxu(wcos_ref[...], precision)
    acc_ref[...] += scale * (
        jax.lax.dot_general(_cast_mxu(jnp.sin(proj), precision), wsin,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(_cast_mxu(jnp.cos(proj), precision), wcos,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    )

    @pl.when(j == nfeat - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("signal", "block_m", "block_f", "interpret", "precision")
)
def rff_matvec_pallas(
    x: jax.Array,
    omega: jax.Array,
    w: jax.Array,
    *,
    signal: float = 1.0,
    block_m: int = 256,
    block_f: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
) -> jax.Array:
    """x:(n,d) ω:(m,d) w:(2m,s) (sin rows then cos rows) → (n,s). Pre-padded."""
    n, d = x.shape
    m = omega.shape[0]
    s = w.shape[1]
    assert n % block_m == 0 and m % block_f == 0
    assert w.shape[0] == 2 * m
    w_sin, w_cos = w[:m], w[m:]
    nfeat = m // block_f
    scale = (signal / m) ** 0.5
    return pl.pallas_call(
        functools.partial(
            _rff_kernel, scale=scale, nfeat=nfeat, precision=precision
        ),
        grid=(n // block_m, nfeat),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_f, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_f, s), lambda i, j: (j, 0)),
            pl.BlockSpec((block_f, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), w.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, s), jnp.float32)],
        interpret=interpret,
    )(x, omega, w_sin, w_cos)


# ---------------------------------------------------------------------------
# Transposed fused matvec: Φ(X)ᵀ @ U, sin/cos halves accumulated per feature tile
# ---------------------------------------------------------------------------


def _rff_t_kernel(
    x_ref, om_ref, u_ref, osin_ref, ocos_ref, accs_ref, accc_ref,
    *, scale, nrows, precision
):
    i = pl.program_id(1)  # row tile (innermost: the feature-tile output stays
    # resident in VMEM across the full row accumulation)

    @pl.when(i == 0)
    def _init():
        accs_ref[...] = jnp.zeros_like(accs_ref)
        accc_ref[...] = jnp.zeros_like(accc_ref)

    proj = _proj(x_ref[...], om_ref[...], precision)  # (bm, bf)
    u = _cast_mxu(u_ref[...], precision)  # (bm, s)
    # sin(proj)ᵀ @ u and cos(proj)ᵀ @ u — contract the row dimension on the MXU
    accs_ref[...] += scale * jax.lax.dot_general(
        _cast_mxu(jnp.sin(proj), precision), u, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bf, s)
    accc_ref[...] += scale * jax.lax.dot_general(
        _cast_mxu(jnp.cos(proj), precision), u, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == nrows - 1)
    def _flush():
        osin_ref[...] = accs_ref[...].astype(osin_ref.dtype)
        ocos_ref[...] = accc_ref[...].astype(ocos_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("signal", "block_m", "block_f", "interpret", "precision")
)
def rff_t_matvec_pallas(
    x: jax.Array,
    omega: jax.Array,
    u: jax.Array,
    *,
    signal: float = 1.0,
    block_m: int = 256,
    block_f: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
) -> jax.Array:
    """Φ(x)ᵀ @ u: x:(n,d) ω:(m,d) u:(n,s) → (2m,s) (sin rows then cos rows).

    Pre-padded; padded u rows must be zero (they are — ops.py zero-pads).
    """
    n, d = x.shape
    m = omega.shape[0]
    s = u.shape[1]
    assert n % block_m == 0 and m % block_f == 0
    nrows = n // block_m
    scale = (signal / m) ** 0.5
    osin, ocos = pl.pallas_call(
        functools.partial(
            _rff_t_kernel, scale=scale, nrows=nrows, precision=precision
        ),
        grid=(m // block_f, nrows),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda j, i: (i, 0)),
            pl.BlockSpec((block_f, d), lambda j, i: (j, 0)),
            pl.BlockSpec((block_m, s), lambda j, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_f, s), lambda j, i: (j, 0)),
            pl.BlockSpec((block_f, s), lambda j, i: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, s), u.dtype),
            jax.ShapeDtypeStruct((m, s), u.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_f, s), jnp.float32),
            pltpu.VMEM((block_f, s), jnp.float32),
        ],
        interpret=interpret,
    )(x, omega, u)
    return jnp.concatenate([osin, ocos], axis=0)


# ---------------------------------------------------------------------------
# Backward kernel: input cotangents of the projection proj = R Cᵀ.
#
# The cotangent of L through proj is the (rows × cols) matrix
#     W = cos(proj) ⊙ (P₁ Q₁ᵀ) − sin(proj) ⊙ (P₂ Q₂ᵀ)
# and the output is  dR = scale · W @ C.  Instantiations:
#   * ∂x of Φ(x)w:  R=x, C=ω, P₁=P₂=ḡ, Q₁=w_sin, Q₂=w_cos;
#   * ∂ω of Φ(x)w:  R=ω, C=x, P₁=w_sin, P₂=w_cos, Q₁=Q₂=ḡ  (Wᵀ by symmetry);
#   * ∂x/∂ω of Φ(x)ᵀu: same with ḡ ↦ u and w_sin/w_cos ↦ the sin/cos halves of
#     the (2m, s) cotangent.
# W never exists in HBM — per tile it is three MXU contractions in VMEM.
# ---------------------------------------------------------------------------


def _rff_bwd_kernel(
    r_ref, c_ref, p1_ref, p2_ref, q1_ref, q2_ref, o_ref, acc_ref,
    *, scale, ncols, precision
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...]  # (bn, d)
    proj = _proj(r_ref[...], c, precision)  # (bm, bn)
    a = jax.lax.dot_general(
        _cast_mxu(p1_ref[...], precision), _cast_mxu(q1_ref[...], precision),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bm, bn) = P₁_i · Q₁_j
    b = jax.lax.dot_general(
        _cast_mxu(p2_ref[...], precision), _cast_mxu(q2_ref[...], precision),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w = jnp.cos(proj) * a - jnp.sin(proj) * b
    acc_ref[...] += scale * jax.lax.dot_general(
        _cast_mxu(w, precision), _cast_mxu(c, precision),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (bm, d)

    @pl.when(j == ncols - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_m", "block_n", "interpret", "precision")
)
def rff_bwd_pallas(
    r: jax.Array,
    c: jax.Array,
    p1: jax.Array,
    p2: jax.Array,
    q1: jax.Array,
    q2: jax.Array,
    *,
    scale: float,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
) -> jax.Array:
    """dR = scale · (cos(RCᵀ)⊙(P₁Q₁ᵀ) − sin(RCᵀ)⊙(P₂Q₂ᵀ)) @ C — (rows, d)."""
    n, d = r.shape
    m = c.shape[0]
    assert n % block_m == 0 and m % block_n == 0, (n, m, block_m, block_n)
    ncols = m // block_n
    s = p1.shape[1]
    return pl.pallas_call(
        functools.partial(
            _rff_bwd_kernel, scale=scale, ncols=ncols, precision=precision
        ),
        grid=(n // block_m, ncols),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, s), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, s), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, s), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        interpret=interpret,
    )(r, c, p1, p2, q1, q2)


# ---------------------------------------------------------------------------
# Differentiable fused cores (unit signal; ops.py folds σ_f² outside so its
# gradient is plain autodiff, exactly like the Gram kernel).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def rff_matvec_fused(block_m, block_f, interpret, precision, x, omega, w):
    """Φ̃(x) @ w with Φ̃ = sqrt(1/m)·[sin(xΩᵀ) | cos(xΩᵀ)], differentiable w.r.t.
    x, ω and w — every pass a fused Pallas kernel. Operands pre-padded to block
    multiples (ops.py pads; padded w/u rows are zero so cotangents vanish there
    and the surrounding ``jnp.pad`` transposes slice them off)."""
    return rff_matvec_pallas(
        x, omega, w, signal=1.0, block_m=block_m, block_f=block_f,
        interpret=interpret, precision=precision,
    )


def _rff_matvec_fused_fwd(block_m, block_f, interpret, precision, x, omega, w):
    out = rff_matvec_fused(block_m, block_f, interpret, precision, x, omega, w)
    return out, (x, omega, w)


def _rff_matvec_fused_bwd(block_m, block_f, interpret, precision, res, g):
    x, omega, w = res
    m = omega.shape[0]
    scale = (1.0 / m) ** 0.5
    w_sin, w_cos = w[:m], w[m:]
    # ∂w = Φ̃ᵀ ḡ — the transposed fused matvec
    dw = rff_t_matvec_pallas(
        x, omega, g, signal=1.0, block_m=block_m, block_f=block_f,
        interpret=interpret, precision=precision,
    )
    # ∂x_i = Σ_j [cos(x_i·ω_j)(ḡ_i·ws_j) − sin(x_i·ω_j)(ḡ_i·wc_j)]·scale·ω_j
    dx = rff_bwd_pallas(
        x, omega, g, g, w_sin, w_cos, scale=scale, block_m=block_m,
        block_n=block_f, interpret=interpret, precision=precision,
    )
    # ∂ω_j — the same kernel with rows/cols and factor roles swapped (Wᵀ)
    dom = rff_bwd_pallas(
        omega, x, w_sin, w_cos, g, g, scale=scale, block_m=block_f,
        block_n=block_m, interpret=interpret, precision=precision,
    )
    return dx, dom, dw


rff_matvec_fused.defvjp(_rff_matvec_fused_fwd, _rff_matvec_fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def rff_t_matvec_fused(block_m, block_f, interpret, precision, x, omega, u):
    """Φ̃(x)ᵀ @ u (unit signal), differentiable w.r.t. x, ω and u."""
    return rff_t_matvec_pallas(
        x, omega, u, signal=1.0, block_m=block_m, block_f=block_f,
        interpret=interpret, precision=precision,
    )


def _rff_t_matvec_fused_fwd(block_m, block_f, interpret, precision, x, omega, u):
    out = rff_t_matvec_fused(block_m, block_f, interpret, precision, x, omega, u)
    return out, (x, omega, u)


def _rff_t_matvec_fused_bwd(block_m, block_f, interpret, precision, res, g):
    x, omega, u = res
    m = omega.shape[0]
    scale = (1.0 / m) ** 0.5
    g_sin, g_cos = g[:m], g[m:]  # (2m, s) cotangent split into halves
    # ∂u = Φ̃ ḡ — the forward fused matvec against the cotangent
    du = rff_matvec_pallas(
        x, omega, g, signal=1.0, block_m=block_m, block_f=block_f,
        interpret=interpret, precision=precision,
    )
    # L = Σ ḡ ⊙ (Φ̃ᵀu) = Σ u ⊙ (Φ̃ḡ): same projection cotangent with ḡ ↦ u
    dx = rff_bwd_pallas(
        x, omega, u, u, g_sin, g_cos, scale=scale, block_m=block_m,
        block_n=block_f, interpret=interpret, precision=precision,
    )
    dom = rff_bwd_pallas(
        omega, x, g_sin, g_cos, u, u, scale=scale, block_m=block_f,
        block_n=block_m, interpret=interpret, precision=precision,
    )
    return dx, dom, du


rff_t_matvec_fused.defvjp(_rff_t_matvec_fused_fwd, _rff_t_matvec_fused_bwd)


# ---------------------------------------------------------------------------
# Fused regulariser pair: Φ̃(x) (Φ̃(x)ᵀ u) in ONE launch — the SGD regulariser
# (Eq. 3.3) composition. The (2m, s) intermediate t = Φ̃ᵀu lives in a VMEM
# scratch spanning the whole (padded) feature axis and never touches HBM.
# ---------------------------------------------------------------------------


def _rff_pair_kernel(
    x_ref, om_ref, u_ref, o_ref, ts_ref, tc_ref, *, scale, nrows, m_true, precision
):
    """Two-phase grid (phase outermost, row tiles innermost).

    Phase 0 sweeps the row tiles, accumulating the sin/cos halves of
    t = Φ̃ᵀu into VMEM scratches covering the full feature axis; at the last
    row tile the rows belonging to feature padding are zeroed (padded ω rows
    are zero frequencies, whose cos features are identically 1 — their tᵀu
    accumulations are Σᵢuᵢ garbage, not zero). Phase 1 revisits the row tiles,
    rebuilds each projection tile and writes o_i = Φ̃_i t straight out; blocks
    flushed during phase 0 hold dead data that phase 1 fully overwrites.
    """
    ph, i = pl.program_id(0), pl.program_id(1)
    proj = _proj(x_ref[...], om_ref[...], precision)  # (bm, m_pad)
    sn, cs = jnp.sin(proj), jnp.cos(proj)

    @pl.when(ph == 0)
    def _accumulate():
        @pl.when(i == 0)
        def _init():
            ts_ref[...] = jnp.zeros_like(ts_ref)
            tc_ref[...] = jnp.zeros_like(tc_ref)

        u = _cast_mxu(u_ref[...], precision)  # (bm, s)
        ts_ref[...] += scale * jax.lax.dot_general(
            _cast_mxu(sn, precision), u, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (m_pad, s)
        tc_ref[...] += scale * jax.lax.dot_general(
            _cast_mxu(cs, precision), u, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(i == nrows - 1)
        def _finalize():
            rows = jax.lax.broadcasted_iota(jnp.int32, ts_ref.shape, 0)
            keep = rows < m_true
            ts_ref[...] = jnp.where(keep, ts_ref[...], 0.0)
            tc_ref[...] = jnp.where(keep, tc_ref[...], 0.0)

    @pl.when(ph == 1)
    def _apply():
        o_ref[...] = (
            scale * (
                jax.lax.dot_general(
                    _cast_mxu(sn, precision), _cast_mxu(ts_ref[...], precision),
                    (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
                )
                + jax.lax.dot_general(
                    _cast_mxu(cs, precision), _cast_mxu(tc_ref[...], precision),
                    (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
                )
            )
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "interpret", "precision", "m_true")
)
def rff_pair_pallas(
    x: jax.Array,
    omega: jax.Array,
    u: jax.Array,
    *,
    block_m: int = 256,
    interpret: bool = False,
    precision: str = "fp32",
    m_true: int | None = None,
) -> jax.Array:
    """Φ̃(x) (Φ̃(x)ᵀ u) with Φ̃ = sqrt(1/m)·[sin|cos] — x:(n,d) ω:(m,d) u:(n,s)
    → (n,s), pre-padded (n to block_m, m to 128 multiples; padded u rows zero).
    The feature axis is NOT tiled: both (m, s) halves of the intermediate stay
    resident in VMEM across the whole grid. ``m_true`` masks feature padding.
    """
    n, d = x.shape
    m = omega.shape[0]
    s = u.shape[1]
    assert n % block_m == 0 and m % 128 == 0, (n, m, block_m)
    m_true = m if m_true is None else m_true
    nrows = n // block_m
    scale = (1.0 / m) ** 0.5
    return pl.pallas_call(
        functools.partial(
            _rff_pair_kernel, scale=scale, nrows=nrows, m_true=m_true,
            precision=precision,
        ),
        grid=(2, nrows),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda ph, i: (i, 0)),
            pl.BlockSpec((m, d), lambda ph, i: (0, 0)),
            pl.BlockSpec((block_m, s), lambda ph, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, s), lambda ph, i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), u.dtype),
        scratch_shapes=[
            pltpu.VMEM((m, s), jnp.float32),
            pltpu.VMEM((m, s), jnp.float32),
        ],
        interpret=interpret,
    )(x, omega, u)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def rff_pair_fused(block_m, interpret, precision, m_true, x, omega, u):
    """Differentiable fused pair Φ̃(Φ̃ᵀu) (unit signal; ops.py folds σ_f²·m_pad/m
    outside). The VJP composes existing fused primitives: du is the pair itself
    (the operator is symmetric PSD), and dx/dω run through ``rff_bwd_pallas``
    on the concatenated factors of dΦ̃ = ō tᵀ + u t̃ᵀ (t = Φ̃ᵀu, t̃ = Φ̃ᵀō)."""
    return rff_pair_pallas(
        x, omega, u, block_m=block_m, interpret=interpret, precision=precision,
        m_true=m_true,
    )


def _rff_pair_fused_fwd(block_m, interpret, precision, m_true, x, omega, u):
    out = rff_pair_fused(block_m, interpret, precision, m_true, x, omega, u)
    return out, (x, omega, u)


def _rff_pair_fused_bwd(block_m, interpret, precision, m_true, res, g):
    x, omega, u = res
    m = omega.shape[0]
    scale = (1.0 / m) ** 0.5
    kw = dict(block_m=block_m, block_f=min(128, m), interpret=interpret,
              precision=precision)
    # ∂u = Φ̃ Φ̃ᵀ ḡ — the pair itself (symmetric operator)
    du = rff_pair_fused(block_m, interpret, precision, m_true, x, omega, g)
    # t = Φ̃ᵀu and t̃ = Φ̃ᵀḡ, masked to the true feature rows exactly like the
    # forward masks its VMEM intermediate
    keep = (jnp.arange(m) < m_true)[:, None]
    t = rff_t_matvec_pallas(x, omega, u, signal=1.0, **kw)
    tt = rff_t_matvec_pallas(x, omega, g, signal=1.0, **kw)
    t_s, t_c = jnp.where(keep, t[:m], 0.0), jnp.where(keep, t[m:], 0.0)
    tt_s, tt_c = jnp.where(keep, tt[:m], 0.0), jnp.where(keep, tt[m:], 0.0)
    # dL/dΦ̃ = ḡ tᵀ + u t̃ᵀ — rank-2s factors for the projection cotangent
    pp = jnp.concatenate([g, u], axis=1)  # (n, 2s)
    q1 = jnp.concatenate([t_s, tt_s], axis=1)  # (m, 2s)
    q2 = jnp.concatenate([t_c, tt_c], axis=1)
    dx = rff_bwd_pallas(
        x, omega, pp, pp, q1, q2, scale=scale, block_m=block_m,
        block_n=min(128, m), interpret=interpret, precision=precision,
    )
    dom = rff_bwd_pallas(
        omega, x, q1, q2, pp, pp, scale=scale, block_m=min(128, m),
        block_n=block_m, interpret=interpret, precision=precision,
    )
    return dx, dom, du


rff_pair_fused.defvjp(_rff_pair_fused_fwd, _rff_pair_fused_bwd)
