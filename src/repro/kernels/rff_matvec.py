"""Fused random-Fourier-feature matvec Pallas kernel.

Computes O = Φ(X) @ W with Φ(x) = sqrt(σ_f²/m)·[sin(xΩᵀ) | cos(xΩᵀ)] without
materialising the (n × 2m) feature matrix in HBM: each (bm × bf) projection tile is
built on the MXU, the sin/cos map applied in VREGs, and both halves contracted
against the corresponding W rows into a VMEM accumulator.

Used by RFF prior-function evaluation (core/rff.py) and the SGD regulariser term
(Eq. 3.3) where fresh features are drawn every step — the dominant non-Gram cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rff_kernel(x_ref, om_ref, wsin_ref, wcos_ref, o_ref, acc_ref, *, scale, nfeat):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, d)
    om = om_ref[...]  # (bf, d)
    proj = jax.lax.dot_general(
        x, om, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, bf)
    acc_ref[...] += scale * (
        jax.lax.dot_general(jnp.sin(proj), wsin_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(jnp.cos(proj), wcos_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    )

    @pl.when(j == nfeat - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("signal", "block_m", "block_f", "interpret")
)
def rff_matvec_pallas(
    x: jax.Array,
    omega: jax.Array,
    w: jax.Array,
    *,
    signal: float = 1.0,
    block_m: int = 256,
    block_f: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x:(n,d) ω:(m,d) w:(2m,s) (sin rows then cos rows) → (n,s). Pre-padded."""
    n, d = x.shape
    m = omega.shape[0]
    s = w.shape[1]
    assert n % block_m == 0 and m % block_f == 0
    assert w.shape[0] == 2 * m
    w_sin, w_cos = w[:m], w[m:]
    nfeat = m // block_f
    scale = (signal / m) ** 0.5
    return pl.pallas_call(
        functools.partial(_rff_kernel, scale=scale, nfeat=nfeat),
        grid=(n // block_m, nfeat),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_f, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_f, s), lambda i, j: (j, 0)),
            pl.BlockSpec((block_f, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), w.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, s), jnp.float32)],
        interpret=interpret,
    )(x, omega, w_sin, w_cos)
