import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture × input shape) cell:
  1. build the production mesh (16×16 single-pod, or 2×16×16 multi-pod),
  2. jit the step function with explicit in/out shardings,
  3. .lower(**abstract inputs).compile()  — proving the distribution config is
     coherent (sharding mismatches / compile-OOM / unsupported collectives fail here),
  4. print memory_analysis() (per-device fit) and cost_analysis(),
  5. run the loop-aware HLO profiler (hlo_analysis) for flops / bytes / collective
     traffic and emit one JSON line per cell for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out dryrun.jsonl
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import SHAPES, ModelConfig, ShapeConfig, cell_is_applicable, get_config, list_configs
from ..models import model as model_lib
from ..models.param import is_leaf
from ..models.sharding_ctx import use_mesh
from ..train.optim import AdamWConfig
from . import steps as steps_lib
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh, num_chips
from .roofline import make_terms, model_flops
from .sharding import (
    activation_rules,
    batch_sharding,
    cache_shardings,
    param_shardings,
    replicated,
    spec_for_axes,
)


def _input_shardings(cfg, shape, mesh, specs: dict):
    b = shape.global_batch
    out = {}
    for k, v in specs.items():
        if k == "cache_index":
            out[k] = replicated(mesh)
        else:
            out[k] = batch_sharding(mesh, v.shape, b)
    return out


def _opt_shardings(params_sh, mesh):
    from ..train.optim import OptState

    return OptState(mu=params_sh, nu=params_sh, step=replicated(mesh))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               config_override=None, opt_cfg: AdamWConfig = AdamWConfig(),
               profile: str = "tp", micro_steps: int = 1):
    """Lower + compile one cell; returns (record dict, compiled) — compiled is None
    for inapplicable (skipped) cells."""
    cfg: ModelConfig = config_override or get_config(arch)
    shape: ShapeConfig = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    base = dict(arch=arch, shape=shape_name, mesh="x".join(map(str, mesh.devices.shape)),
                chips=chips, mode=shape.mode, profile=profile)
    if not ok:
        return dict(base, status="skipped", reason=why), None

    schema = model_lib.param_schema(cfg)
    params_abs = model_lib.abstract_model_params(cfg, steps_lib.COMPUTE_DTYPE)
    params_sh = param_shardings(schema, mesh, profile)
    inputs = steps_lib.input_specs(cfg, shape)
    inputs_sh = _input_shardings(cfg, shape, mesh, inputs)
    rules = activation_rules(mesh, profile)

    t0 = time.time()
    with use_mesh(mesh, rules):
        if shape.mode == "train":
            from ..train.optim import abstract_opt_state

            opt_abs = abstract_opt_state(params_abs, opt_cfg)
            opt_sh = _opt_shardings(params_sh, mesh)
            step = steps_lib.make_train_step(cfg, opt_cfg, micro_steps=micro_steps)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, inputs_sh),
                out_shardings=(params_sh, opt_sh, replicated(mesh)),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, inputs)
        elif shape.mode == "prefill":
            cache_abs = model_lib.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            cache_sh = cache_shardings(cache_abs, mesh, shape.global_batch)
            step = steps_lib.make_prefill_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, inputs_sh),
                out_shardings=(replicated(mesh), cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_abs, cache_abs, inputs)
        else:  # decode
            cache_abs = model_lib.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            cache_sh = cache_shardings(cache_abs, mesh, shape.global_batch)
            step = steps_lib.make_serve_step(cfg)
            tok_sh = inputs_sh["token"]
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh, replicated(mesh)),
                out_shardings=(tok_sh, tok_sh, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_abs, cache_abs, inputs["token"], inputs["cache_index"]
            )
        compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    prof = analyze_hlo(hlo)

    n_params = model_lib.count_params(cfg)
    n_active = model_lib.active_param_count(cfg)
    mflops = model_flops(cfg, shape, n_active)
    # per-device flops from the profiler × chips = global; memory term uses the
    # fusion-aware bytes model (the raw operand+output sum is kept as upper bound)
    terms = make_terms(prof.flops * chips, prof.bytes_fused * chips,
                       prof.collective_bytes * chips, mflops, chips)

    rec = dict(
        base,
        status="ok",
        compile_s=round(compile_s, 1),
        params=n_params,
        active_params=n_active,
        hbm_per_device=dict(
            arguments=mem.argument_size_in_bytes,
            temps=mem.temp_size_in_bytes,
            outputs=mem.output_size_in_bytes,
            total_gb=round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        ),
        cost_analysis=dict(
            flops_raw=cost.get("flops", 0.0),
            bytes_raw=cost.get("bytes accessed", 0.0),
        ),
        hlo_profile=dict(
            flops_per_device=prof.flops,
            bytes_per_device=prof.bytes_fused,
            bytes_upper_per_device=prof.bytes,
            collective_bytes_per_device=prof.collective_bytes,
            collective_by_kind=prof.collective_by_kind,
            collective_counts=prof.collective_counts,
        ),
        roofline=dict(
            compute_s=terms.compute_s,
            memory_s=terms.memory_s,
            collective_s=terms.collective_s,
            dominant=terms.dominant,
            model_flops=mflops,
            useful_fraction=round(terms.useful_fraction, 4),
            mfu=round(terms.mfu, 4),
            step_time_s=terms.step_time_s,
        ),
    )
    return rec, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--resume", action="store_true", help="skip cells already in --out")
    ap.add_argument("--profile", default="tp", help="sharding profile: tp | fsdp")
    args = ap.parse_args(argv)

    cells = []
    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    done = set()
    if args.resume and args.out and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"]))
            except Exception:
                pass

    failures = 0
    for a, s, mp in cells:
        mesh_tag = "2x16x16" if mp else "16x16"
        if (a, s, mesh_tag) in done:
            print(f"[dryrun] {a} × {s} × {mesh_tag}: already done, skipping")
            continue
        print(f"[dryrun] {a} × {s} × {mesh_tag} ...", flush=True)
        try:
            rec, _ = lower_cell(a, s, multi_pod=mp, profile=args.profile)
        except Exception as e:
            traceback.print_exc()
            rec = dict(arch=a, shape=s, mesh=mesh_tag, status="error",
                       error=f"{type(e).__name__}: {e}")
            failures += 1
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  status=ok  compile={rec['compile_s']}s  "
                  f"hbm/dev={rec['hbm_per_device']['total_gb']}GB  "
                  f"dominant={r['dominant']}  mfu={r['mfu']}")
        else:
            print(f"  status={rec['status']}  {rec.get('reason', rec.get('error',''))}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"[dryrun] finished; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
