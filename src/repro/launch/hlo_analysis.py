"""Post-SPMD HLO static profiler for the dry-run roofline (§Roofline).

`compiled.cost_analysis()` does not multiply through `while` loops (lax.scan over
layers counts as ONE iteration) and reports no collective traffic at all. This
module re-derives all three roofline inputs from `compiled.as_text()`:

  flops             — 2·M·N·K for every `dot` (+ conv), × enclosing-loop trip counts
  bytes             — Σ (operand + output bytes) of top-level instructions
                      (fusion-internal ops excluded: a fusion is one HBM round trip)
  collective_bytes  — Σ operand bytes of all-gather / all-reduce / reduce-scatter /
                      all-to-all / collective-permute, × trip counts

Trip counts come from the `backend_config={"known_trip_count":{"n":...}}` attribute
XLA attaches to compiled `while` ops (fallback: the largest constant compared in the
loop condition). All sizes are PER DEVICE (the text is the partitioned module).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[([0-9,]*)\]"
)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = ("parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "copy-start",
             "copy-done")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    return sum(_DTYPE_BYTES[d] * _shape_elems(dims) for d, dims in _SHAPE_RE.findall(text))


@dataclasses.dataclass
class HloProfile:
    flops: float
    bytes: float  # upper bound: every top-level op pays operand+output traffic
    bytes_fused: float  # TPU-fusion model: standalone elementwise ops fuse for free
    collective_bytes: float
    collective_by_kind: dict
    collective_counts: dict
    notes: dict


# Ops that materialise HBM traffic even under aggressive fusion (the bytes_fused
# model): matmuls, fusions XLA already formed, data movement, and cache updates.
_MATERIALIZING = ("dot", "fusion", "dynamic-update-slice", "dynamic-slice", "gather",
                  "scatter", "copy", "convolution", "reduce", "transpose", "concatenate",
                  "pad", "reduce-window", "select-and-scatter", "sort", "rng")


@dataclasses.dataclass
class _Comp:
    name: str
    lines: list
    shapes: dict  # %name -> shape-text (result declarations + typed params)


def _parse_computations(hlo: str) -> dict[str, "_Comp"]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and "->" in line and "=" not in line.split("(")[0]:
            header = line[:-1].strip()
            tok = header.split()[0] if not header.startswith("ENTRY") else header.split()[1]
            name = tok.lstrip("%")
            cur = _Comp(name, [], {})
            comps[name] = cur
            # typed params in the signature: "(p: f32[2,3], q: (s32[], f32[4]))"
            sig = header[len(tok) + (6 if header.startswith("ENTRY") else 0):]
            for m in re.finditer(r"([\w.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)", sig):
                cur.shapes["%" + m.group(1)] = m.group(2)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        cur.lines.append(line)
        if "=" in line:
            lhs, rhs = line.split("=", 1)
            mname = re.search(r"%[\w.\-]+", lhs) or re.search(r"^\s*([\w.\-]+)", lhs)
            if mname:
                nm = mname.group(0).strip()
                if not nm.startswith("%"):
                    nm = "%" + nm
                cur.shapes[nm] = rhs.split("(")[0]
    return comps


def _opcode(rhs: str) -> str:
    """The op name after the result type, e.g. 'bf16[2]{0} all-gather(...'."""
    m = re.search(r"\}?\s*([a-z][\w\-]*)\(", rhs)
    return m.group(1) if m else ""


def _operands(rhs: str) -> list[str]:
    inner = rhs.split("(", 1)[1] if "(" in rhs else ""
    # cut at the matching close paren — approximate: stop at "), " attr boundary
    inner = re.split(r"\)\s*,\s*[a-z_]+=", inner)[0]
    return re.findall(r"%[\w.\-]+", inner)


def _trip_count(line: str, comps, cond_name: str | None) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    if m:
        return int(m.group(1))
    best = 1
    if cond_name and cond_name in comps:
        for ln in comps[cond_name].lines:
            for mm in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(mm.group(1)))
    return best


def _dot_flops(comp: _Comp, line: str) -> float:
    """2 × out_elems × contracted_elems for a dot instruction."""
    lhs, rhs = line.split("=", 1)
    out = _SHAPE_RE.search(rhs)  # result type leads the rhs
    if not out:
        return 0.0
    out_elems = _shape_elems(out.group(2))
    ops = _operands(rhs)
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    contracted = 1
    if ops and mcd:
        lhs_shape = comp.shapes.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(x) for x in sm.group(2).split(",") if x]
            for ci in mcd.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contracted *= dims[int(ci)]
    return 2.0 * out_elems * contracted


def _conv_flops(comp: _Comp, line: str) -> float:
    _, rhs = line.split("=", 1)
    out = _SHAPE_RE.search(rhs)
    ops = _operands(rhs)
    if not out or len(ops) < 2:
        return 0.0
    out_elems = _shape_elems(out.group(2))
    ker = _SHAPE_RE.search(comp.shapes.get(ops[1], ""))
    ker_elems = _shape_elems(ker.group(2)) if ker else 1
    mfg = re.search(r"feature_group_count=(\d+)", rhs)
    fg = int(mfg.group(1)) if mfg else 1
    return 2.0 * out_elems * ker_elems / max(fg, 1)


def analyze_hlo(hlo: str) -> HloProfile:
    comps = _parse_computations(hlo)

    # ---- call graph: (parent, child, kind, mult) --------------------------------
    called: set[str] = set()
    edges: dict[str, list] = defaultdict(list)
    for c in comps.values():
        for line in c.lines:
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1]
            if re.search(r"\bwhile\(", rhs):
                mb = re.search(r"body=%?([\w.\-]+)", rhs)
                mc = re.search(r"condition=%?([\w.\-]+)", rhs)
                if mb:
                    tc = _trip_count(line, comps, mc.group(1) if mc else None)
                    edges[c.name].append((mb.group(1), "loop", tc))
                    called.add(mb.group(1))
                    if mc:
                        edges[c.name].append((mc.group(1), "loop", tc))
                        called.add(mc.group(1))
            for m in re.finditer(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-,% ]+)\}?", rhs):
                for t in re.split(r"[,\s]+", m.group(1)):
                    t = t.lstrip("%")
                    if t in comps:
                        kind = "fused" if "calls=" in rhs or "to_apply=" in rhs else "branch"
                        edges[c.name].append((t, kind, 1))
                        called.add(t)

    # multiplier + topline flag per computation
    mult: dict[str, float] = {}
    topline: dict[str, bool] = {}

    def visit(name: str, m: float, top: bool, depth=0):
        if name not in comps or depth > 50:
            return
        if mult.get(name, 0.0) >= m and topline.get(name, False) >= top:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        topline[name] = topline.get(name, False) or top
        for child, kind, tc in edges.get(name, []):
            visit(child, m * tc, top and kind in ("loop", "branch"), depth + 1)

    entries = [n for n in comps if n not in called]
    for e in entries or list(comps):
        visit(e, 1.0, True)

    flops = 0.0
    bytes_ = 0.0
    bytes_fused = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, int] = defaultdict(int)
    for c in comps.values():
        m = mult.get(c.name, 1.0)
        top = topline.get(c.name, False)
        for line in c.lines:
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1]
            op = _opcode(rhs)
            if op == "dot":
                flops += m * _dot_flops(c, line)
            elif op.startswith("convolution"):
                flops += m * _conv_flops(c, line)
            coll = next((k for k in _COLLECTIVES if op.startswith(k)), None)
            if coll is not None and not op.endswith("-done"):
                ops_ = _operands(rhs)
                sz = sum(_shapes_bytes(c.shapes.get(o, "")) for o in ops_)
                if sz == 0:  # fallback: result shape
                    sz = _shapes_bytes(rhs.split("(")[0])
                coll_bytes[coll] += m * sz
                coll_counts[coll] += 1
            if top and op and not any(op.startswith(s) for s in _SKIP_OPS):
                out_b = _shapes_bytes(rhs.split("(")[0])
                ops_ = _operands(rhs)
                opd_b = sum(_shapes_bytes(c.shapes.get(o, "")) for o in ops_)
                bytes_ += m * (out_b + opd_b)
                # slicing ops touch only the slice, not the whole buffer; DUS/scatter
                # update in place (read+write the update region)
                if op.startswith(("dynamic-slice", "gather")):
                    eff = 2.0 * out_b
                elif op.startswith("dynamic-update-slice"):
                    upd = _shapes_bytes(c.shapes.get(ops_[1], "")) if len(ops_) > 1 else out_b
                    eff = 2.0 * min(upd, out_b)
                elif op.startswith("scatter"):
                    upd = _shapes_bytes(c.shapes.get(ops_[-1], "")) if ops_ else out_b
                    eff = 2.0 * min(upd, out_b)
                else:
                    eff = out_b + opd_b
                if any(op.startswith(k) for k in _MATERIALIZING) or coll is not None:
                    bytes_fused += m * eff

    return HloProfile(
        flops=flops,
        bytes=bytes_,
        bytes_fused=bytes_fused,
        collective_bytes=sum(coll_bytes.values()),
        collective_by_kind=dict(coll_bytes),
        collective_counts=dict(coll_counts),
        notes={"computations": len(comps)},
    )
