"""Production mesh topology (DESIGN.md §5).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod" axis is
pure data parallelism across ICI-disjoint pods (gradient all-reduce crosses DCN).

Functions, not module-level constants: importing this module never touches jax
device state (smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the same logical axes — CPU smoke tests of sharded code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch (pure-DP axes)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_chips(mesh) -> int:
    return mesh.devices.size
