"""Roofline model for the TPU v5e target (§Roofline).

    compute term    = HLO_FLOPs        / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes        / (chips × 819e9  B/s)
    collective term = collective_bytes / (chips × 50e9   B/s per ICI link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). XLA's cost analysis does
NOT multiply through `while` loops (lax.scan over layers), so dryrun.py scales both
by the known scan trip structure before they reach this module; MODEL_FLOPS
(analytic 6·N·D, or 6·N_active·D for MoE) is reported alongside as the
useful-compute yardstick.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips × peak × step_time) — the roofline fraction."""
        t = self.step_time_s
        return self.model_flops / (self.chips * PEAK_FLOPS * t) if t > 0 else 0.0


def make_terms(hlo_flops: float, hlo_bytes: float, coll_bytes: float,
               model_flops: float, chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops / (chips * PEAK_FLOPS),
        memory_s=hlo_bytes / (chips * HBM_BW),
        collective_s=coll_bytes / (chips * ICI_BW),
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=coll_bytes,
        model_flops=model_flops,
        chips=chips,
    )


# ------------------------------------------------------- analytic FLOPs -------


def model_flops(cfg: ModelConfig, shape: ShapeConfig, active_params: int) -> float:
    """6·N_active·D for training; 2·N_active per decoded token (+ attention reads).

    Attention FLOPs (the S² term) are added explicitly since 6·N·D ignores them:
      train:  6·b·s²·h·dh·L   (fwd 2 + bwd 4; ×2 for the two matmuls QK^T and PV
              halves folded into the 12·b·s²·d_attn convention)
      decode: 4·b·S·h·dh per attention layer (one query against S cached keys).
    """
    b, s = shape.global_batch, shape.seq_len
    n_attn = _attention_layers(cfg)
    dh = cfg.head_dim
    h = cfg.num_heads
    if shape.mode == "train":
        dense = 6.0 * active_params * b * s
        attn = 12.0 * b * s * s * h * dh * n_attn * 0.5  # causal halves the square
        return dense + attn
    if shape.mode == "prefill":
        dense = 2.0 * active_params * b * s
        attn = 4.0 * b * s * s * h * dh * n_attn * 0.5
        return dense + attn
    # decode: one token, cache length s
    dense = 2.0 * active_params * b
    attn = 4.0 * b * s * h * dh * n_attn
    return dense + attn


def _attention_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_layer_period
    if cfg.is_encdec:
        return cfg.num_layers * 2 + cfg.encoder_layers  # self + cross + encoder
    return cfg.num_layers
