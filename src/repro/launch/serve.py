"""Serving launcher: batched prefill + greedy decode.

`python -m repro.launch.serve --arch olmo-1b --reduced --prompt-len 32 --gen 16`
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.base import get_config, list_configs
from ..data.pipeline import token_batch
from ..models import model as model_lib
from . import steps as steps_lib


def generate(cfg, params, tokens, max_len: int, gen: int, extra_inputs=None):
    """Prefill the prompt then greedy-decode `gen` tokens.

    Returns ``(tokens, timings)`` where ``tokens`` is ``(b, gen)`` and
    ``timings`` has separate ``prefill_s`` and ``decode_s`` walls (both phases
    blocked on device completion, so the split is real, not dispatch time).
    """
    b, prompt_len = tokens.shape
    cache = model_lib.zero_cache(cfg, b, max_len, jnp.float32)
    inputs = dict(extra_inputs or {}, tokens=tokens)
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    serve_step = jax.jit(steps_lib.make_serve_step(cfg))
    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, inputs)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    tok.block_until_ready()
    t1 = time.perf_counter()
    out = [tok]
    for i in range(gen - 1):
        tok, _, cache = serve_step(params, cache, tok, jnp.asarray(prompt_len + i))
        out.append(tok)
    result = jnp.concatenate(out, axis=1)
    result.block_until_ready()
    timings = {"prefill_s": t1 - t0, "decode_s": time.perf_counter() - t1}
    return result, timings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_configs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_model_params(cfg, jax.random.PRNGKey(args.seed))
    batch = token_batch(args.seed, 0, args.batch, args.prompt_len, cfg.vocab_size)
    extra = {}
    if cfg.is_encdec:
        extra["frames"] = jnp.ones((args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.ones((args.batch, cfg.vision_tokens, cfg.d_model))
    toks, timings = generate(cfg, params, batch["tokens"],
                             args.prompt_len + args.gen, args.gen, extra)
    dt = timings["prefill_s"] + timings["decode_s"]
    # decode throughput is the serving number; guard the division — a tiny
    # reduced config can finish a short decode inside timer resolution
    decode_s = timings["decode_s"]
    rate = f"{args.batch * args.gen / decode_s:.1f} tok/s" if decode_s > 0 else "n/a"
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"(prefill {timings['prefill_s']:.2f}s, decode {decode_s:.2f}s, {rate})")
    print(toks[0])


if __name__ == "__main__":
    main()
