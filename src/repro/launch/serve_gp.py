"""GP posterior serving launcher: drive a `GPEngine` with synthetic traffic.

`python -m repro.launch.serve_gp --n 1024 --d 4 --requests 64 --depth 8`

Closed-loop load generator over the continuous-batching engine
(:mod:`repro.serve`): keep ``--depth`` requests outstanding, submit a mixed
predict/sample/thompson stream, drive ``engine.step()`` until the stream
drains, and print throughput plus the engine's cumulative counter snapshot.
``--repeat`` replays a fraction of the stream with previously-used seeds, which
exercises the warm-start cache (repeat solves re-enter CG at their cached
solution and finish in a couple of iterations).

Write-traffic knobs (docs/serving.md): ``--write-every K`` interleaves an
``engine.add_observations`` call after every K completed read requests,
appending ``--write-batch`` fresh rows from a held-out pool; ``--update``
picks the refit policy (``auto`` takes the rank-k incremental path and
compacts when certified drift exceeds the budget, ``lowrank``/``full`` force
one path). The summary then reports the write-side counters
(``refits``/``lowrank_updates``/``compactions``/``cache_purged``/…).

Fault-tolerance knobs (docs/robustness.md): ``--deadline-ms`` stamps a
relative deadline on every request (expired requests complete with a
structured ``deadline_exceeded`` error instead of queueing); ``--fault-rate``
injects a transient matvec fault into that fraction of solve batches — the
poisoned request is rescued solo through the escalation ladder and the
failure counters (``escalations``/``failed``/``quarantined``/…) show up in
the summary and the ``--json`` snapshot.
"""
from __future__ import annotations

import argparse
import itertools
import json
import random
import time

import jax
import jax.numpy as jnp

from ..core.kernels_fn import make_params
from ..serve import GPEngine, PREDICT, SAMPLE, THOMPSON


def synthetic_dataset(n: int, d: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    kx, kf = jax.random.split(key)
    x = jax.random.uniform(kx, (n, d))
    w = jax.random.normal(kf, (d,))
    y = jnp.sin(4.0 * (x @ w)) + 0.1 * jnp.cos(7.0 * x[:, 0])
    return x, y


def request_stream(num, mix, d, key, num_rows, num_samples):
    """The synthetic workload: an endless (kind, kwargs) iterator."""
    kinds = [k for k in mix for _ in range(mix[k])]
    for i in itertools.count():
        if i >= num:
            return
        kind = kinds[i % len(kinds)]
        if kind == THOMPSON:
            yield kind, dict(num_samples=num_samples, seed=i, num_candidates=128,
                             ascent_steps=5)
        else:
            xs = jax.random.uniform(jax.random.fold_in(key, i), (num_rows, d))
            if kind == PREDICT:
                yield kind, dict(xs=xs, seed=i)
            else:
                yield kind, dict(xs=xs, num_samples=num_samples, seed=i)


def drive(engine: GPEngine, stream, depth: int, *, writes=(), write_every=0,
          update="auto"):
    """Closed loop: keep `depth` requests outstanding until the stream drains.

    With ``write_every > 0``, pop one ``(x_new, y_new)`` batch off ``writes``
    after every ``write_every`` completions and apply it via
    ``engine.add_observations``. A write drains the in-flight queue against
    the pre-update posterior before mutating it, so outstanding has to be
    recounted from the handles afterwards rather than decremented.
    """
    handles = []
    outstanding = 0
    writes_done = 0
    writes = list(writes)
    t0 = time.perf_counter()
    stream = iter(stream)
    exhausted = False
    while not exhausted or outstanding > 0:
        while not exhausted and outstanding < depth:
            nxt = next(stream, None)
            if nxt is None:
                exhausted = True
                break
            kind, kw = nxt
            kw = dict(kw)  # the repeat tail aliases earlier entries
            xs = kw.pop("xs", None)
            h = engine.submit(kind, xs, **kw)
            handles.append(h)
            if not h.done:  # quarantined submits complete immediately
                outstanding += 1
        outstanding -= len(engine.step())
        if write_every > 0 and writes:
            completed = sum(1 for h in handles if h.done)
            if completed // write_every > writes_done:
                xb, yb = writes.pop(0)
                engine.add_observations(xb, yb, update=update)
                writes_done += 1
                outstanding = sum(1 for h in handles if not h.done)
    return handles, time.perf_counter() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="training set size")
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--depth", type=int, default=8, help="outstanding requests")
    ap.add_argument("--solver", default="cg")
    ap.add_argument("--num-rows", type=int, default=16, help="query rows/request")
    ap.add_argument("--num-samples", type=int, default=4, help="RHS cols/request")
    ap.add_argument("--num-features", type=int, default=512)
    ap.add_argument("--max-batch-requests", type=int, default=16)
    ap.add_argument("--max-rhs-columns", type=int, default=64)
    ap.add_argument("--mix", default="predict=2,sample=2,thompson_step=1",
                    help="kind=weight comma list")
    ap.add_argument("--repeat", type=float, default=0.25,
                    help="fraction of the stream replayed with repeat seeds "
                    "(exercises the warm-start cache)")
    ap.add_argument("--write-every", type=int, default=0,
                    help="append a batch of fresh observations after every "
                    "K completed requests (0 = read-only stream)")
    ap.add_argument("--write-batch", type=int, default=4,
                    help="rows per add_observations call")
    ap.add_argument("--update", choices=("auto", "lowrank", "full"),
                    default="auto",
                    help="refit policy for interleaved writes: auto certifies "
                    "the rank-k incremental update and falls back to a full "
                    "warm refit when drift exceeds the compaction budget")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="relative deadline stamped on every request; "
                    "requests still queued past it complete with a "
                    "structured deadline_exceeded error")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="fraction of solve batches hit by a transient "
                    "matvec fault (chaos mode: exercises flag detection, "
                    "solo rescue and the failure counters)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true", help="print stats as JSON")
    args = ap.parse_args(argv)

    mix = {}
    for part in args.mix.split(","):
        kind, _, weight = part.partition("=")
        if kind not in (PREDICT, SAMPLE, THOMPSON):
            raise SystemExit(f"unknown kind {kind!r} in --mix")
        mix[kind] = int(weight or 1)

    total_reads = args.requests + int(args.requests * args.repeat)
    num_writes = (
        total_reads // args.write_every if args.write_every > 0 else 0
    )
    # one synthetic draw covers the training set plus the write pool, so the
    # appended rows come from the same function as the fit data
    x_all, y_all = synthetic_dataset(
        args.n + num_writes * args.write_batch, args.d, args.seed
    )
    x, y = x_all[:args.n], y_all[:args.n]
    writes = [
        (x_all[args.n + i * args.write_batch:args.n + (i + 1) * args.write_batch],
         y_all[args.n + i * args.write_batch:args.n + (i + 1) * args.write_batch])
        for i in range(num_writes)
    ]
    params = make_params("matern32", lengthscale=0.5, signal=1.0, noise=0.1,
                         d=args.d)
    print(f"[serve_gp] fitting posterior state: n={args.n} d={args.d} "
          f"solver={args.solver}", flush=True)
    operator_transform = None
    if args.fault_rate > 0:
        from ..testing import FaultyOperator

        chaos = random.Random(args.seed + 2)

        def operator_transform(op):
            if chaos.random() < args.fault_rate:
                # transient: fires at batch width, vanishes on the narrower
                # solo rescue solve — the rescuable fault model
                return FaultyOperator(
                    op, columns=(0,), min_width=args.num_samples + 1
                )
            return op

    t0 = time.perf_counter()
    engine = GPEngine(
        params, x, y,
        spec=args.solver,
        num_features=args.num_features,
        seed=args.seed,
        max_batch_requests=args.max_batch_requests,
        max_rhs_columns=args.max_rhs_columns,
        default_deadline_s=(
            None if args.deadline_ms is None else args.deadline_ms / 1e3
        ),
        operator_transform=operator_transform,
    )
    print(f"[serve_gp] fit in {time.perf_counter() - t0:.2f}s "
          f"({int(engine.state.fit_result.iterations)} iters)", flush=True)

    stream = list(request_stream(
        args.requests, mix, args.d, jax.random.PRNGKey(args.seed + 1),
        args.num_rows, args.num_samples,
    ))
    nrep = int(len(stream) * args.repeat)
    stream = stream + stream[:nrep]  # repeat seeds → warm-start cache hits

    handles, wall = drive(engine, stream, args.depth, writes=writes,
                          write_every=args.write_every, update=args.update)
    snap = engine.stats()
    served = snap["requests_served"]
    if args.json:
        print(json.dumps(snap, indent=2, sort_keys=True, default=float))
    else:
        rate = len(handles) / wall if wall > 0 else float("inf")
        print(f"[serve_gp] served {len(handles)} requests in {wall:.2f}s "
              f"({rate:.1f} req/s) at depth {args.depth}: {served}")
        print(f"[serve_gp] steps={snap['steps']} batches={snap['batches']} "
              f"solves={snap['solves']} rhs_columns={snap['rhs_columns']} "
              f"(+{snap['padded_columns']} pad)")
        print(f"[serve_gp] solver iterations={snap['solver_iterations']} "
              f"matvecs={snap['solver_matvecs']}; warm hits={snap['warm_hits']} "
              f"(saved {snap['iterations_saved_warm']} iters)")
        print(f"[serve_gp] latency p50={snap['total_latency_p50_s']*1e3:.1f}ms "
              f"p99={snap['total_latency_p99_s']*1e3:.1f}ms "
              f"queue p50={snap['queue_latency_p50_s']*1e3:.1f}ms")
        if snap["refits"]:
            print(f"[serve_gp] writes: refits={snap['refits']} "
                  f"lowrank_updates={snap['lowrank_updates']} "
                  f"(+{snap['lowrank_rows']} rows) "
                  f"compactions={snap['compactions']} "
                  f"refit_iters={snap['refit_iterations']} "
                  f"(saved {snap['refit_iterations_saved']}) "
                  f"cache_purged={snap['cache_purged']} n={snap['n']}")
        faults = {k: snap[k] for k in (
            "failed", "escalations", "deadline_misses", "quarantined",
            "retries", "shed", "degraded",
        ) if snap[k]}
        if faults:
            print(f"[serve_gp] faults: " + " ".join(
                f"{k}={v}" for k, v in sorted(faults.items())
            ))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
