"""Logical-axis → physical-mesh sharding rules (MaxText-style; DESIGN.md §5).

Parameters are FSDP-sharded: the "embed" (d_model) axis shards over the mesh "data"
axis, and tensor-parallel axes (heads / kv / mlp / experts / vocab / d_inner /
kv_lora) shard over "model". The "pod" axis is pure DP for parameters (weights are
replicated across pods; gradients all-reduce over it — the cross-DCN collective).

Activations: batch over ("pod","data"); per-token feature axes over "model".

A weight may name several logical axes that map to the same mesh axis (e.g. MoE
(experts, embed, mlp)); `spec_for_axes` assigns each mesh axis at most once, in
rule-priority order, so PartitionSpecs stay valid.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs.base import ModelConfig, ShapeConfig
from ..models.param import P, is_leaf


# Priority-ordered: earlier rules claim their mesh axis first.
PARAM_RULES: dict[str, Optional[tuple[str, ...]]] = {
    # tensor/expert parallel dims → "model"
    "experts": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "kv_lora": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "d_inner": ("model",),
    # FSDP dim → ("pod","data"): on the multi-pod mesh parameters + optimizer
    # state shard over BOTH DP axes (ZeRO-3 over 32 ways) — required to fit the
    # 398B-param archs; single-pod meshes simply drop the absent "pod" axis.
    "embed": ("pod", "data"),
    # layer-stack dim stays replicated (scanned)
    "layers": None,
}

# Pure-FSDP profile (§Perf hillclimb): for models whose per-device compute is small
# (≤33B dense), 16-way tensor parallelism makes every layer pay (b,s,d)-sized
# all-reduces that dwarf the matmul time. This profile retires the "model" axis
# into extra data/FSDP parallelism: weights shard d_model over ALL devices, batch
# shards over all devices, and the only collectives left are the FSDP param
# all-gathers + gradient reduce-scatters (overlappable with compute).
PARAM_RULES_FSDP: dict[str, Optional[tuple[str, ...]]] = {
    "embed": ("pod", "data", "model"),
    "layers": None,
    "experts": None, "heads": None, "kv": None, "kv_lora": None,
    "mlp": None, "vocab": None, "d_inner": None,
}

ACT_RULES_FSDP: dict[str, Optional[tuple[str, ...]]] = {
    "batch": ("pod", "data", "model"),
    "seq": None, "seq_act": None, "heads_act": None, "kv_act": None,
    "mlp_act": None, "vocab_act": None, "experts_act": None,
}

PROFILES = {"tp": None, "fsdp": (PARAM_RULES_FSDP, ACT_RULES_FSDP)}

ACT_RULES: dict[str, Optional[tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    # Megatron-style sequence parallelism: the residual stream between blocks (and
    # therefore the remat-saved carry stack of the layer scan) shards its sequence
    # dim over "model". Without this the bf16 carry stack alone is
    # L·b_local·s·d·2B ≈ 17 GB/device for llama3-8b train_4k.
    "seq_act": ("model",),
    "heads_act": ("model",),
    "kv_act": ("model",),  # grouped-attention internals: shard the kv-heads dim
    "mlp_act": ("model",),
    "vocab_act": ("model",),
    "experts_act": ("model",),
}


def _filter_rules(rules: dict, mesh: Mesh) -> dict:
    """Drop mesh axes absent from this mesh (e.g. "pod" on the single-pod mesh)."""
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
        else:
            kept = tuple(a for a in v if a in mesh.axis_names)
            out[k] = kept if kept else None
    return out


def activation_rules(mesh: Mesh, profile: str = "tp") -> dict:
    """Rules installed into models.sharding_ctx for with_sharding_constraint."""
    base = ACT_RULES if PROFILES.get(profile) is None else PROFILES[profile][1]
    r = _filter_rules(base, mesh)
    # sharding_ctx expects a flat axis→mesh-axes mapping
    return {k: (v if v is None else (v if len(v) > 1 else v[0])) for k, v in r.items()}


def spec_for_axes(
    logical: tuple, mesh: Mesh, rules: Optional[dict] = None
) -> PartitionSpec:
    """Build a PartitionSpec, assigning each mesh axis at most once (priority order
    = PARAM_RULES declaration order, then positional order)."""
    rules = _filter_rules(PARAM_RULES if rules is None else rules, mesh)
    order = {name: i for i, name in enumerate(rules)}
    used: set[str] = set()
    spec: list = [None] * len(logical)
    # visit dims by rule priority so e.g. "experts" beats "mlp" for the model axis
    dims = sorted(
        range(len(logical)),
        key=lambda i: order.get(logical[i], len(order)),
    )
    for i in dims:
        ax = logical[i]
        mesh_axes = rules.get(ax)
        if not mesh_axes:
            continue
        kept = tuple(a for a in mesh_axes if a not in used)
        if not kept:
            continue
        used.update(kept)
        spec[i] = kept if len(kept) > 1 else kept[0]
    return PartitionSpec(*spec)


def _mesh_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def evenize_spec(spec: PartitionSpec, shape: tuple, mesh: Mesh) -> PartitionSpec:
    """jit in_shardings require each dim divisible by its shard count; drop mesh
    axes (innermost first) on dims that don't divide (e.g. vocab 50280 over 16,
    kv_heads 8 over 16)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else list(entry)
        axes = list(axes)
        while axes and shape[i] % _mesh_size(mesh, tuple(axes)) != 0:
            axes.pop()  # drop innermost
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return PartitionSpec(*out)


def param_shardings(schema: Any, mesh: Mesh, profile: str = "tp") -> Any:
    """NamedSharding tree matching a param schema (P-leaf tree)."""
    rules = PARAM_RULES if PROFILES.get(profile) is None else PROFILES[profile][0]
    return jax.tree.map(
        lambda p: NamedSharding(
            mesh, evenize_spec(spec_for_axes(p.axes, mesh, rules), p.shape, mesh)),
        schema,
        is_leaf=is_leaf,
    )


# ----------------------------------------------------------------- caches -----


def _cache_spec(path: str, shape: tuple, mesh: Mesh, batch: int) -> PartitionSpec:
    """KV/SSM-cache leaf sharding by leaf name (DESIGN.md §5).

    gqa k/v:  (layers.., b, s, kv, dh) → batch over DP axes, kv heads over model.
    mla ckv:  (layers.., b, s, r)      → batch over DP, latent r over model.
    mla krope:(layers.., b, s, rope)   → batch over DP only (tiny).
    mamba conv:(layers.., b, w, c)     → batch over DP, channels over model.
    mamba ssm: (layers.., b, h, n, p)  → batch over DP, heads over model.
    memory:   (b, enc_seq, d)          → batch over DP.

    When batch == 1 (long_500k) the batch dim cannot shard; the cache *sequence*
    dim takes the DP axes instead (sequence parallelism over the KV cache).
    """
    ndim = len(shape)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    model_n = mesh.shape.get("model", 1)
    leaf = path.rsplit("/", 1)[-1]
    trailing = {"k": 4, "v": 4, "ckv": 3, "krope": 3, "conv": 3, "ssm": 4, "memory": 3}
    n_lead = 0 if leaf == "memory" else ndim - trailing[leaf]
    spec: list = [None] * ndim
    seq_shard = batch == 1  # long_500k: batch can't shard → seq takes the DP axes
    has_seq = leaf in ("k", "v", "ckv", "krope", "memory")
    spec[n_lead] = None if seq_shard else dp_spec
    if seq_shard and has_seq:
        spec[n_lead + 1] = dp_spec
    # "model" goes on the first trailing feature dim that divides evenly (kv-heads
    # when divisible, else head_dim; ssm heads else state/head dims; conv channels)
    if leaf != "krope" and leaf != "memory":
        for i in range(n_lead + (2 if has_seq else 1), ndim):
            if spec[i] is None and shape[i] % model_n == 0:
                spec[i] = "model"
                break
    return evenize_spec(PartitionSpec(*spec), shape, mesh)


def cache_shardings(cache_tree: Any, mesh: Mesh, batch: int) -> Any:
    paths_leaves = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    specs = []
    for path, leaf in paths_leaves:
        name = "/".join(
            getattr(k, "key", getattr(k, "idx", getattr(k, "name", "?"))).__str__()
            for k in path
        )
        specs.append(NamedSharding(mesh, _cache_spec(name, leaf.shape, mesh, batch)))
    treedef = jax.tree_util.tree_structure(cache_tree)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ----------------------------------------------------------------- inputs -----


def batch_sharding(mesh: Mesh, shape: tuple, batch: int) -> NamedSharding:
    """Token/label arrays: (b, s, ...) — batch over DP axes (replicated if b == 1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    spec: list = [None] * len(shape)
    if batch > 1:
        spec[0] = dp_spec
    return NamedSharding(mesh, evenize_spec(PartitionSpec(*spec), shape, mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())
