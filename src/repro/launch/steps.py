"""Step functions lowered by the dry-run and driven by train.py / serve.py.

    train_step(params, opt, batch)            → (params, opt, metrics)
    prefill_step(params, cache, batch)        → (logits, cache)
    serve_step(params, cache, token, index)   → (next_token, logits, cache)

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every *data* input
of the step the shape lowers (tokens/labels, stub frame/patch embeddings, decode
token + cache index). Params / optimizer state / caches get their own abstract trees
(models.model.abstract_model_params, train.optim.abstract_opt_state, abstract_cache).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model as model_lib
from ..train.optim import AdamWConfig, OptState, adamw_update

COMPUTE_DTYPE = jnp.bfloat16


# ----------------------------------------------------------------- inputs -----


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=COMPUTE_DTYPE) -> dict:
    """Abstract data inputs for the step this (arch × shape) cell lowers."""
    b, s = shape.global_batch, shape.seq_len
    ints = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    if shape.mode == "train":
        specs = {"tokens": ints((b, s)), "labels": ints((b, s))}
    elif shape.mode == "prefill":
        specs = {"tokens": ints((b, s))}
    else:  # decode: one new token against a cache of seq_len
        specs = {"token": ints((b, 1)), "cache_index": ints(())}
    if cfg.is_encdec and shape.mode != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.family == "vlm" and shape.mode != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), dtype
        )
    return specs


# ------------------------------------------------------------------- loss -----


def _next_token_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy. logits (b,s,v) fp32, labels (b,s) int32."""
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    lab = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - lab)


# ------------------------------------------------------------------ steps -----


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    micro_steps: int = 1):
    """Fused fwd+bwd+AdamW step. micro_steps > 1 runs gradient accumulation over
    batch slices (lax.scan): activation liveness drops ×micro_steps at the cost of
    holding one fp32 grad accumulator (sharded like the params) — the §Perf
    memory-term lever for the ≥132B cells."""

    def loss_fn(p, batch):
        logits = model_lib.forward_train(cfg, p, batch)
        return _next_token_loss(cfg, logits, batch["labels"])

    def train_step(params: Any, opt: OptState, batch: dict):
        if micro_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((micro_steps, x.shape[0] // micro_steps)
                                    + x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = (acc[0] + l,
                       jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), acc[1], g))
                return acc, None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, grads), _ = jax.lax.scan(body, zero, micro)
            loss = loss_sum / micro_steps
            grads = jax.tree.map(lambda g: g / micro_steps, grads)
        params2, opt2 = adamw_update(params, grads, opt, opt_cfg)
        metrics = {"loss": loss, "step": opt2.step}
        return params2, opt2, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params: Any, cache: Any, batch: dict):
        logits, cache = model_lib.prefill(cfg, params, batch, cache)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params: Any, cache: Any, token: jax.Array, cache_index: jax.Array):
        logits, cache = model_lib.decode_step(cfg, params, token, cache, cache_index)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, cache

    return serve_step


# ---------------------------------------------------------------- helpers -----


def abstract_state(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                   dtype=COMPUTE_DTYPE):
    """Abstract (params, opt) trees for the train dry-run."""
    from ..train.optim import abstract_opt_state

    params = model_lib.abstract_model_params(cfg, dtype)
    return params, abstract_opt_state(params, opt_cfg)
