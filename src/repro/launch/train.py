"""Training launcher: `python -m repro.launch.train --arch olmo-1b [--reduced] ...`

On real hardware this runs the full config on the production mesh; in this
container use --reduced for a CPU-sized variant of the same architecture family.
Checkpoint/restart works the same in both (kill and relaunch to resume).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from ..configs.base import get_config, list_configs
from ..train.optim import AdamWConfig
from ..train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_configs())
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainerConfig(
        batch=args.batch, seq_len=args.seq_len, num_steps=args.steps,
        seed=args.seed, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        opt=AdamWConfig(lr=args.lr),
    )
    tr = Trainer(cfg, tc)
    tr.run(dtype=jnp.float32)
    rep = tr.straggler_report()
    print(f"[train] done. final loss {tr.losses[-1]:.4f}  "
          f"median step {rep.median_s*1e3:.0f} ms  stragglers: {len(rep.slow_steps)}")


if __name__ == "__main__":
    main()
