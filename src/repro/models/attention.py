"""Attention mixers: GQA (llama-family) and MLA (deepseek-v2), each supporting
train (full causal), prefill (causal + returns KV cache), and decode (1 token vs
cache). Pure-jnp attention is the CPU/dry-run path; on TPU the flash-attention
Pallas kernel (kernels/flash_attention.py) is selected via ``use_flash``.

MLA caches the 512-d latent c_kv + shared rope key only (the paper point of MLA);
the baseline decode up-projects the cached latents every step — the documented
hillclimb (EXPERIMENTS.md §Perf) absorbs W_uk into the query instead.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .param import P
from .layers import apply_rope, apply_mrope
from .sharding_ctx import shard


_Q_CHUNK = 512  # query-block size for the streaming (flash-style) path


def _sdpa_block(qg, k, v, *, causal: bool, q_offset, kv_len):
    """One query block. qg: (b,sq,hkv,g,dh); k,v: (b,sk,hkv,dh). fp32 softmax."""
    b, sq, hkv, g, dh = qg.shape
    # no explicit constraint on the grouped-head dims: kv_heads is often not a
    # multiple of the model-axis size (8 vs 16), and forcing it causes involuntary
    # full rematerialisation in SPMD (measured: +4 GB/device, +4.5 s memory term on
    # llama3-8b train_4k). GSPMD propagates a consistent layout from wq/wk/wv.
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * (dh**-0.5)
    sk = k.shape[1]
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        mask = rows >= cols
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len is not None:  # decode: only first kv_len cache entries are valid
        valid = jnp.arange(sk) < kv_len  # (sk,)
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, sq, hkv * g, dh)


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len: Optional[jax.Array] = None):
    """q: (b,sq,h,dh); k,v: (b,sk,hkv,dh), h % hkv == 0.

    Long sequences stream over query blocks (lax.scan) so the (sq × sk) logits
    tensor never materialises at once — O(q_chunk · sk) live memory, the pure-JAX
    analogue of the Pallas flash kernel (kernels/flash_attention.py is the TPU
    runtime path; this is the portable/dry-run path with identical semantics).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    if sq <= _Q_CHUNK:
        return _sdpa_block(qg, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)
    # largest divisor of sq ≤ _Q_CHUNK (whisper's encoder length 1500 → 500)
    qc = next(c for c in range(min(_Q_CHUNK, sq), 0, -1) if sq % c == 0)
    nq = sq // qc
    qb = jnp.moveaxis(qg.reshape(b, nq, qc, hkv, g, dh), 1, 0)

    # remat the block: backward recomputes each chunk's logits/softmax instead of
    # the inner scan stacking (nq, b, hkv, g, qc, sk) fp32 residuals — that stack
    # would be the full s² tensor the streaming exists to avoid.
    blk = jax.checkpoint(
        lambda qblk, off: _sdpa_block(qblk, k, v, causal=causal, q_offset=off,
                                      kv_len=kv_len),
        prevent_cse=False,
    )

    def body(_, inp):
        i, qblk = inp
        return None, blk(qblk, q_offset + i * qc)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qb))  # (nq,b,qc,h,dh)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh)


# ------------------------------------------------------------------ GQA ------


def gqa_params(cfg):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": P((d, h * dh), ("embed", "heads")),
        "wk": P((d, kv * dh), ("embed", "kv")),
        "wv": P((d, kv * dh), ("embed", "kv")),
        "wo": P((h * dh, d), ("heads", "embed")),
    }


def gqa_make_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, max_len, kv, dh)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def gqa_apply(
    p: dict,
    cfg,
    h: jax.Array,
    positions: jax.Array,  # (b, s) int32 or (3, b, s) for m-rope
    mode: str,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    cross_kv: Optional[tuple] = None,
    causal: bool = True,
):
    b, s, d = h.shape
    nh, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(b, s, nh, dh)
    if cross_kv is None:
        k = (h @ p["wk"]).reshape(b, s, kv, dh)
        v = (h @ p["wv"]).reshape(b, s, kv, dh)
    else:  # cross-attention (whisper decoder): kv from encoder memory
        mem = cross_kv[0]
        k = (mem @ p["wk"]).reshape(b, mem.shape[1], kv, dh)
        v = (mem @ p["wv"]).reshape(b, mem.shape[1], kv, dh)
    if cfg.use_mrope and cross_kv is None:
        q = apply_mrope(q, positions, cfg.rope_theta, _mrope_sections(cfg))
        k = apply_mrope(k, positions, cfg.rope_theta, _mrope_sections(cfg))
    elif cross_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cross_kv is not None:
        # cross-attention: encoder memory is fixed; never cached, never masked
        out = _sdpa(q, k, v, causal=False)
        return out.reshape(b, s, nh * dh) @ p["wo"], new_cache
    if mode == "train":
        out = _sdpa(q, k, v, causal=causal)
    elif mode == "prefill":
        out = _sdpa(q, k, v, causal=True)
        new_cache = {  # write the prompt into the full-length cache buffer
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1
            ),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1
            ),
        }
    elif mode == "decode":
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1
        )
        new_cache = {"k": ck, "v": cv}
        out = _sdpa(q, ck, cv, causal=False, kv_len=cache_index + 1)
    else:
        raise ValueError(mode)
    out = out.reshape(b, s, nh * dh)
    return out @ p["wo"], new_cache


def _mrope_sections(cfg):
    half = cfg.head_dim // 2
    t = half // 4
    hw = (half - t) // 2
    return (t, hw, half - t - hw)


# ------------------------------------------------------------------ MLA ------


def mla_params(cfg):
    d, h = cfg.d_model, cfg.num_heads
    r = cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq": P((d, h * (nope + rope_d)), ("embed", "heads")),
        "w_dkv": P((d, r), ("embed", "kv_lora")),
        "w_krope": P((d, rope_d), ("embed", None)),
        "w_uk": P((r, h * nope), ("kv_lora", "heads")),
        "w_uv": P((r, h * vd), ("kv_lora", "heads")),
        "wo": P((h * vd, d), ("heads", "embed")),
    }


def mla_make_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def _mla_attend_block(cfg, q, k_nope, v, krope, kv_len, q_offset, causal):
    """One query block. q: (b,sq,h,nope+rope); k_nope/v: (b,sk,h,·); krope: (b,sk,rope)."""
    b, sq, h, _ = q.shape
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    sk = k_nope.shape[1]
    qn, qr = q[..., :nope], q[..., nope:]
    scale = (nope + rope_d) ** -0.5
    logits = (
        jnp.einsum("bqhd,bshd->bhqs", qn, k_nope)
        + jnp.einsum("bqhd,bsd->bhqs", qr, krope)
    ).astype(jnp.float32) * scale
    logits = shard(logits, "batch", "heads_act", None, None)
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        logits = jnp.where((rows >= cols)[None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk) < kv_len  # (sk,)
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", pr, v)
    return out.reshape(b, sq, -1)


def _mla_attend_absorbed(cfg, q, ckv, krope, p, kv_len=None, q_offset=0, causal=True):
    """§Perf H3 (decode): attend in LATENT space — absorb W_uk into the query and
    W_uv into the output so the 32k-position cache is never up-projected:

        logits = (q_nope W_ukᵀ) ckvᵀ + q_rope kropeᵀ      (contract over r=512)
        out    = (P @ ckv) W_uv                           (weighted latents, then up)

    Per decode step this reads O(s·r) cache bytes instead of O(s·h·(nope+vd))
    up-projections — the MLA memory-term hillclimb. Used when sq is small
    (decode/short prefill); training keeps the standard form (better MXU shapes).
    """
    b, sq, h, _ = q.shape
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    sk = ckv.shape[1]
    qn, qr = q[..., :nope], q[..., nope:]
    w_uk = p["w_uk"].reshape(r, h, nope)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", qn, w_uk)  # (b,sq,h,r)
    scale = (nope + rope_d) ** -0.5
    logits = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv)
        + jnp.einsum("bqhd,bsd->bhqs", qr, krope)
    ).astype(jnp.float32) * scale
    logits = shard(logits, "batch", "heads_act", None, None)
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        logits = jnp.where((rows >= cols)[None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk) < kv_len
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(ckv.dtype)
    lat = jnp.einsum("bhqs,bsr->bqhr", pr, ckv)  # weighted latents
    w_uv = p["w_uv"].reshape(r, h, vd)
    out = jnp.einsum("bqhr,rhd->bqhd", lat, w_uv)
    return out.reshape(b, sq, h * vd)


def _mla_attend(cfg, q, ckv, krope, p, kv_len=None, q_offset=0, causal=True):
    """q: (b,sq,h,nope+rope); ckv: (b,sk,r); krope: (b,sk,rope).

    Streams over query blocks like _sdpa so the (sq × sk) logits never
    materialise at once. The cached latent is up-projected once per call
    (baseline; cfg.mla_absorb=True switches decode to the latent-space form)."""
    b, sq, h, _ = q.shape
    nope, vd = cfg.qk_nope_dim, cfg.v_head_dim
    sk = ckv.shape[1]
    if cfg.mla_absorb and sq <= _Q_CHUNK:
        return _mla_attend_absorbed(cfg, q, ckv, krope, p, kv_len, q_offset, causal)
    k_nope = (ckv @ p["w_uk"]).reshape(b, sk, h, nope)  # baseline: up-project cache
    v = (ckv @ p["w_uv"]).reshape(b, sk, h, vd)
    k_nope = shard(k_nope, "batch", None, "heads_act", None)
    v = shard(v, "batch", None, "heads_act", None)
    if sq <= _Q_CHUNK:
        return _mla_attend_block(cfg, q, k_nope, v, krope, kv_len, q_offset, causal)
    assert sq % _Q_CHUNK == 0, (sq, _Q_CHUNK)
    nq = sq // _Q_CHUNK
    qb = jnp.moveaxis(q.reshape(b, nq, _Q_CHUNK, h, -1), 1, 0)

    blk = jax.checkpoint(
        lambda qblk, off: _mla_attend_block(cfg, qblk, k_nope, v, krope, kv_len, off,
                                            causal),
        prevent_cse=False,
    )

    def body(_, inp):
        i, qblk = inp
        return None, blk(qblk, q_offset + i * _Q_CHUNK)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, -1)


def mla_apply(
    p: dict,
    cfg,
    h: jax.Array,
    positions: jax.Array,
    mode: str,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    **_,
):
    b, s, d = h.shape
    nh = cfg.num_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = (h @ p["wq"]).reshape(b, s, nh, nope + rope_d)
    qr = apply_rope(q[..., nope:], positions, cfg.rope_theta)
    q = jnp.concatenate([q[..., :nope], qr], axis=-1)
    ckv = h @ p["w_dkv"]  # (b, s, r)
    krope = apply_rope((h @ p["w_krope"])[:, :, None, :], positions, cfg.rope_theta)[
        :, :, 0
    ]
    new_cache = cache
    if mode == "train":
        out = _mla_attend(cfg, q, ckv, krope, p, causal=True)
    elif mode == "prefill":
        out = _mla_attend(cfg, q, ckv, krope, p, causal=True)
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1
            ),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], krope.astype(cache["krope"].dtype), 0, axis=1
            ),
        }
    elif mode == "decode":
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_index, axis=1
        )
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope.astype(cache["krope"].dtype), cache_index, axis=1
        )
        new_cache = {"ckv": ck, "krope": kr}
        out = _mla_attend(cfg, q, ck, kr, p, kv_len=cache_index + 1, causal=False)
    else:
        raise ValueError(mode)
    return out @ p["wo"], new_cache
