"""Common layers: RMSNorm (parametric + olmo non-parametric), RoPE / M-RoPE, SwiGLU
MLP, embeddings. Pure functions over param dicts declared with models/param.P.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .param import P
from .sharding_ctx import shard


def rmsnorm_params(cfg):
    if not cfg.parametric_norm:
        return {}
    return {"scale": P((cfg.d_model,), ("embed",), init="ones")}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if "scale" in p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------- RoPE -------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (b, s, h, d); positions: (b, s) int32."""
    freqs = rope_freqs(x.shape[-1], theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (b, s, d/2)
    cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]  # (b,s,1,d/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections=(16, 24, 24)
) -> jax.Array:
    """M-RoPE (qwen2-vl): positions3 (3, b, s); head_dim/2 split into (t,h,w) sections.

    Text tokens carry identical (t,h,w) positions ⇒ reduces to 1-D RoPE there.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # pick which of the 3 position streams drives each frequency index
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # (half,)
    pos = positions3[sec_id]  # (half, b, s) gathered per-frequency stream
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (b, s, half)
    cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- MLP -------


def mlp_params(cfg, d_ff: Optional[int] = None):
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    return {
        "gate": P((d, ff), ("embed", "mlp")),
        "up": P((d, ff), ("embed", "mlp")),
        "down": P((ff, d), ("mlp", "embed")),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    h = shard(h, "batch", "seq", "mlp_act")
    return h @ p["down"]


# ----------------------------------------------------------- embeddings ------


def embed_params(cfg):
    out = {"tok": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        out["unembed"] = P((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return out


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, h: jax.Array) -> jax.Array:
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    return shard(logits, "batch", "seq", "vocab_act")
