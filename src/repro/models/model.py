"""Model assembly: composable decoder / encoder-decoder builder over the mixer and
MLP modules, with lax.scan over homogeneous layer stacks (jamba scans 8-layer
periods) and optional remat. Three entry points per model:

    forward_train(params, inputs)            → logits (b, s, v)
    prefill(params, inputs, cache)           → (last logits, filled cache)
    decode_step(params, token, cache, index) → (logits, updated cache)

Caches are pytrees with a leading layer/period dim so they scan together with the
stacked params. ``abstract_cache``/``param_schema`` provide ShapeDtypeStructs for the
dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    embed,
    embed_params,
    mlp,
    mlp_params,
    rmsnorm,
    rmsnorm_params,
    unembed,
)
from .param import P, abstract_params, init_params, logical_axes, stack_schema
from .sharding_ctx import shard


# --------------------------------------------------------------- schemas -----


def _mixer_params(cfg: ModelConfig, kind: str):
    if kind == "attn":
        return attn_mod.mla_params(cfg) if cfg.use_mla else attn_mod.gqa_params(cfg)
    return ssm_mod.mamba_params(cfg)


def _block_schema(cfg: ModelConfig, mixer: str, mlp_kind: str, cross: bool = False):
    s: dict[str, Any] = {
        "norm1": rmsnorm_params(cfg),
        "mixer": _mixer_params(cfg, mixer),
    }
    if mlp_kind == "dense":
        s["norm2"] = rmsnorm_params(cfg)
        s["mlp"] = mlp_params(cfg)
    elif mlp_kind == "moe":
        s["norm2"] = rmsnorm_params(cfg)
        s["mlp"] = moe_mod.moe_params(cfg)
    if cross:
        s["norm_x"] = rmsnorm_params(cfg)
        s["cross"] = attn_mod.gqa_params(cfg)
    return s


def _layer_plan(cfg: ModelConfig) -> dict:
    """How the layer stack decomposes into scannable homogeneous groups."""
    if cfg.family == "ssm":
        return {"kind": "uniform", "mixer": "mamba", "mlp": "none", "n": cfg.num_layers}
    if cfg.family == "hybrid":
        assert cfg.num_layers % cfg.attn_layer_period == 0
        return {"kind": "period", "n": cfg.num_layers // cfg.attn_layer_period,
                "period": cfg.attn_layer_period}
    mlp_kind = "moe" if cfg.is_moe else "dense"
    return {"kind": "uniform", "mixer": "attn", "mlp": mlp_kind, "n": cfg.num_layers}


def _period_schema(cfg: ModelConfig):
    """jamba 8-layer period: [attn, mamba×7]; MLP alternates dense/moe by parity."""
    per = cfg.attn_layer_period
    n_moe = per // cfg.moe_layer_period
    return {
        "attn_block": _block_schema(cfg, "attn", "dense"),
        "mamba_blocks": stack_schema(_block_schema(cfg, "mamba", "none"), per - 1, None),
        "moe_mlps": stack_schema(
            {"norm2": rmsnorm_params(cfg), "mlp": moe_mod.moe_params(cfg)}, n_moe, None
        ),
        "dense_mlps": stack_schema(
            {"norm2": rmsnorm_params(cfg), "mlp": mlp_params(cfg)}, per - n_moe - 1, None
        ),
    }


def param_schema(cfg: ModelConfig):
    plan = _layer_plan(cfg)
    sch: dict[str, Any] = {"embed": embed_params(cfg), "final_norm": rmsnorm_params(cfg)}
    if plan["kind"] == "uniform":
        sch["layers"] = stack_schema(
            _block_schema(cfg, plan["mixer"], plan["mlp"]), plan["n"]
        )
    else:
        sch["layers"] = stack_schema(_period_schema(cfg), plan["n"])
    if cfg.is_encdec:
        sch["enc_layers"] = stack_schema(
            _block_schema(cfg, "attn", "dense"), cfg.encoder_layers
        )
        sch["enc_norm"] = rmsnorm_params(cfg)
        sch["dec_layers"] = stack_schema(
            _block_schema(cfg, "attn", "dense", cross=True), cfg.num_layers
        )
        del sch["layers"]
    return sch


# --------------------------------------------------------------- caches ------


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    plan = _layer_plan(cfg)

    def attn_cache():
        if cfg.use_mla:
            return attn_mod.mla_make_cache(cfg, batch, max_len, dtype)
        return attn_mod.gqa_make_cache(cfg, batch, max_len, dtype)

    def stackit(tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
        )

    if cfg.is_encdec:
        return {
            "self": stackit(attn_cache(), cfg.num_layers),
            "memory": jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), dtype),
        }
    if plan["kind"] == "uniform":
        if plan["mixer"] == "mamba":
            return {"mamba": stackit(ssm_mod.mamba_make_cache(cfg, batch, dtype), plan["n"])}
        return {"attn": stackit(attn_cache(), plan["n"])}
    per = plan["period"]
    return {
        "attn": stackit(attn_cache(), plan["n"]),
        "mamba": stackit(
            stackit(ssm_mod.mamba_make_cache(cfg, batch, dtype), per - 1), plan["n"]
        ),
    }


def zero_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), abstract_cache(cfg, batch, max_len, dtype)
    )


# --------------------------------------------------------------- blocks ------


def _apply_block(p, cfg, h, positions, mode, cache, cache_index, mixer: str,
                 mlp_kind: str, cross_mem=None):
    attn_fn = attn_mod.mla_apply if cfg.use_mla else attn_mod.gqa_apply
    if mixer == "attn":
        mixed, new_cache = attn_fn(
            p["mixer"], cfg, rmsnorm(p["norm1"], h, cfg.norm_eps), positions, mode,
            cache, cache_index,
        )
    else:
        mixed, new_cache = ssm_mod.mamba_apply(
            p["mixer"], cfg, rmsnorm(p["norm1"], h, cfg.norm_eps), mode, cache,
            cache_index,
        )
    h = h + mixed
    if cross_mem is not None:
        xattn, _ = attn_mod.gqa_apply(
            p["cross"], cfg, rmsnorm(p["norm_x"], h, cfg.norm_eps), positions, mode,
            None, None, cross_kv=(cross_mem,),
        )
        h = h + xattn
    if mlp_kind == "dense":
        h = h + mlp(p["mlp"], rmsnorm(p["norm2"], h, cfg.norm_eps))
    elif mlp_kind == "moe":
        h = h + moe_mod.moe_apply(p["mlp"], cfg, rmsnorm(p["norm2"], h, cfg.norm_eps))
    return h, new_cache


def _apply_period(p, cfg, h, positions, mode, cache, cache_index):
    """jamba 8-layer period (see _period_schema).

    Each sub-block is itself checkpointed (nested remat): the outer period-level
    checkpoint would otherwise keep all 8 blocks' recompute intermediates live at
    once in its backward — measured 146 GB/device on jamba-1.5-large train_4k.
    """
    per = cfg.attn_layer_period
    new_cache = {"attn": None, "mamba": None}
    mamba_caches = []
    i_moe = i_dense = 0
    remat_block = cfg.remat and mode == "train"

    def _ckpt(fn):
        return jax.checkpoint(fn, prevent_cse=False) if remat_block else fn

    for i in range(per):
        is_attn = i == 0
        is_moe = (i % cfg.moe_layer_period) == 1  # global layer 8p+i; odd i → MoE
        if is_attn:
            blk = dict(p["attn_block"])

            def attn_fn(hh, bp, cc):
                return _apply_block(bp, cfg, hh, positions, mode, cc, cache_index,
                                    "attn", "dense")

            h, c = _ckpt(attn_fn)(h, blk, None if cache is None else cache["attn"])
            new_cache["attn"] = c
        else:
            blk = jax.tree.map(lambda a: a[i - 1], p["mamba_blocks"])

            def mamba_fn(hh, bp, cc):
                return _apply_block(bp, cfg, hh, positions, mode, cc, cache_index,
                                    "mamba", "none")

            h, c = _ckpt(mamba_fn)(
                h, blk,
                None if cache is None else jax.tree.map(lambda a: a[i - 1], cache["mamba"]),
            )
            mamba_caches.append(c)
            if is_moe:
                mp = jax.tree.map(lambda a: a[i_moe], p["moe_mlps"])

                def moe_fn(hh, mpp):
                    return hh + moe_mod.moe_apply(
                        mpp["mlp"], cfg, rmsnorm(mpp["norm2"], hh, cfg.norm_eps))

                h = _ckpt(moe_fn)(h, mp)
                i_moe += 1
            else:
                dp = jax.tree.map(lambda a: a[i_dense], p["dense_mlps"])

                def mlp_fn(hh, dpp):
                    return hh + mlp(dpp["mlp"], rmsnorm(dpp["norm2"], hh, cfg.norm_eps))

                h = _ckpt(mlp_fn)(h, dp)
                i_dense += 1
    if cache is not None:
        new_cache["mamba"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *mamba_caches
        )
    return h, new_cache


# --------------------------------------------------------------- model -------


def _seq_shard(h: jax.Array) -> jax.Array:
    """Sequence-parallel residual stream (Megatron SP): between blocks, activations
    (b, s, d) shard their seq dim over the mesh "model" axis. The remat-saved carry
    stack of the layer scan inherits this layout (16× smaller at TP=16)."""
    if h.ndim == 3 and h.shape[1] > 1:
        return shard(h, "batch", "seq_act", None)
    return h


def _scan_stack(apply_fn, stacked_params, h, cache, remat: bool, seq_shard: bool = True):
    """Scan a homogeneous block stack; cache (may be None) scans alongside.

    seq_shard applies sequence parallelism to the inter-block residual — a remat
    *memory* optimisation: only worthwhile when remat saves the carry (training).
    In prefill it forces per-layer gathers that made prefill_32k collective-bound
    (88 s collective term on llama3 before the §Perf H4 fix), so callers pass
    seq_shard=(mode == "train").
    """
    # prevent_cse=False: we are inside lax.scan, where CSE-prevention barriers are
    # unnecessary (jax docs) and on some backends cause the saved bf16 carry stack
    # to be re-materialised in fp32 (observed: +8 GB/device on olmo-1b train_4k).
    fn = jax.checkpoint(apply_fn, prevent_cse=False) if remat else apply_fn
    sq = _seq_shard if seq_shard else (lambda x: x)
    h = sq(h)

    if cache is None:
        def body(carry, p_l):
            out, _ = fn(carry, p_l, None)
            return sq(out), None

        h, _ = jax.lax.scan(body, h, stacked_params)
        return h, None

    def body(carry, xs):
        p_l, c_l = xs
        out, new_c = fn(carry, p_l, c_l)
        return sq(out), new_c

    h, new_cache = jax.lax.scan(body, h, (stacked_params, cache))
    return h, new_cache


def _positions_for(cfg: ModelConfig, batch: int, seq: int, offset) -> jax.Array:
    pos = offset + jnp.arange(seq)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if not cfg.use_mrope:
        return pos
    # M-RoPE: (3, b, s); text positions identical across (t,h,w); the stub vision
    # region gets a 2-D grid in the (h,w) streams.
    vt = cfg.vision_tokens
    side = max(int(vt**0.5), 1)
    th = pos.copy()
    tw = pos.copy()
    if vt and seq >= vt:
        grid = jnp.arange(vt)
        th = th.at[:, :vt].set(grid // side)
        tw = tw.at[:, :vt].set(grid % side)
    return jnp.stack([pos, th, tw])


def _trunk(cfg, params, h, positions, mode, cache, cache_index):
    plan = _layer_plan(cfg)
    remat = cfg.remat and mode == "train"
    if plan["kind"] == "uniform":
        mixer, mlp_kind = plan.get("mixer", "attn"), plan.get("mlp", "dense")

        def apply_fn(hh, p_l, c_l):
            return _apply_block(p_l, cfg, hh, positions, mode, c_l, cache_index,
                                mixer, mlp_kind)

        key = "mamba" if plan["mixer"] == "mamba" else "attn"
        sub_cache = None if cache is None else cache[key]
        h, new_sub = _scan_stack(apply_fn, params["layers"], h, sub_cache, remat,
                                 seq_shard=mode == "train")
        new_cache = None if cache is None else {key: new_sub}
    else:
        def apply_fn(hh, p_l, c_l):
            return _apply_period(p_l, cfg, hh, positions, mode, c_l, cache_index)

        h, new_cache = _scan_stack(apply_fn, params["layers"], h, cache, remat,
                                   seq_shard=mode == "train")
    return h, new_cache


def _encode(cfg, params, frames):
    """whisper encoder over stub frame embeddings (b, enc_seq, d)."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def apply_fn(hh, p_l, c_l):
        del c_l
        out, _ = attn_mod.gqa_apply(
            p_l["mixer"], cfg, rmsnorm(p_l["norm1"], hh, cfg.norm_eps), pos, "train",
            causal=False,
        )
        hh = hh + out
        hh = hh + mlp(p_l["mlp"], rmsnorm(p_l["norm2"], hh, cfg.norm_eps))
        return hh, None

    h, _ = _scan_stack(apply_fn, params["enc_layers"], frames, None, cfg.remat)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _decode_trunk(cfg, params, h, positions, mode, cache, cache_index, memory):
    """whisper decoder stack (self-attn cached + cross-attn to memory)."""
    remat = cfg.remat and mode == "train"

    def apply_fn(hh, p_l, c_l):
        return _apply_block(p_l, cfg, hh, positions, mode, c_l, cache_index,
                            "attn", "dense", cross_mem=memory)

    sub_cache = None if cache is None else cache["self"]
    h, new_sub = _scan_stack(apply_fn, params["dec_layers"], h, sub_cache, remat,
                             seq_shard=mode == "train")
    new_cache = None if cache is None else dict(cache, self=new_sub)
    return h, new_cache


def _inputs_to_h(cfg, params, inputs, mode):
    tokens = inputs["tokens"]
    h = embed(params["embed"], tokens)
    if cfg.family == "vlm" and "vision_embeds" in inputs and mode != "decode":
        vt = cfg.vision_tokens
        h = jnp.concatenate([inputs["vision_embeds"].astype(h.dtype), h[:, vt:]], axis=1)
    return h


def forward_train(cfg: ModelConfig, params, inputs) -> jax.Array:
    """Full causal LM forward → logits (b, s, vocab)."""
    if cfg.is_encdec:
        memory = _encode(cfg, params, inputs["frames"])
        h = embed(params["embed"], inputs["tokens"])
        b, s, _ = h.shape
        pos = _positions_for(cfg, b, s, 0)
        h, _ = _decode_trunk(cfg, params, h, pos, "train", None, None, memory)
    else:
        h = _inputs_to_h(cfg, params, inputs, "train")
        b, s, _ = h.shape
        pos = _positions_for(cfg, b, s, 0)
        h, _ = _trunk(cfg, params, h, pos, "train", None, None)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params["embed"], h)


def prefill(cfg: ModelConfig, params, inputs, cache):
    """Process the prompt, fill the cache, return last-position logits."""
    if cfg.is_encdec:
        memory = _encode(cfg, params, inputs["frames"])
        h = embed(params["embed"], inputs["tokens"])
        b, s, _ = h.shape
        pos = _positions_for(cfg, b, s, 0)
        h, new_cache = _decode_trunk(cfg, params, h, pos, "prefill", cache, None, memory)
        new_cache["memory"] = memory.astype(cache["memory"].dtype)
    else:
        h = _inputs_to_h(cfg, params, inputs, "prefill")
        b, s, _ = h.shape
        pos = _positions_for(cfg, b, s, 0)
        h, new_cache = _trunk(cfg, params, h, pos, "prefill", cache, None)
    h = rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    return unembed(params["embed"], h), new_cache


def decode_step(cfg: ModelConfig, params, token, cache, cache_index):
    """One token (b, 1) against the cache at position cache_index."""
    h = embed(params["embed"], token)
    b = token.shape[0]
    pos = _positions_for(cfg, b, 1, cache_index)
    if cfg.is_encdec:
        memory = cache["memory"]
        h, new_cache = _decode_trunk(
            cfg, params, h, pos, "decode", cache, cache_index, memory
        )
    else:
        h, new_cache = _trunk(cfg, params, h, pos, "decode", cache, cache_index)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params["embed"], h), new_cache


# ------------------------------------------------------------- init/count ----


def init_model_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(param_schema(cfg), key, dtype)


def abstract_model_params(cfg: ModelConfig, dtype=jnp.float32):
    return abstract_params(param_schema(cfg), dtype)


def model_logical_axes(cfg: ModelConfig):
    return logical_axes(param_schema(cfg))


def count_params(cfg: ModelConfig) -> int:
    from .param import param_count

    return param_count(param_schema(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: routed k of E experts) — for 6·N·D."""
    total = count_params(cfg)
    if not cfg.is_moe:
        return total
    e, k = cfg.num_experts, cfg.experts_per_tok
    per_expert = 3 * cfg.d_model * cfg.expert_ff
    n_moe_layers = (
        cfg.num_layers // cfg.moe_layer_period
        if cfg.family != "hybrid"
        else cfg.num_layers // cfg.moe_layer_period
    )
    inactive = (e - k) * per_expert * n_moe_layers
    return total - inactive
