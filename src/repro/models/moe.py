"""Mixture-of-Experts layer: top-k routing with capacity-grouped dispatch.

Dispatch is gather-based "sort-free grouping": every (token, k) copy computes its
slot inside its expert's capacity buffer via a masked cumulative sum; tokens beyond
capacity are dropped (weights renormalised). FLOPs are proportional to E·C·ff with
C = ceil(tokens·K/E · capacity_factor) — i.e. to the *routed* compute, never the
dense all-experts product — so roofline numbers are honest.

Routing groups are **per batch row** (leading dim g=b stays sharded over the mesh
data axes — no cross-device grouping traffic); decode steps (s=1) group over the
whole batch instead (tokens are tiny there, the gather is cheap). Expert weights
carry the "experts" logical axis → expert parallelism over the mesh "model" axis.

Shared experts (deepseek-v2) are plain always-on MLPs added to the routed output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .param import P
from .layers import mlp_params, mlp
from .sharding_ctx import shard


def moe_params(cfg):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.expert_ff
    out = {
        "router": P((d, e), ("embed", None)),
        "gate": P((e, d, ff), ("experts", "embed", "mlp")),
        "up": P((e, d, ff), ("experts", "embed", "mlp")),
        "down": P((e, ff, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        out["shared"] = mlp_params(cfg, d_ff=cfg.num_shared_experts * cfg.expert_ff)
    return out


def _capacity(cfg, tokens_per_group: int) -> int:
    c = math.ceil(
        tokens_per_group * cfg.experts_per_tok * cfg.capacity_factor / cfg.num_experts
    )
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _grouped_experts(p, cfg, xg: jax.Array) -> jax.Array:
    """xg: (g, t, d) token groups → routed output (g, t, d). Grouping stays within g."""
    g, t, d = xg.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    cap = _capacity(cfg, t)
    logits = (xg @ p["router"]).astype(jnp.float32)  # (g, t, e)
    gates, expert_idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)  # (g, t, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(g, t * k)  # expert id of each token-copy
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (g, tk, e)
    ranks = jnp.cumsum(onehot, axis=1) - onehot  # exclusive prefix count per expert
    slot = jnp.take_along_axis(ranks, flat_e[..., None], axis=2)[..., 0]  # (g, tk)
    keep = slot < cap
    buf_pos = flat_e * cap + jnp.where(keep, slot, cap - 1)  # (g, tk) in [0, e*cap)
    src_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t), k)[None], (g, t * k)
    )
    token_of_slot = (
        jnp.zeros((g, e * cap), jnp.int32)
        .at[jnp.arange(g)[:, None], buf_pos]
        .set(jnp.where(keep, src_token, 0), mode="drop")
    )
    filled = (
        jnp.zeros((g, e * cap), bool)
        .at[jnp.arange(g)[:, None], buf_pos]
        .set(keep, mode="drop")
    )

    xin = jnp.take_along_axis(xg, token_of_slot[..., None], axis=1)  # (g, e*cap, d)
    # flattened dispatch buffers are expert-major: sharding dim 1 over "model" IS
    # expert parallelism (1 GB-scale f32 cotangents at full d otherwise — dbrx)
    xin = shard(xin, "batch", "experts_act", None)
    xin = (xin * filled[..., None]).reshape(g, e, cap, d)
    xin = shard(xin, "batch", "experts_act", None, None)
    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["gate"])) * jnp.einsum(
        "gecd,edf->gecf", xin, p["up"]
    )
    hidden = shard(hidden, "batch", "experts_act", None, "mlp_act")
    out_buf = jnp.einsum("gecf,efd->gecd", hidden, p["down"]).reshape(g, e * cap, d)
    out_buf = shard(out_buf, "batch", "experts_act", None)

    copy_out = jnp.take_along_axis(out_buf, buf_pos[..., None], axis=1)  # (g, tk, d)
    # token-copy dim is token-major → sharding it over "model" matches the
    # sequence-parallel residual stream (the gather above is the all-to-all)
    copy_out = shard(copy_out, "batch", "seq_act", None)
    copy_out = copy_out * keep[..., None]
    weighted = copy_out * gates.reshape(g, t * k, 1).astype(copy_out.dtype)
    return jnp.sum(weighted.reshape(g, t, k, d), axis=2).astype(xg.dtype)


def moe_apply(p: dict, cfg, x: jax.Array) -> jax.Array:
    """x: (b, s, d) → (b, s, d). Deterministic top-k routing."""
    b, s, d = x.shape
    if s == 1:  # decode: group over the batch (tokens are few; gather is cheap)
        y = _grouped_experts(p, cfg, x.reshape(1, b, d)).reshape(b, s, d)
    else:  # train/prefill: per-batch-row groups — batch dim stays sharded
        y = _grouped_experts(p, cfg, x)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y
