"""Param schema system: declare parameters once as a pytree of ``P`` leaves carrying
shape + *logical axes*; derive (a) initialised arrays, (b) ShapeDtypeStructs for the
dry-run (no allocation), (c) PartitionSpecs via launch/sharding.py logical-axis rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter leaf: shape + logical axis names (len == ndim)."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "fan_in"  # fan_in | zeros | ones | embed

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x: Any) -> bool:
    return isinstance(x, P)


def init_params(schema: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Materialise a schema into arrays (smoke tests / real training)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        if leaf.init == "zeros":
            a = jnp.zeros(leaf.shape, dtype)
        elif leaf.init == "ones":
            a = jnp.ones(leaf.shape, dtype)
        elif leaf.init == "embed":
            a = 0.02 * jax.random.normal(k, leaf.shape, dtype)
        else:  # fan_in
            fan_in = leaf.shape[0] if len(leaf.shape) == 1 else math.prod(leaf.shape[:-1])
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            a = scale * jax.random.normal(k, leaf.shape, dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def abstract_params(schema: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct tree — the dry-run path (never allocates)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), schema, is_leaf=is_leaf
    )


def logical_axes(schema: Any) -> Any:
    """Tree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda p: p.axes, schema, is_leaf=is_leaf)


def param_count(schema: Any) -> int:
    return sum(
        math.prod(p.shape) for p in jax.tree.leaves(schema, is_leaf=is_leaf)
    )


def stack_schema(schema: Any, n: int, axis_name: str | None = "layers") -> Any:
    """Prepend a stacking dim (for lax.scan over layers)."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init),
        schema,
        is_leaf=is_leaf,
    )
