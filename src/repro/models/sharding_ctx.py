"""Activation-sharding context: model code calls ``shard(x, *logical_axes)``; the
launcher installs a mesh + logical→physical rules; outside a context it's a no-op
(smoke tests on 1 device).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()


def rules_to_spec(rules: dict, logical: tuple) -> PartitionSpec:
    """Each mesh axis may appear once; the earliest logical dim wins (e.g. MoE
    activations name both experts_act and mlp_act, which both map to "model")."""
    used: set[str] = set()
    out = []
    for ax in logical:
        phys = rules.get(ax)
        if phys is None:
            out.append(None)
            continue
        axes = (phys,) if isinstance(phys, str) else tuple(phys)
        kept = tuple(a for a in axes if a not in used)
        used.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*out)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current() -> Optional[tuple]:
    return getattr(_STATE, "ctx", None)


def shard(x: jax.Array, *logical: str) -> jax.Array:
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules_to_spec(rules, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
