"""Mamba2 block — SSD (state-space duality) chunked scan [arXiv:2405.21060].

Train/prefill uses the chunked SSD algorithm: within a chunk the recurrence is
expanded into an attention-like masked (q,k) matmul (MXU-shaped); across chunks a
single (b,h,n,p) state is carried by lax.scan — O(S·Q) work, O(S/Q) sequential depth.
Decode is the exact linear recurrence h ← exp(Δa)·h + Δ·x⊗B, one step.

Matches the sequential reference `ssm_scan_ref` (tests/test_models.py) to fp32
tolerance for any chunk size.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .param import P
from .layers import rmsnorm
from .sharding_ctx import shard


def mamba_params(cfg):
    d, din = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n  # x, B, C are convolved (G=1 groups)
    return {
        "in_proj": P((d, 2 * din + 2 * n + h), ("embed", "d_inner")),
        "conv_w": P((cfg.ssm_conv_width, conv_ch), (None, "d_inner")),
        "conv_b": P((conv_ch,), ("d_inner",), init="zeros"),
        "a_log": P((h,), (None,), init="ones"),
        "d_skip": P((h,), (None,), init="ones"),
        "dt_bias": P((h,), (None,), init="zeros"),
        "norm_scale": P((din,), ("d_inner",), init="ones"),
        "out_proj": P((din, d), ("d_inner", "embed")),
    }


def mamba_make_cache(cfg, batch: int, dtype=jnp.bfloat16):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    conv_ch = din + 2 * n
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, h, n, p), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (b, s, c); w: (width, c)."""
    width, c = w.shape
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # (width, 1, c) HIO
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=c,
    )
    return out + b


def ssd_chunked(
    x: jax.Array,  # (b, s, h, p)
    dt: jax.Array,  # (b, s, h) — already softplus'd
    a_log: jax.Array,  # (h,)
    bmat: jax.Array,  # (b, s, n)
    cmat: jax.Array,  # (b, s, n)
    d_skip: jax.Array,  # (h,)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (b, h, n, p) initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (b,s,h,p), final_state (b,h,n,p))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))  # (h,)

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    hinit = (
        jnp.zeros((b, h, n, p), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    mask = jnp.tril(jnp.ones((q, q), bool))

    def chunk_body(hprev, inp):
        """All work for ONE chunk: the (b,q,q,h) intra-chunk tensors live only
        inside this (rematted) body — materialising them for all chunks at once
        costs s/q × more memory (measured 146 GB/device on jamba train_4k)."""
        xc_c, dtc_c, bc_c, cc_c = inp  # (b,q,h,p),(b,q,h),(b,q,n),(b,q,n)
        da = dtc_c * a  # (b,q,h)
        cum = jnp.cumsum(da, axis=1)  # inclusive over the chunk
        cum_last = cum[:, -1:]  # (b,1,h)
        # intra-chunk attention-like masked matmul
        cb = jnp.einsum("bqn,bkn->bqk", cc_c, bc_c)  # (b,q,q)
        decay = jnp.exp(cum[:, :, None] - cum[:, None])  # (b,q,k,h)
        decay = shard(decay, "batch", None, None, "heads_act")
        att = cb[..., None] * jnp.where(mask[None, ..., None], decay, 0.0)
        att = att * dtc_c[:, None]  # dt_k broadcast over q-index
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", att.astype(x.dtype), xc_c)
        # contribution of the carried state
        y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp", cc_c, hprev, jnp.exp(cum))
        # outgoing state
        w_k = jnp.exp(cum_last - cum) * dtc_c  # (b,q,h)
        st = jnp.einsum("bqn,bqh,bqhp->bhnp", bc_c, w_k.astype(x.dtype), xc_c)
        hnext = jnp.exp(cum_last[:, 0])[..., None, None] * hprev + st.astype(jnp.float32)
        return hnext, y_intra.astype(jnp.float32) + y_inter

    body = jax.checkpoint(chunk_body, prevent_cse=False)
    xs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(dtc, 1, 0),
        jnp.moveaxis(bc, 1, 0),
        jnp.moveaxis(cc, 1, 0),
    )
    h_final, yc = jax.lax.scan(body, hinit, xs)  # yc: (nc,b,q,h,p)
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final


def ssm_scan_ref(x, dt, a_log, bmat, cmat, d_skip, h0=None):
    """Sequential oracle for SSD (used by tests and derivable decode semantics)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    hinit = jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0

    def step(hprev, inp):
        x_t, dt_t, b_t, c_t = inp  # (b,h,p),(b,h),(b,n),(b,n)
        decay = jnp.exp(dt_t * a)  # (b,h)
        upd = jnp.einsum("bn,bh,bhp->bhnp", b_t, dt_t, x_t.astype(jnp.float32))
        hnext = decay[..., None, None] * hprev + upd
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, hnext)
        return hnext, y_t

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(bmat, 1, 0),
        jnp.moveaxis(cmat, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, hinit, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final


def mamba_apply(
    p: dict,
    cfg,
    hidden: jax.Array,  # (b, s, d)
    mode: str,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
):
    b, s, d = hidden.shape
    din, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = hidden @ p["in_proj"]  # (b, s, 2*din + 2n + h)
    # the widest activations in the model (jamba: (16,4096,33152) bf16 = 4.3 GB per
    # tensor per device) — shard the channel dim over "model", matching in_proj's
    # d_inner weight sharding so the matmul output needs no reshard
    proj = shard(proj, "batch", None, "heads_act")
    z, xbc_dt = jnp.split(proj, [din], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [din + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if mode in ("train", "prefill"):
        xbc_conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
        x_in, bmat, cmat = jnp.split(xbc_conv, [din, din + n], axis=-1)
        xh = x_in.reshape(b, s, nh, hd)
        xh = shard(xh, "batch", "seq", "heads_act", None)
        y, h_final = ssd_chunked(
            xh, dt, p["a_log"], bmat, cmat, p["d_skip"], cfg.ssm_chunk
        )
        new_cache = cache
        if mode == "prefill" and cache is not None:
            conv_tail = xbc[:, -(cfg.ssm_conv_width - 1):, :]
            new_cache = {
                "conv": conv_tail.astype(cache["conv"].dtype),
                "ssm": h_final,
            }
    elif mode == "decode":
        # conv: append current input to stored window
        window = jnp.concatenate(
            [cache["conv"].astype(xbc.dtype), xbc], axis=1
        )  # (b, width, c)
        conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
        xbc_conv = jax.nn.silu(conv_out)[:, None, :]  # (b, 1, c)
        x_in, bmat, cmat = jnp.split(xbc_conv, [din, din + n], axis=-1)
        xh = x_in.reshape(b, 1, nh, hd)
        y, h_final = ssm_scan_ref(
            xh, dt, p["a_log"], bmat, cmat, p["d_skip"], h0=cache["ssm"]
        )
        new_cache = {
            "conv": window[:, 1:].astype(cache["conv"].dtype),
            "ssm": h_final,
        }
    else:
        raise ValueError(mode)

    y = y.reshape(b, s, din)
    y = shard(y, "batch", None, "heads_act")
    gated = y * jax.nn.silu(z)
    gated = rmsnorm({"scale": p["norm_scale"]}, gated, eps=cfg.norm_eps)
    return gated @ p["out_proj"], new_cache
