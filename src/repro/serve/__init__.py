"""GP posterior serving engine — continuous batching of predict/sample/Thompson
queries over shared multi-RHS solves (see docs/serving.md)."""
from .engine import EngineOverloaded, GPEngine  # noqa: F401
from .metrics import EngineStats, percentile  # noqa: F401
from .request import (  # noqa: F401
    Completion,
    KINDS,
    PREDICT,
    Request,
    RequestHandle,
    SAMPLE,
    SOLVE_KINDS,
    THOMPSON,
)
from .scheduler import BatchPlan, FIFOScheduler, bucket  # noqa: F401
from .state import (  # noqa: F401
    PosteriorState,
    WarmStartCache,
    extend_state,
    fit_state,
    hypers_fingerprint,
    update_state_lowrank,
)
