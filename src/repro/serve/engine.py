"""`GPEngine` — the long-lived GP posterior serving loop.

The GP analogue of a vLLM-class engine: clients ``submit`` posterior queries
(``predict`` / ``sample`` / ``thompson_step``) and a driver calls ``step()``
in a loop; each step the scheduler coalesces compatible queued requests into
one batch, the batch executes as ONE shared computation, and completions are
scattered back to the callers' handles:

    submit → schedule → batch → execute → complete        (engine.step())

The paper makes this batching natural: every expensive posterior computation
is a multi-RHS solve against the *same* (K + σ²I) operator, so queued
``sample``/``thompson_step`` requests stack their RHS columns into one
``solve(op, B, spec)`` (§2.2.4 — the per-iteration cost is one fused multi-RHS
matvec regardless of how many requests ride it), and queued ``predict``
requests stack their query blocks into one fused cross-covariance pass over
cached representer weights. Batch shapes are bucketed to powers of two so
steady-state serving reuses a small fixed set of compiled solves.

Warm starts (Ch. 5 §5.3): solutions are cached keyed by (hyperparameter
fingerprint, request kind, request seed); repeat queries re-enter the solver
with their previous solution as ``x0`` and converge in a couple of iterations
— the scheduler never mixes warm and cold requests in one batch, so the win is
visible in per-request latency, not just matvec counts. New observations go
through ``add_observations``: by default a rank-k bordered-system correction
of the existing solution (k solve columns at the OLD n — pathwise conditioning
makes appending rows a low-rank update of the sampled paths), certified
against the extended operator and compacted to a full warm row-extension refit
when accumulated drift exceeds the tolerance budget (see serve/state.py and
docs/serving.md).

Synchronous and host-driven by design (``step()`` is the vLLM idiom —
async frontends wrap it in a task loop; ``submit`` never blocks). All device
work stays inside the core library's ``solve()``/fused-matvec entry points.
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.kernels_fn import KernelParams
from ..core.pathwise import PosteriorFunctions
from ..core.rff import PriorSamples
from ..core.solvers.base import FROZEN_FLAGS, flag_names
from ..core.solvers.robust import EscalationPolicy, _pin_backend, solve_robust
from ..core.solvers.spec import SpecLike, as_spec, solve
from ..core.thompson import _maximise_samples
from .metrics import EngineStats
from .request import (
    Completion,
    KINDS,
    PREDICT,
    Request,
    RequestHandle,
    SAMPLE,
    SOLVE_KINDS,
    THOMPSON,
)
from .scheduler import (
    BatchPlan,
    FIFOScheduler,
    GROUP_PREDICT,
    GROUP_SOLVE_WARM,
    bucket,
)
from .state import (
    PosteriorState,
    WarmStartCache,
    extend_state,
    fit_state,
    update_state_lowrank,
)


class EngineOverloaded(RuntimeError):
    """Backpressure signal: the queue is past ``max_queue_depth`` and the
    overload policy rejected this submit. Callers back off and retry."""


class GPEngine:
    """Continuous-batching server over one fitted GP posterior.

    Args:
        params, x, y: the fitted hyperparameters and training data (usually via
            ``IterativeGP.engine()``).
        spec: the SolverSpec every serve-time solve runs with. The engine's
            per-request determinism guarantee (same seed ⇒ same payload,
            regardless of batch composition) holds for deterministic solvers
            (CG, the default); stochastic specs draw their mini-batch indices
            from a per-solve key, so results then depend on batching.
        num_samples / num_features: the cached posterior's pathwise sample
            count and prior feature count (predict variance quality).
        max_batch_requests / max_rhs_columns: scheduler caps.
        row_bucket_min / col_bucket_min: smallest padded block shapes.
        clock: timeline source for arrival/latency stamps (injectable so the
            benchmark can drive a simulated arrival process); compute durations
            are always measured with ``time.perf_counter``.

    Fault tolerance (docs/robustness.md):
        max_skips: scheduler starvation guard — a request skipped this many
            times is promoted to head the next batch.
        default_deadline_s: relative deadline stamped on every submit that
            does not pass its own ``deadline_s``; ``None`` = no deadline.
        max_queue_depth / overload_policy: overload shedding — past the depth
            threshold, ``"degrade"`` serves ``sample`` requests as mean-only
            ``predict`` (and rejects the rest), ``"reject"`` refuses
            everything with :class:`EngineOverloaded` backpressure.
        max_exec_retries / retry_backoff_s: host-level retry of a batch whose
            execution *raised* (transient dispatch/runtime errors); past the
            budget the batch's requests complete with ``exec_error``.
        quarantine_after: a (kind, seed) identity whose solo rescue fails this
            many times is quarantined — later submits complete immediately
            with a ``quarantined`` error instead of poisoning more batches.
        escalation: the :class:`EscalationPolicy` for solo rescues of flagged
            columns (``None`` disables rescue — flagged requests fail fast).
        operator_transform: optional hook wrapping the solve operator each
            batch (fault injection in tests/benchmarks; must preserve the
            LinearOperator protocol).

    Incremental updates (docs/serving.md):
        update_policy: the default ``add_observations`` path — ``"lowrank"``
            (rank-k bordered correction), ``"full"`` (row-extension refit), or
            ``"auto"`` (lowrank with residual-drift compaction; the default).
        compaction_tol_factor: the auto policy's drift budget — fall back to a
            full warm refit when a low-rank update's certified residual against
            the extended operator exceeds this factor × the spec tolerance.
    """

    def __init__(
        self,
        params: KernelParams,
        x: jax.Array,
        y: jax.Array,
        *,
        spec: SpecLike = "cg",
        num_samples: int = 16,
        num_features: int = 1024,
        key: Optional[jax.Array] = None,
        seed: int = 0,
        max_batch_requests: int = 16,
        max_rhs_columns: int = 64,
        row_bucket_min: int = 16,
        col_bucket_min: int = 8,
        warm_cache_entries: int = 256,
        default_sample_count: int = 8,
        clock: Callable[[], float] = time.monotonic,
        max_skips: int = 16,
        default_deadline_s: Optional[float] = None,
        max_queue_depth: Optional[int] = None,
        overload_policy: str = "degrade",
        max_exec_retries: int = 1,
        retry_backoff_s: float = 0.02,
        quarantine_after: int = 2,
        escalation: Optional[EscalationPolicy] = EscalationPolicy(),
        operator_transform: Optional[Callable] = None,
        update_policy: str = "auto",
        compaction_tol_factor: float = 4.0,
    ):
        if overload_policy not in ("degrade", "reject"):
            raise ValueError(
                f"overload_policy must be 'degrade' or 'reject', got "
                f"{overload_policy!r}"
            )
        if update_policy not in ("lowrank", "full", "auto"):
            raise ValueError(
                f"update_policy must be 'lowrank', 'full' or 'auto', got "
                f"{update_policy!r}"
            )
        self.update_policy = update_policy
        self.compaction_tol_factor = float(compaction_tol_factor)
        self.spec = as_spec(spec)
        self._clock = clock
        self.row_bucket_min = int(row_bucket_min)
        self.col_bucket_min = int(col_bucket_min)
        self.default_sample_count = int(default_sample_count)
        self.default_deadline_s = default_deadline_s
        self.max_queue_depth = max_queue_depth
        self.overload_policy = overload_policy
        self.max_exec_retries = int(max_exec_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.quarantine_after = int(quarantine_after)
        self.escalation = escalation
        self._op_transform = operator_transform
        key = jax.random.PRNGKey(seed) if key is None else key
        kf, self._solver_key = jax.random.split(key)
        self.state: PosteriorState = fit_state(
            params, x, y, kf,
            spec=self.spec, num_samples=num_samples, num_features=num_features,
        )
        self.scheduler = FIFOScheduler(
            max_batch_requests=max_batch_requests,
            max_rhs_columns=max_rhs_columns,
            max_skips=max_skips,
        )
        self.cache = WarmStartCache(max_entries=warm_cache_entries)
        self._stats = EngineStats()
        self._ids = itertools.count()
        self._auto_seeds = itertools.count()
        self._handles: dict = {}
        # poison-request bookkeeping: strike counts and the quarantine set,
        # keyed by the (kind, seed) identity that regenerates the RHS columns
        self._strikes: dict = {}
        self._quarantine: set = set()
        # warm-start savings are reported against the most recent cold solve
        self._last_cold_iters: Optional[int] = None
        # refit-savings baseline: the most recent COLD solve of the fit system
        # (EngineStats docstring has the exact semantics); re-baselined by any
        # warm=False full refit
        self._stats.refit_baseline_n = self.state.n
        self._stats.refit_baseline_iters = int(self.state.fit_result.iterations)

    # ------------------------------------------------------------------ submit

    def submit(
        self,
        kind: str,
        xs=None,
        *,
        num_samples: Optional[int] = None,
        seed: Optional[int] = None,
        deadline_s: Optional[float] = None,
        **options,
    ) -> RequestHandle:
        """Queue a request; never blocks on execution. Returns a handle
        completed by step().

        ``seed`` pins the request's randomness (repeat seeds are what the
        warm-start cache keys on); omitted, a fresh engine-unique seed is
        assigned. ``deadline_s`` is relative to now (falls back to the
        engine's ``default_deadline_s``); a request still queued past its
        deadline completes with a structured ``deadline_exceeded`` error.
        ``options`` are kind-specific (thompson_step: ascent parameters
        ``num_candidates``/``num_top``/``ascent_steps``/``lr``).

        Overload shedding: past ``max_queue_depth``, policy ``"degrade"``
        downgrades ``sample`` to mean-only ``predict`` (same query block) and
        rejects everything else; policy ``"reject"`` refuses all submits —
        rejection raises :class:`EngineOverloaded` as backpressure. A
        quarantined (kind, seed) identity completes immediately with a
        ``quarantined`` error.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown request kind {kind!r}; expected one of {KINDS}")
        if (
            self.max_queue_depth is not None
            and len(self.scheduler) >= self.max_queue_depth
        ):
            if (
                self.overload_policy == "degrade"
                and kind == SAMPLE
                and xs is not None
            ):
                kind = PREDICT
                options["degraded"] = True
                self._stats.degraded += 1
            else:
                self._stats.shed += 1
                raise EngineOverloaded(
                    f"queue depth {len(self.scheduler)} >= max_queue_depth "
                    f"{self.max_queue_depth}; request shed "
                    f"(policy={self.overload_policy!r}) — back off and retry"
                )
        if kind in (PREDICT, SAMPLE):
            if xs is None:
                raise ValueError(f"{kind!r} requests need a query block xs of shape (m, d)")
            xs = jnp.atleast_2d(jnp.asarray(xs))
            if xs.shape[1] != self.state.x.shape[1]:
                raise ValueError(
                    f"query block has feature dimension {xs.shape[1]}, "
                    f"engine state has d={self.state.x.shape[1]}"
                )
        elif xs is not None:
            raise ValueError(
                "thompson_step requests draw their own candidates — xs must be None"
            )
        if num_samples is None:
            num_samples = (
                self.state.post.num_samples if kind == PREDICT
                else self.default_sample_count
            )
        if seed is None:
            seed = (1 << 20) + next(self._auto_seeds)
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        req = Request(
            id=next(self._ids),
            kind=kind,
            xs=xs,
            num_samples=int(num_samples),
            seed=int(seed),
            arrival=self._clock(),
            options=dict(options),
            warm=(
                kind in SOLVE_KINDS
                and self.cache.probe(self.state.hypers_key, kind, int(seed))
            ),
            deadline=None if deadline_s is None else self._clock() + deadline_s,
        )
        handle = RequestHandle(req)
        self._handles[req.id] = handle
        self._stats.requests_submitted += 1
        if kind in SOLVE_KINDS and (kind, int(seed)) in self._quarantine:
            # repeat offender: fail fast instead of poisoning another batch
            self._stats.quarantined += 1
            self._fail(
                req,
                code="quarantined",
                message=(
                    f"(kind={kind!r}, seed={seed}) exceeded "
                    f"{self.quarantine_after} failed rescue attempts and is "
                    f"quarantined; resubmit with a fresh seed"
                ),
            )
            return handle
        self.scheduler.add(req)
        return handle

    # convenience wrappers
    def predict(self, xs, **kw) -> RequestHandle:
        return self.submit(PREDICT, xs, **kw)

    def sample(self, xs, **kw) -> RequestHandle:
        return self.submit(SAMPLE, xs, **kw)

    def thompson_step(self, **kw) -> RequestHandle:
        return self.submit(THOMPSON, None, **kw)

    # -------------------------------------------------------------------- step

    def _fail(self, req, *, code: str, message: str, **detail) -> Completion:
        """Complete ``req`` with a structured error (never an exception)."""
        comp = Completion(
            request_id=req.id,
            kind=req.kind,
            value={},
            metrics=dict(queue_s=self._clock() - req.arrival),
            error=dict(code=code, message=message, **detail),
        )
        self._handles.pop(req.id)._complete(comp)
        self._stats.failed += 1
        return comp

    def step(self) -> List[Completion]:
        """Run one engine iteration: expire → schedule → batch → execute →
        complete.

        Returns the completions produced this step (possibly empty), both
        successes and structured failures (``Completion.ok``). Latency
        accounting: ``queue_s`` is arrival → batch start on the engine clock;
        ``exec_s`` is the batch's measured compute wall (shared by every
        request in the batch, as is the solve's iteration/matvec spend).
        """
        now = self._clock()
        completions: List[Completion] = []
        for req in self.scheduler.expire(now):
            self._stats.deadline_misses += 1
            completions.append(
                self._fail(
                    req,
                    code="deadline_exceeded",
                    message=(
                        f"request {req.id} ({req.kind}) expired in queue: "
                        f"deadline {req.deadline:.3f} < now {now:.3f}"
                    ),
                    deadline=req.deadline,
                    now=now,
                )
            )
        plan = self.scheduler.next_batch()
        if plan is None:
            return completions
        t_start = self._clock()
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                if plan.group == GROUP_PREDICT:
                    values, extra = self._execute_predict(plan)
                    errors: dict = {}
                else:
                    values, extra, errors = self._execute_solve(plan)
                jax.block_until_ready([list(v.values()) for v in values])
                break
            except Exception as exc:  # noqa: BLE001 — isolation boundary:
                # a raising batch must fail structurally, not kill the loop
                attempt += 1
                if attempt > self.max_exec_retries:
                    for req in plan.requests:
                        completions.append(
                            self._fail(
                                req,
                                code="exec_error",
                                message=f"batch execution failed after "
                                f"{attempt} attempts: {exc!r}",
                            )
                        )
                    return completions
                self._stats.retries += 1
                time.sleep(self.retry_backoff_s * attempt)
        exec_s = time.perf_counter() - t0

        self._stats.steps += 1
        self._stats.bump_batch(plan.group)
        for req, value in zip(plan.requests, values):
            queue_s = t_start - req.arrival
            error = errors.get(req.id)
            if error is not None:
                comp = self._fail(req, **error)
                if error.get("code") == "solver_failure":
                    self._strike(req)
                completions.append(comp)
                continue
            metrics = dict(
                queue_s=queue_s,
                exec_s=exec_s,
                total_s=queue_s + exec_s,
                batch_requests=len(plan.requests),
                group=plan.group,
                **extra,
            )
            if req.kind in SOLVE_KINDS:
                metrics["warm"] = req.warm
            if req.options.get("degraded"):
                metrics["degraded"] = True
            comp = Completion(
                request_id=req.id, kind=req.kind, value=value, metrics=metrics
            )
            self._handles.pop(req.id)._complete(comp)
            self._stats.bump_kind(req.kind)
            self._stats.queue_latencies.append(queue_s)
            self._stats.total_latencies.append(queue_s + exec_s)
            completions.append(comp)
        return completions

    def _strike(self, req) -> None:
        """Record a failed rescue; quarantine the (kind, seed) identity past
        the strike budget."""
        ident = (req.kind, req.seed)
        self._strikes[ident] = self._strikes.get(ident, 0) + 1
        if self._strikes[ident] >= self.quarantine_after:
            self._quarantine.add(ident)

    def run_until_idle(self, max_steps: int = 100_000) -> List[Completion]:
        """Drive step() until the queue drains; returns all completions."""
        out: List[Completion] = []
        for _ in range(max_steps):
            if len(self.scheduler) == 0:
                break
            out.extend(self.step())
        return out

    # --------------------------------------------------------------- execution

    def _execute_predict(self, plan: BatchPlan):
        """One fused row-batched mean/variance pass over cached state."""
        d = self.state.x.shape[1]
        rows = bucket(plan.max_rows, self.row_bucket_min)
        nblk = bucket(len(plan.requests), 1)
        blocks = np.zeros((nblk, rows, d), dtype=np.asarray(self.state.x).dtype)
        for i, req in enumerate(plan.requests):
            blocks[i, : req.num_rows] = np.asarray(req.xs)
        mean, var = self.state.post.blocked_mean_and_var(jnp.asarray(blocks))
        values = [
            {"mean": mean[i, : r.num_rows], "var": var[i, : r.num_rows]}
            for i, r in enumerate(plan.requests)
        ]
        real_rows = sum(r.num_rows for r in plan.requests)
        self._stats.predict_rows += real_rows
        self._stats.predict_padded_rows += nblk * rows - real_rows
        return values, dict(bucket_rows=rows, bucket_blocks=nblk)

    def _request_draws(self, req: Request):
        """Deterministic per-request randomness: fresh prior weight draws and
        noise draws from the request seed alone, so the payload is independent
        of batch composition (CG) and repeat seeds regenerate identical
        columns — the warm-start cache's correctness condition."""
        state = self.state
        f = state.prior.num_features
        kw, ke, ka = jax.random.split(jax.random.PRNGKey(req.seed), 3)
        w_new = jax.random.normal(kw, (f, req.num_samples))
        eps = jnp.sqrt(state.params.noise) * jax.random.normal(
            ke, (state.n, req.num_samples), dtype=w_new.dtype
        )
        return w_new, eps, ka

    def _execute_solve(self, plan: BatchPlan):
        """ONE shared multi-RHS solve for every sample/thompson request in the
        batch, then per-request scatter + evaluation.

        Every device pass in this path is batch-level, never per-request: the
        requests' prior weight columns are stacked so one fused feature matvec
        produces every RHS, one ``solve`` produces every representer block, and
        one pathwise evaluation produces every sample request's payload —
        per-request work is pure slicing. That is where the engine's throughput
        comes from: at depth D the O(n²d) kernel evaluation inside each solver
        iteration (and the dispatch overhead of each fused pass) is paid once,
        not D times.

        Fault isolation (docs/robustness.md): after the shared solve, columns
        whose diagnostic flags carry ``FROZEN_FLAGS`` identify the requests
        that poisoned them; each such request is re-run *solo* through
        :func:`solve_robust`'s escalation ladder against the same operator.
        Rescued requests complete normally (their payload comes from the
        rescued solution); unrescuable ones get a structured
        ``solver_failure`` error. Requests whose columns stayed clean are
        untouched — their payloads are bit-identical to a fault-free batch.
        """
        state = self.state
        op = state.operator()
        if self._op_transform is not None:
            # wrappers can't survive solve()'s dataclasses.replace backend
            # pinning, so pin the inner operator first, then wrap
            op = self._op_transform(_pin_backend(op, self.spec))
        n = state.n
        per_req = [self._request_draws(r) for r in plan.requests]
        widths = [r.num_samples for r in plan.requests]
        offsets = np.concatenate([[0], np.cumsum(widths)])
        total = int(offsets[-1])
        cbucket = bucket(total, self.col_bucket_min)

        w_cat = jnp.concatenate([w for w, _, _ in per_req], axis=1)
        delta = jnp.concatenate(
            [eps / state.params.noise for _, eps, _ in per_req], axis=1
        )
        pad = cbucket - total
        if pad:
            w_cat = jnp.pad(w_cat, ((0, 0), (0, pad)))
            delta = jnp.pad(delta, ((0, 0), (0, pad)))
        # one fused feature matvec builds every request's RHS columns (padded
        # zero-weight columns give zero columns, which converge instantly)
        data = state.prior.phi_mv(state.x, w_cat)

        x0 = None
        if plan.group == GROUP_SOLVE_WARM:
            cols = np.zeros((n, cbucket), dtype=data.dtype)
            for req, lo, hi in zip(plan.requests, offsets[:-1], offsets[1:]):
                hit = self.cache.lookup(state.hypers_key, req.kind, req.seed)
                if hit is not None and hit.shape == (n, req.num_samples):
                    cols[:, lo:hi] = hit
                    self._stats.warm_hits += 1
                else:  # probe said warm but the entry aged out — cold column
                    self._stats.warm_misses += 1
            x0 = jnp.asarray(cols, dtype=data.dtype)
        skey = jax.random.fold_in(self._solver_key, self._stats.solves)
        res = solve(op, data, self.spec, key=skey, x0=x0, delta=delta)
        iters = int(res.iterations)
        matvecs = int(res.matvecs)
        self._stats.solves += 1
        self._stats.rhs_columns += total
        self._stats.padded_columns += pad
        self._stats.solver_iterations += iters
        self._stats.solver_matvecs += matvecs
        if plan.group == GROUP_SOLVE_WARM:
            if self._last_cold_iters is not None:
                self._stats.iterations_saved_warm += max(
                    0, self._last_cold_iters - iters
                )
        else:
            self._last_cold_iters = iters

        # ---- fault isolation: map flagged columns back to their requests,
        # rescue each affected request solo, fail the unrescuable ones
        flags = np.atleast_1d(np.asarray(jax.device_get(res.flags)))
        if flags.size == 1 and cbucket > 1:
            flags = np.full((cbucket,), int(flags[0]))
        bad = (flags[:total].astype(np.int64) & FROZEN_FLAGS) != 0
        errors: dict = {}
        rescued: dict = {}
        if bad.any():
            for req, (w_req, eps_req, _), lo, hi in zip(
                plan.requests, per_req, offsets[:-1], offsets[1:]
            ):
                if not bad[lo:hi].any():
                    continue
                req_flags = [int(f) for f in flags[lo:hi]]
                names = flag_names(int(np.bitwise_or.reduce(flags[lo:hi])))
                if self.escalation is None:
                    errors[req.id] = dict(
                        code="solver_failure",
                        message=(
                            f"request {req.id} ({req.kind}) columns flagged "
                            f"({', '.join(names)}) and rescue is disabled"
                        ),
                        flags=req_flags,
                    )
                    continue
                self._stats.escalations += 1
                data_req = state.prior.phi_mv(state.x, w_req)
                rkey = jax.random.fold_in(
                    self._solver_key, 20_000_000 + req.id
                )
                report = solve_robust(
                    op,
                    data_req,
                    self.spec,
                    key=rkey,
                    delta=eps_req / state.params.noise,
                    policy=self.escalation,
                )
                if report.failed_columns:
                    errors[req.id] = dict(
                        code="solver_failure",
                        message=(
                            f"request {req.id} ({req.kind}) columns flagged "
                            f"({', '.join(names)}); escalation ladder "
                            f"{report.ladder or ['(empty)']} could not recover "
                            f"columns {report.failed_columns}"
                        ),
                        flags=req_flags,
                        rungs=list(report.ladder),
                    )
                else:
                    rescued[req.id] = report.result.solution

        for req, lo, hi in zip(plan.requests, offsets[:-1], offsets[1:]):
            if req.id in errors:
                continue  # never cache a poisoned solution
            sol = rescued.get(req.id)
            if sol is None:
                sol = res.solution[:, lo:hi]
            self.cache.store(state.hypers_key, req.kind, req.seed, sol)

        values_by_id = {}
        # one batched pathwise evaluation serves every sample request: their
        # query blocks stack row-wise, the batch's weight/representer columns
        # ride whole (padded zero columns are exact mean paths), and each
        # request's payload is the (rows, columns) sub-block at its offsets
        sample_at = [
            (req, int(lo)) for req, lo in zip(plan.requests, offsets[:-1])
            if req.kind == SAMPLE
            and req.id not in errors and req.id not in rescued
        ]
        if sample_at:
            row_offsets, r_total = [], 0
            for req, _ in sample_at:
                row_offsets.append(r_total)
                r_total += req.num_rows
            rbucket = bucket(r_total, self.row_bucket_min)
            xs_all = jnp.concatenate([req.xs for req, _ in sample_at], axis=0)
            xs_pad = jnp.pad(xs_all, ((0, rbucket - r_total), (0, 0)))
            vals = state.post.sample_paths(xs_pad, w_cat, res.solution)
            for (req, lo), ro in zip(sample_at, row_offsets):
                values_by_id[req.id] = {
                    "samples": vals[ro : ro + req.num_rows,
                                    lo : lo + req.num_samples]
                }

        # rescued sample requests get a solo pathwise pass over the rescued
        # representer block (cheap: the solve already happened in the ladder)
        for req, (w_req, _, _) in zip(plan.requests, per_req):
            if req.kind == SAMPLE and req.id in rescued:
                values_by_id[req.id] = {
                    "samples": state.post.sample_paths(
                        req.xs, w_req, rescued[req.id]
                    )
                }

        for req, (_, _, ka), lo, hi in zip(
            plan.requests, per_req, offsets[:-1], offsets[1:]
        ):
            if req.kind != THOMPSON or req.id in errors:
                continue
            # THOMPSON: ascend each fresh sample path (§3.3.2); the ascent loop
            # is per-request (its sample count fixes the compiled shape), at a
            # bucketed column count so repeat shapes reuse the compiled step
            sbucket = bucket(req.num_samples, self.col_bucket_min)
            spad = sbucket - req.num_samples
            alpha_req = rescued.get(req.id, res.solution[:, lo:hi])
            w_pad = jnp.pad(w_cat[:, lo:hi], ((0, 0), (0, spad)))
            a_pad = jnp.pad(alpha_req, ((0, 0), (0, spad)))
            post_r = PosteriorFunctions(
                params=state.params,
                x=state.x,
                prior=PriorSamples(
                    ff=state.prior.ff, w=w_pad, backend=state.prior.backend
                ),
                v_mean=state.post.v_mean,
                alpha=a_pad,
                backend=state.post.backend,
            )
            opts = req.options
            pts = _maximise_samples(
                post_r,
                state.y,
                ka,
                num_candidates=int(opts.get("num_candidates", 256)),
                num_top=int(opts.get("num_top", 2)),
                ascent_steps=int(opts.get("ascent_steps", 10)),
                lr=float(opts.get("lr", 1e-2)),
                lengthscale=float(jnp.mean(state.params.lengthscale)),
            )
            per_sample = jnp.einsum("ss->s", post_r(pts))
            values_by_id[req.id] = {
                "points": pts[: req.num_samples],
                "values": per_sample[: req.num_samples],
            }
        values = [values_by_id.get(req.id, {}) for req in plan.requests]
        extra = dict(
            batch_columns=total,
            bucket_columns=cbucket,
            iterations=iters,
            matvecs=matvecs,
        )
        return values, extra, errors

    # ------------------------------------------------------------------- state

    def add_observations(
        self, x_new, y_new, *, warm: bool = True, update: Optional[str] = None
    ) -> None:
        """Append observations and update the posterior state incrementally.

        Drains the queue first so every pending request is served against the
        state it was submitted under. ``update`` picks the path (defaults to
        the engine's ``update_policy``):

        * ``"lowrank"`` — rank-k bordered correction
          (:func:`~repro.serve.state.update_state_lowrank`): k correction
          columns solved against the OLD n-operator plus a k×k Schur
          factorization; cost scales with k, not n+k, and is independent of
          the posterior sample count. Applied unconditionally (the certified
          residual is still recorded — check ``last_refit_rel_residual``).
        * ``"full"`` — row-extension refit
          (:func:`~repro.serve.state.extend_state`), warm-started when
          ``warm`` (the pre-update solution zero-padded to the new n).
        * ``"auto"`` — lowrank first, compacted to a full warm refit when the
          corrected solution's TRUE residual against the extended operator
          exceeds ``compaction_tol_factor × spec.tol`` (or the correction
          solve raised a freezing flag). Successive low-rank updates
          accumulate solve drift; the certification matvec makes that drift
          observable, so the solver — not the cache — certifies freshness.

        Every path re-keys ``hypers_key`` (it covers n), purges the now
        unreachable warm-cache entries (counted in ``cache_purged``) and
        resets the warm-batch cold-iteration reference.
        """
        update = self.update_policy if update is None else update
        if update not in ("lowrank", "full", "auto"):
            raise ValueError(
                f"update must be 'lowrank', 'full' or 'auto', got {update!r}"
            )
        self.run_until_idle()
        skey = jax.random.fold_in(self._solver_key, 10_000_000 + self._stats.refits)
        if update == "full":
            self._refit_full(x_new, y_new, skey, warm=warm)
        else:
            cand = update_state_lowrank(self.state, x_new, y_new, skey)
            drift = float(jnp.max(cand.fit_result.rel_residual))
            tol = float(getattr(self.spec, "tol", 1e-2))
            accept = update == "lowrank" or (
                bool(cand.fit_result.healthy)
                and drift <= self.compaction_tol_factor * tol
            )
            if accept:
                k = int(cand.n) - int(self.state.n)
                self.state = cand
                self._stats.lowrank_updates += 1
                self._stats.lowrank_rows += k
                self._stats.lowrank_iterations += int(cand.fit_result.iterations)
                self._stats.lowrank_matvecs += int(cand.fit_result.matvecs)
                self._stats.last_refit_rel_residual = drift
            else:
                # compaction: the correction drifted past the certifiable
                # budget (or its solve flagged) — re-solve the extended system
                # in full, warm-started from the PRE-update state
                self._stats.compactions += 1
                self._refit_full(x_new, y_new, skey, warm=True)
        self._stats.refits += 1
        # a new operator shape: cold-iteration reference resets with it, and
        # warm-cache entries under the superseded hypers_key are unreachable
        self._last_cold_iters = None
        self._stats.cache_purged += self.cache.purge(self.state.hypers_key)

    def _refit_full(self, x_new, y_new, skey, *, warm: bool) -> None:
        """Full row-extension refit + its iteration/savings accounting."""
        self.state = extend_state(self.state, x_new, y_new, skey, warm=warm)
        iters = int(self.state.fit_result.iterations)
        self._stats.refit_iterations += iters
        self._stats.last_refit_rel_residual = float(
            jnp.max(self.state.fit_result.rel_residual)
        )
        if warm:
            self._stats.refit_iterations_saved += max(
                0, self._stats.refit_baseline_iters - iters
            )
        else:
            # a cold solve of the fit system at the CURRENT n: re-baseline,
            # so later warm refits are credited against a fresh reference
            self._stats.refit_baseline_n = self.state.n
            self._stats.refit_baseline_iters = iters

    # ------------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Cumulative counter snapshot + live queue/state info (one dict, used
        by the benchmark, the CLI and the tests alike)."""
        snap = self._stats.snapshot()
        snap.update(
            queue_depth=len(self.scheduler),
            n=self.state.n,
            posterior_samples=self.state.post.num_samples,
            hypers_key=self.state.hypers_key,
            solver=self.spec.name,
            warm_cache_entries=len(self.cache),
        )
        return snap
