"""Cumulative engine counters and latency summaries.

Plain host-side Python counters — the engine loop is host code (like any
continuous-batching server); everything device-side stays in the solver's own
``SolveResult``/runtime-matvec accounting. ``EngineStats.snapshot()`` is the
one read path, used by ``GPEngine.stats()``, the serving benchmark, and the
engine tests, so the three can never disagree about what a counter means.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input.

    Textbook nearest-rank: the ``max(⌈q/100 · N⌉, 1)``-th smallest value
    (clamped to N, so q=0 → the minimum and q=100 → the maximum). The previous
    implementation rounded an interpolation index with ``int(round(...))``,
    which goes through Python's round-half-even — biasing small-sample
    quantiles (e.g. p50 of N=4 picked the 3rd element, p50 of N=100 the 51st
    instead of the 50th), exactly where serving latency windows are small.
    """
    if not values:
        return 0.0
    xs = sorted(values)
    rank = min(len(xs), max(1, math.ceil(q / 100.0 * len(xs))))
    return float(xs[rank - 1])


@dataclasses.dataclass
class EngineStats:
    """Monotone counters for one engine's lifetime.

    ``iterations_saved_warm`` is the headline warm-start number: for every
    warm-batch solve, the iteration gap to the most recent *cold* solve of the
    same request kind (clamped at zero).

    Refit accounting (``add_observations``): ``refits`` counts posterior
    updates applied by ANY path; the full-refit path adds its solve iterations
    to ``refit_iterations``, the rank-k path adds its correction-solve
    iterations/matvecs to ``lowrank_iterations``/``lowrank_matvecs`` (k solve
    columns at the OLD n, + one certification matvec). ``compactions`` counts
    ``auto``-policy fallbacks to a full warm refit after the certified drift
    exceeded its budget; ``last_refit_rel_residual`` is the most recent
    update's max true relative residual against the extended operator.

    ``refit_iterations_saved`` credits each WARM full refit against
    ``refit_baseline_iters`` — the most recent COLD solve of the fit system
    (the engine's initial fit, or any ``warm=False`` refit), re-baselined
    whenever one occurs; ``refit_baseline_n`` records the n it was measured
    at. Cold iteration counts are non-decreasing in n at a fixed spec, so a
    baseline measured at a smaller n can only UNDERSTATE savings — the counter
    is a clamped lower bound, never an overstatement (exact lowrank-vs-full
    economics are measured in ``bench_serve``'s write-heavy section instead).

    ``cache_purged`` counts warm-start cache entries dropped because their
    ``hypers_key`` was superseded by a refit re-key (they were unreachable but
    still held LRU slots).
    """

    requests_submitted: int = 0
    requests_served: Dict[str, int] = dataclasses.field(default_factory=dict)
    steps: int = 0
    batches: Dict[str, int] = dataclasses.field(default_factory=dict)
    solves: int = 0
    rhs_columns: int = 0  # real RHS columns batched through shared solves
    padded_columns: int = 0  # bucket padding columns on top of them
    solver_iterations: int = 0
    solver_matvecs: int = 0
    warm_hits: int = 0
    warm_misses: int = 0
    iterations_saved_warm: int = 0
    refits: int = 0  # posterior updates applied, any path
    refit_iterations: int = 0  # full-refit solve iterations
    refit_iterations_saved: int = 0  # vs refit_baseline_iters (see docstring)
    refit_baseline_n: int = 0  # n at which the cold baseline was measured
    refit_baseline_iters: int = 0  # iterations of that cold fit-system solve
    lowrank_updates: int = 0  # rank-k bordered updates accepted
    lowrank_rows: int = 0  # observation rows appended via the rank-k path
    lowrank_iterations: int = 0  # correction-solve iterations (k cols, old n)
    lowrank_matvecs: int = 0  # correction-solve matvecs + certification matvecs
    compactions: int = 0  # auto-policy fallbacks to a full warm refit
    cache_purged: int = 0  # stale-key warm-cache entries dropped on re-key
    last_refit_rel_residual: float = 0.0  # latest update's certified drift
    predict_rows: int = 0
    predict_padded_rows: int = 0
    # fault-tolerance counters (docs/robustness.md): every failure-handling
    # decision the engine takes is visible here, so chaos tests and the
    # serve_gp --json driver can assert on exactly what happened
    deadline_misses: int = 0  # requests expired before execution
    shed: int = 0  # requests rejected at submit (queue over threshold)
    degraded: int = 0  # sample requests downgraded to predict under overload
    retries: int = 0  # batch execution retries (exec-level exceptions)
    escalations: int = 0  # flagged requests re-run solo via solve_robust
    quarantined: int = 0  # submits refused: (kind, seed) exceeded its strikes
    failed: int = 0  # completions delivered with a structured error
    queue_latencies: List[float] = dataclasses.field(default_factory=list)
    total_latencies: List[float] = dataclasses.field(default_factory=list)

    def bump_kind(self, kind: str, n: int = 1) -> None:
        self.requests_served[kind] = self.requests_served.get(kind, 0) + n

    def bump_batch(self, group: str) -> None:
        self.batches[group] = self.batches.get(group, 0) + 1

    def snapshot(self) -> dict:
        """A JSON-ready view — the contract shared by ``GPEngine.stats()``,
        ``benchmarks/bench_serve.py`` and the engine tests."""
        return {
            "requests_submitted": self.requests_submitted,
            "requests_served": dict(self.requests_served),
            "steps": self.steps,
            "batches": dict(self.batches),
            "solves": self.solves,
            "rhs_columns": self.rhs_columns,
            "padded_columns": self.padded_columns,
            "solver_iterations": self.solver_iterations,
            "solver_matvecs": self.solver_matvecs,
            "warm_hits": self.warm_hits,
            "warm_misses": self.warm_misses,
            "iterations_saved_warm": self.iterations_saved_warm,
            "refits": self.refits,
            "refit_iterations": self.refit_iterations,
            "refit_iterations_saved": self.refit_iterations_saved,
            "refit_baseline_n": self.refit_baseline_n,
            "refit_baseline_iters": self.refit_baseline_iters,
            "lowrank_updates": self.lowrank_updates,
            "lowrank_rows": self.lowrank_rows,
            "lowrank_iterations": self.lowrank_iterations,
            "lowrank_matvecs": self.lowrank_matvecs,
            "compactions": self.compactions,
            "cache_purged": self.cache_purged,
            "last_refit_rel_residual": self.last_refit_rel_residual,
            "predict_rows": self.predict_rows,
            "predict_padded_rows": self.predict_padded_rows,
            "deadline_misses": self.deadline_misses,
            "shed": self.shed,
            "degraded": self.degraded,
            "retries": self.retries,
            "escalations": self.escalations,
            "quarantined": self.quarantined,
            "failed": self.failed,
            "queue_latency_p50_s": percentile(self.queue_latencies, 50),
            "queue_latency_p99_s": percentile(self.queue_latencies, 99),
            "total_latency_p50_s": percentile(self.total_latencies, 50),
            "total_latency_p99_s": percentile(self.total_latencies, 99),
        }
