"""Cumulative engine counters and latency summaries.

Plain host-side Python counters — the engine loop is host code (like any
continuous-batching server); everything device-side stays in the solver's own
``SolveResult``/runtime-matvec accounting. ``EngineStats.snapshot()`` is the
one read path, used by ``GPEngine.stats()``, the serving benchmark, and the
engine tests, so the three can never disagree about what a counter means.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not values:
        return 0.0
    xs = sorted(values)
    rank = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return float(xs[rank])


@dataclasses.dataclass
class EngineStats:
    """Monotone counters for one engine's lifetime.

    ``iterations_saved_warm`` is the headline warm-start number: for every
    warm-batch solve, the iteration gap to the most recent *cold* solve of the
    same request kind (clamped at zero); ``refit_iterations_saved`` is the same
    idea for warm-started incremental refits (``add_observations``) against the
    engine's initial cold fit.
    """

    requests_submitted: int = 0
    requests_served: Dict[str, int] = dataclasses.field(default_factory=dict)
    steps: int = 0
    batches: Dict[str, int] = dataclasses.field(default_factory=dict)
    solves: int = 0
    rhs_columns: int = 0  # real RHS columns batched through shared solves
    padded_columns: int = 0  # bucket padding columns on top of them
    solver_iterations: int = 0
    solver_matvecs: int = 0
    warm_hits: int = 0
    warm_misses: int = 0
    iterations_saved_warm: int = 0
    refits: int = 0
    refit_iterations: int = 0
    refit_iterations_saved: int = 0
    predict_rows: int = 0
    predict_padded_rows: int = 0
    # fault-tolerance counters (docs/robustness.md): every failure-handling
    # decision the engine takes is visible here, so chaos tests and the
    # serve_gp --json driver can assert on exactly what happened
    deadline_misses: int = 0  # requests expired before execution
    shed: int = 0  # requests rejected at submit (queue over threshold)
    degraded: int = 0  # sample requests downgraded to predict under overload
    retries: int = 0  # batch execution retries (exec-level exceptions)
    escalations: int = 0  # flagged requests re-run solo via solve_robust
    quarantined: int = 0  # submits refused: (kind, seed) exceeded its strikes
    failed: int = 0  # completions delivered with a structured error
    queue_latencies: List[float] = dataclasses.field(default_factory=list)
    total_latencies: List[float] = dataclasses.field(default_factory=list)

    def bump_kind(self, kind: str, n: int = 1) -> None:
        self.requests_served[kind] = self.requests_served.get(kind, 0) + n

    def bump_batch(self, group: str) -> None:
        self.batches[group] = self.batches.get(group, 0) + 1

    def snapshot(self) -> dict:
        """A JSON-ready view — the contract shared by ``GPEngine.stats()``,
        ``benchmarks/bench_serve.py`` and the engine tests."""
        return {
            "requests_submitted": self.requests_submitted,
            "requests_served": dict(self.requests_served),
            "steps": self.steps,
            "batches": dict(self.batches),
            "solves": self.solves,
            "rhs_columns": self.rhs_columns,
            "padded_columns": self.padded_columns,
            "solver_iterations": self.solver_iterations,
            "solver_matvecs": self.solver_matvecs,
            "warm_hits": self.warm_hits,
            "warm_misses": self.warm_misses,
            "iterations_saved_warm": self.iterations_saved_warm,
            "refits": self.refits,
            "refit_iterations": self.refit_iterations,
            "refit_iterations_saved": self.refit_iterations_saved,
            "predict_rows": self.predict_rows,
            "predict_padded_rows": self.predict_padded_rows,
            "deadline_misses": self.deadline_misses,
            "shed": self.shed,
            "degraded": self.degraded,
            "retries": self.retries,
            "escalations": self.escalations,
            "quarantined": self.quarantined,
            "failed": self.failed,
            "queue_latency_p50_s": percentile(self.queue_latencies, 50),
            "queue_latency_p99_s": percentile(self.queue_latencies, 99),
            "total_latency_p50_s": percentile(self.total_latencies, 50),
            "total_latency_p99_s": percentile(self.total_latencies, 99),
        }
