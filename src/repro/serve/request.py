"""Request lifecycle dataclasses for the GP posterior serving engine.

A request is born in ``GPEngine.submit`` (queued), picked up by the scheduler
into a batch plan (scheduled), executed as part of one shared multi-RHS solve
or one fused batched query pass (executing), and finished as a
:class:`Completion` carrying the payload plus per-request accounting
(completed). The caller holds a :class:`RequestHandle` across that whole
lifecycle — ``submit`` never blocks, ``engine.step()`` drives completions.

Request kinds (``docs/serving.md``):

* ``predict``        — posterior mean + MC variance at a query block; served
                       from the engine's cached posterior state (no solve),
                       row-batched with other predicts into one fused pass;
* ``sample``         — fresh pathwise posterior function samples at a query
                       block; contributes ``num_samples`` RHS columns to the
                       step's shared solve;
* ``thompson_step``  — a parallel Thompson acquisition (§3.3.2): fresh sample
                       columns ride the same shared solve, then each sample is
                       maximised by multi-start Adam ascent; returns the
                       acquisition points.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax

PREDICT = "predict"
SAMPLE = "sample"
THOMPSON = "thompson_step"

#: every request kind the engine accepts
KINDS = (PREDICT, SAMPLE, THOMPSON)
#: kinds that contribute RHS columns to the step's shared multi-RHS solve
SOLVE_KINDS = (SAMPLE, THOMPSON)


@dataclasses.dataclass
class Request:
    """One queued posterior query.

    ``seed`` fully determines the request's randomness (prior weight draw,
    noise draw, ascent starts), so results are reproducible and — for
    deterministic solvers like CG — independent of which batch the request
    lands in (tested: interleaved arrival orders give identical payloads).
    ``warm`` is stamped at submit time from a warm-start cache probe and is
    part of the scheduler's grouping key, so warm repeats never share an
    iteration budget with cold solves.
    """

    id: int
    kind: str
    xs: Optional[jax.Array]  # (m, d) query block; None for thompson_step
    num_samples: int  # RHS columns this request contributes (solve kinds)
    seed: int
    arrival: float  # engine clock() at submit
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)
    warm: bool = False  # warm-start cache probe hit (solve kinds only)
    # absolute engine-clock deadline; requests past it complete with a
    # structured deadline_exceeded error instead of queueing forever
    deadline: Optional[float] = None
    # times this request was skipped by batch formation while queued; the
    # scheduler's starvation guard forces it to head a batch past max_skips
    skips: int = 0

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline

    @property
    def num_rows(self) -> int:
        return 0 if self.xs is None else int(self.xs.shape[0])


@dataclasses.dataclass
class Completion:
    """A finished request: payload + per-request accounting.

    ``value`` is kind-specific:

    * predict       — ``{"mean": (m,), "var": (m,)}``
    * sample        — ``{"samples": (m, num_samples)}``
    * thompson_step — ``{"points": (num_samples, d), "values": (num_samples,)}``

    ``metrics`` is uniform: ``queue_s`` (arrival → batch start), ``exec_s``
    (the batch's compute wall, shared by everything in the batch), ``total_s``,
    ``batch_requests``/``batch_columns``/``bucket_columns``/``bucket_rows``
    (what the request rode with), and for solve kinds ``iterations``,
    ``matvecs`` (shared batch totals) and ``warm``.

    ``error`` is ``None`` on success; a failed request carries a structured
    dict instead of a payload — ``{"code": ..., "message": ...}`` plus
    code-specific detail (``flags``/``rungs`` for ``solver_failure``,
    ``deadline``/``now`` for ``deadline_exceeded``). Codes:
    ``deadline_exceeded`` | ``solver_failure`` | ``exec_error`` |
    ``quarantined``. Never an exception: fault isolation means the caller of a
    *different* request in the same batch sees nothing at all.
    """

    request_id: int
    kind: str
    value: Dict[str, Any]
    metrics: Dict[str, Any]
    error: Optional[Dict[str, Any]] = None

    @property
    def ok(self) -> bool:
        return self.error is None


class RequestHandle:
    """The caller's non-blocking view of a submitted request."""

    def __init__(self, request: Request):
        self.request = request
        self._completion: Optional[Completion] = None

    @property
    def done(self) -> bool:
        return self._completion is not None

    def result(self) -> Completion:
        if self._completion is None:
            raise RuntimeError(
                f"request {self.request.id} ({self.request.kind}) is still "
                f"queued — drive the engine with step()/run_until_idle() first"
            )
        return self._completion

    def _complete(self, completion: Completion) -> None:
        self._completion = completion
