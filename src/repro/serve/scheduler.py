"""Continuous-batching scheduler: FIFO with compatible-group coalescing.

Policy (see ``docs/serving.md``):

* Strict head-of-line FIFO: the oldest queued request fixes the batch's group;
  younger requests join *in arrival order* iff they belong to the same group
  and the caps allow. Incompatible requests are skipped without losing their
  queue position, so no group can starve another — the skipped head is served
  on the next step.
* Groups: ``predict`` requests batch with each other (they share one fused
  row-batched query pass over cached state — no solve); ``sample`` and
  ``thompson_step`` batch together (both contribute RHS columns to ONE shared
  multi-RHS solve), but *warm* (cache-hit) and *cold* requests never mix —
  a batch's iteration count is its slowest column's, so one cold column would
  erase every warm column's latency win.
* Caps: ``max_batch_requests`` bounds any batch; ``max_rhs_columns`` bounds the
  solve batch's total RHS width (the solver's memory per iteration is
  O(n · columns)).
* Starvation guard: every skip increments ``Request.skips``; once a request
  has been passed over ``max_skips`` times, it is promoted to head the next
  batch regardless of the true head's group. Under pure FIFO evolution the
  head is always consumed, so skips stay monotone along the queue and wait
  is already bounded by queue position — the guard is the *invariant* that
  keeps it bounded under any richer policy (priorities, re-queues, external
  mutation of the queue) without auditing each one.
* Deadlines: ``expire(now)`` removes requests whose ``deadline`` has passed —
  the engine completes them with a structured ``deadline_exceeded`` error, so
  nothing silently queues forever.

Bucketing is the engine's job (the scheduler deals in requests, not shapes) —
:func:`bucket` is the shared shape-quantisation helper: padding rows/columns up
to the next power of two keeps the set of compiled solve/query shapes small and
fixed, so steady-state serving never retraces.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from .request import PREDICT, Request, SOLVE_KINDS

#: batch group tags
GROUP_PREDICT = "predict"
GROUP_SOLVE_COLD = "solve_cold"
GROUP_SOLVE_WARM = "solve_warm"


def bucket(n: int, minimum: int) -> int:
    """Smallest power-of-two ≥ max(n, minimum) — the fixed shape ladder."""
    size = max(int(n), int(minimum), 1)
    return 1 << (size - 1).bit_length()


def group_of(req: Request) -> str:
    if req.kind == PREDICT:
        return GROUP_PREDICT
    if req.kind in SOLVE_KINDS:
        return GROUP_SOLVE_WARM if req.warm else GROUP_SOLVE_COLD
    raise ValueError(f"unknown request kind {req.kind!r}")


@dataclasses.dataclass
class BatchPlan:
    """One step's worth of coalesced work, in arrival order."""

    group: str
    requests: List[Request]

    @property
    def total_columns(self) -> int:
        return sum(r.num_samples for r in self.requests)

    @property
    def max_rows(self) -> int:
        return max((r.num_rows for r in self.requests), default=0)


class FIFOScheduler:
    """The engine's queue + batch former. Host-side and O(queue) per step."""

    def __init__(
        self,
        max_batch_requests: int = 16,
        max_rhs_columns: int = 64,
        max_skips: int = 16,
    ):
        if max_batch_requests < 1 or max_rhs_columns < 1:
            raise ValueError("batch caps must be >= 1")
        if max_skips < 1:
            raise ValueError("max_skips must be >= 1")
        self.max_batch_requests = max_batch_requests
        self.max_rhs_columns = max_rhs_columns
        self.max_skips = max_skips
        self._queue: Deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def add(self, req: Request) -> None:
        if req.kind in SOLVE_KINDS and req.num_samples > self.max_rhs_columns:
            raise ValueError(
                f"request wants {req.num_samples} RHS columns but the "
                f"scheduler caps a whole batch at {self.max_rhs_columns}; "
                f"raise max_rhs_columns or split the request"
            )
        self._queue.append(req)

    def pending(self) -> Tuple[Request, ...]:
        return tuple(self._queue)

    def expire(self, now: float) -> List[Request]:
        """Remove and return every queued request whose deadline has passed.

        The engine calls this at the top of each step and completes the
        returned requests with a structured ``deadline_exceeded`` error —
        an expired request never executes and never blocks the queue."""
        expired = [r for r in self._queue if r.expired(now)]
        if expired:
            self._queue = deque(r for r in self._queue if not r.expired(now))
        return expired

    def next_batch(self) -> Optional[BatchPlan]:
        """Form the next batch: head request + every compatible follower the
        caps admit, preserving arrival order; the rest keep their positions.

        Starvation guard: a request skipped ``max_skips`` times is promoted to
        *be* the head — its group fixes this batch — so position-preserving
        skips can never defer any single request indefinitely."""
        if not self._queue:
            return None
        head = self._queue[0]
        for req in self._queue:
            if req.skips >= self.max_skips:
                head = req  # oldest over-skipped request wins
                break
        grp = group_of(head)
        picked: List[Request] = []
        kept: List[Request] = []
        columns = 0
        for req in self._queue:
            want_cols = req.num_samples if req.kind in SOLVE_KINDS else 0
            if (
                group_of(req) == grp
                and len(picked) < self.max_batch_requests
                and (grp == GROUP_PREDICT or columns + want_cols <= self.max_rhs_columns)
            ):
                picked.append(req)
                columns += want_cols
            else:
                req.skips += 1
                kept.append(req)
        self._queue = deque(kept)
        return BatchPlan(group=grp, requests=picked)
