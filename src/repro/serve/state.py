"""Fitted posterior state as pytrees + the warm-start cache.

The engine's whole working set is a handful of pytrees — exactly the objects
the core library already produces: representer weights ``v_mean``, per-sample
uncertainty weights ``alpha``, :class:`~repro.core.rff.PriorSamples` pathwise
paths, and a :class:`~repro.core.solvers.spec.SolverSpec`. This module owns

* :class:`PosteriorState` — one fitted posterior, plus the pieces pathwise
  conditioning needs to update it *incrementally*: the prior paths are
  functions evaluable anywhere, so when new observations arrive the RHS of the
  refit solve extends the old one row-wise (old rows keep their stored noise
  draws ``eps``) and the old solution, zero-padded to the new n, is a strong
  warm start (Ch. 5 §5.3 — measurably fewer iterations than a cold refit);
* :class:`WarmStartCache` — previous solve solutions keyed by
  ``(hyperparameter fingerprint, request kind)`` and, within that, by the
  request seed; a repeat query reuses its previous representer weights as
  ``x0`` and converges in a handful of iterations.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.kernels_fn import KernelParams
from ..core.operators import Gram
from ..core.pathwise import PosteriorFunctions
from ..core.rff import PriorSamples, sample_prior
from ..core.solvers.base import SolveResult
from ..core.solvers.spec import SolverSpec, as_spec, solve


def hypers_fingerprint(params: KernelParams, n: int) -> str:
    """A hashable identity for 'the linear system being solved'.

    Covers the kernel hyperparameters (values + kind) *and* the training-set
    size n: after ``add_observations`` the operator changes shape, so cached
    solutions keyed under the old fingerprint become unreachable instead of
    surfacing as shape errors inside the solver (see ``_validate_x0``).
    """
    h = hashlib.sha256()
    h.update(params.kind.encode())
    h.update(np.int64(n).tobytes())
    for leaf in jax.tree_util.tree_leaves(
        (params.log_lengthscale, params.log_signal, params.log_noise)
    ):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class PosteriorState:
    """One fitted posterior, held long-lived by the engine.

    ``eps`` (the fit solve's noise draws) is retained so incremental refits can
    extend the *same* pathwise linear systems row-wise instead of drawing fresh
    ones — that is what makes the old solution a useful warm start.
    """

    params: KernelParams
    x: jax.Array  # (n, d)
    y: jax.Array  # (n,)
    spec: SolverSpec
    post: PosteriorFunctions  # v_mean, alpha, prior paths — all pytrees
    eps: jax.Array  # (n, s) fit-solve noise draws (pathwise targets)
    fit_result: SolveResult
    hypers_key: str

    @property
    def n(self) -> int:
        return int(self.x.shape[0])

    @property
    def prior(self) -> PriorSamples:
        return self.post.prior

    def operator(self) -> Gram:
        """The (K + σ²I) operator every serve-time solve runs against."""
        return Gram(x=self.x, params=self.params)


def fit_state(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
    *,
    spec,
    num_samples: int = 16,
    num_features: int = 2048,
    x0: Optional[jax.Array] = None,
) -> PosteriorState:
    """Fit the engine's posterior state: one batched pathwise solve.

    Same math as :func:`~repro.core.pathwise.posterior_functions`, but keeps
    the noise draws ``eps`` so :func:`extend_state` can refit incrementally.
    """
    s = as_spec(spec)
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    kp, ke, ks = jax.random.split(key, 3)
    op = Gram(x=x, params=params)
    prior = sample_prior(params, kp, num_samples, num_features, x.shape[1])
    f_x = prior(x)  # (n, s)
    eps = jnp.sqrt(op.noise) * jax.random.normal(ke, f_x.shape, dtype=f_x.dtype)
    data = jnp.concatenate([y[:, None], f_x], axis=1)
    delta = jnp.concatenate([jnp.zeros_like(y)[:, None], eps / op.noise], axis=1)
    res = solve(op, data, s, key=ks, x0=x0, delta=delta)
    sol = res.solution
    post = PosteriorFunctions(
        params=params,
        x=x,
        prior=prior,
        v_mean=sol[:, 0],
        alpha=sol[:, 1:],
        solve_info=res,
    )
    return PosteriorState(
        params=params,
        x=x,
        y=y,
        spec=s,
        post=post,
        eps=eps,
        fit_result=res,
        hypers_key=hypers_fingerprint(params, x.shape[0]),
    )


def extend_state(
    state: PosteriorState,
    x_new: jax.Array,
    y_new: jax.Array,
    key: jax.Array,
    *,
    warm: bool = True,
) -> PosteriorState:
    """Incremental posterior update: new observations, warm-started refit.

    Pathwise conditioning makes this cheap: the prior paths are functions, so
    ``f_X`` on the extended inputs is the *same* columns with new rows
    appended, old rows keep their stored noise draws, and only the new rows
    draw fresh ones. The refit therefore solves a system whose RHS agrees with
    the old one on the first n rows — the old solution, zero-padded to the new
    n, is the warm start that cuts iterations (measured by the engine's
    ``refit_iterations_saved`` counter and gated in the serve benchmark).
    """
    x_new = jnp.atleast_2d(jnp.asarray(x_new))
    y_new = jnp.atleast_1d(jnp.asarray(y_new))
    x2 = jnp.concatenate([state.x, x_new], axis=0)
    y2 = jnp.concatenate([state.y, y_new], axis=0)
    op = Gram(x=x2, params=state.params)
    prior = state.prior
    ke, ks = jax.random.split(key)
    f_new = prior(x_new)  # same paths, new rows
    eps_new = jnp.sqrt(op.noise) * jax.random.normal(
        ke, f_new.shape, dtype=f_new.dtype
    )
    eps2 = jnp.concatenate([state.eps, eps_new], axis=0)
    f_x2 = jnp.concatenate([prior(state.x), f_new], axis=0)
    data = jnp.concatenate([y2[:, None], f_x2], axis=1)
    delta = jnp.concatenate([jnp.zeros_like(y2)[:, None], eps2 / op.noise], axis=1)
    x0 = None
    if warm:
        old = jnp.concatenate(
            [state.post.v_mean[:, None], state.post.alpha], axis=1
        )
        x0 = jnp.concatenate(
            [old, jnp.zeros((x_new.shape[0], old.shape[1]), dtype=old.dtype)],
            axis=0,
        )
    res = solve(op, data, state.spec, key=ks, x0=x0, delta=delta)
    sol = res.solution
    post = PosteriorFunctions(
        params=state.params,
        x=x2,
        prior=prior,
        v_mean=sol[:, 0],
        alpha=sol[:, 1:],
        solve_info=res,
    )
    return PosteriorState(
        params=state.params,
        x=x2,
        y=y2,
        spec=state.spec,
        post=post,
        eps=eps2,
        fit_result=res,
        hypers_key=hypers_fingerprint(state.params, x2.shape[0]),
    )


class WarmStartCache:
    """Previous solve solutions, keyed by ``(hypers fingerprint, request kind)``
    and — within a key — by the request seed that generated the RHS columns.

    A repeat query (same seed, same hyperparameters, same kind) regenerates the
    exact same RHS columns, so its cached solution is a near-exact warm start:
    CG re-verifies it in a couple of iterations instead of re-solving — the
    serving analogue of Ch. 5's warm-started MLL inner solves, but still every
    bit as fresh (the solver, not the cache, certifies the residual).

    Plain LRU over ``(key, seed)`` entries; values are host-side numpy copies
    so cached solutions never pin device buffers.
    """

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: OrderedDict[Tuple[str, str, int], np.ndarray] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def probe(self, hypers_key: str, kind: str, seed: int) -> bool:
        """Non-mutating hit test (used at submit time to tag requests warm)."""
        return (hypers_key, kind, seed) in self._entries

    def lookup(
        self, hypers_key: str, kind: str, seed: int
    ) -> Optional[np.ndarray]:
        entry = self._entries.get((hypers_key, kind, seed))
        if entry is not None:
            self._entries.move_to_end((hypers_key, kind, seed))
        return entry

    def store(
        self, hypers_key: str, kind: str, seed: int, solution: jax.Array
    ) -> None:
        self._entries[(hypers_key, kind, seed)] = np.asarray(solution)
        self._entries.move_to_end((hypers_key, kind, seed))
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
