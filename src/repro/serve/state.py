"""Fitted posterior state as pytrees + the warm-start cache.

The engine's whole working set is a handful of pytrees — exactly the objects
the core library already produces: representer weights ``v_mean``, per-sample
uncertainty weights ``alpha``, :class:`~repro.core.rff.PriorSamples` pathwise
paths, and a :class:`~repro.core.solvers.spec.SolverSpec`. This module owns

* :class:`PosteriorState` — one fitted posterior, plus the pieces pathwise
  conditioning needs to update it *incrementally*: the prior paths are
  functions evaluable anywhere, so when new observations arrive the RHS of the
  refit solve extends the old one row-wise (old rows keep their stored noise
  draws ``eps`` and cached prior values ``f_x``). Two update paths:
  :func:`extend_state` re-solves the extended system with the old solution,
  zero-padded to the new n, as a strong warm start (Ch. 5 §5.3 — measurably
  fewer iterations than a cold refit); :func:`update_state_lowrank` skips the
  (n+k)-re-solve entirely with a rank-k bordered-system correction whose
  iterative cost is k solve columns at the OLD n;
* :class:`WarmStartCache` — previous solve solutions keyed by
  ``(hyperparameter fingerprint, request kind)`` and, within that, by the
  request seed; a repeat query reuses its previous representer weights as
  ``x0`` and converges in a handful of iterations.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.kernels_fn import KernelParams, gram
from ..core.operators import Gram
from ..core.pathwise import PosteriorFunctions, pathwise_target_rows
from ..core.solvers.base import (
    FLAG_BREAKDOWN,
    FLAG_NONFINITE,
    FLAG_STAGNATION,
    SolveResult,
)
from ..core.rff import PriorSamples, sample_prior
from ..core.solvers.spec import SolverSpec, as_spec, solve, solve_bordered


def hypers_fingerprint(params: KernelParams, n: int) -> str:
    """A hashable identity for 'the linear system being solved'.

    Covers the kernel hyperparameters (values + kind) *and* the training-set
    size n: after ``add_observations`` the operator changes shape, so cached
    solutions keyed under the old fingerprint become unreachable instead of
    surfacing as shape errors inside the solver (see ``_validate_x0``).
    """
    h = hashlib.sha256()
    h.update(params.kind.encode())
    h.update(np.int64(n).tobytes())
    for leaf in jax.tree_util.tree_leaves(
        (params.log_lengthscale, params.log_signal, params.log_noise)
    ):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class PosteriorState:
    """One fitted posterior, held long-lived by the engine.

    ``eps`` (the fit solve's noise draws) and ``f_x`` (the prior paths
    evaluated on the training rows) are retained so incremental refits can
    extend the *same* pathwise linear systems row-wise instead of drawing (or
    re-evaluating) anything over the old rows — that is what makes the old
    solution a useful warm start for :func:`extend_state` and an exactly
    correctable one for :func:`update_state_lowrank`.
    """

    params: KernelParams
    x: jax.Array  # (n, d)
    y: jax.Array  # (n,)
    spec: SolverSpec
    post: PosteriorFunctions  # v_mean, alpha, prior paths — all pytrees
    eps: jax.Array  # (n, s) fit-solve noise draws (pathwise targets)
    f_x: jax.Array  # (n, s) prior paths at x, cached at fit, extended row-wise
    fit_result: SolveResult
    hypers_key: str

    @property
    def n(self) -> int:
        return int(self.x.shape[0])

    @property
    def prior(self) -> PriorSamples:
        return self.post.prior

    def operator(self) -> Gram:
        """The (K + σ²I) operator every serve-time solve runs against."""
        return Gram(x=self.x, params=self.params)


def fit_state(
    params: KernelParams,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
    *,
    spec,
    num_samples: int = 16,
    num_features: int = 2048,
    x0: Optional[jax.Array] = None,
) -> PosteriorState:
    """Fit the engine's posterior state: one batched pathwise solve.

    Same math as :func:`~repro.core.pathwise.posterior_functions`, but keeps
    the noise draws ``eps`` so :func:`extend_state` can refit incrementally.
    """
    s = as_spec(spec)
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    kp, ke, ks = jax.random.split(key, 3)
    op = Gram(x=x, params=params)
    prior = sample_prior(params, kp, num_samples, num_features, x.shape[1])
    f_x = prior(x)  # (n, s)
    data, delta, eps = pathwise_target_rows(op.noise, y, f_x, ke)
    res = solve(op, data, s, key=ks, x0=x0, delta=delta)
    sol = res.solution
    post = PosteriorFunctions(
        params=params,
        x=x,
        prior=prior,
        v_mean=sol[:, 0],
        alpha=sol[:, 1:],
        solve_info=res,
    )
    return PosteriorState(
        params=params,
        x=x,
        y=y,
        spec=s,
        post=post,
        eps=eps,
        f_x=f_x,
        fit_result=res,
        hypers_key=hypers_fingerprint(params, x.shape[0]),
    )


def extend_state(
    state: PosteriorState,
    x_new: jax.Array,
    y_new: jax.Array,
    key: jax.Array,
    *,
    warm: bool = True,
) -> PosteriorState:
    """Incremental posterior update: new observations, warm-started refit.

    Pathwise conditioning makes this cheap: the prior paths are functions, so
    ``f_X`` on the extended inputs is the *same* columns with new rows
    appended, old rows keep their stored noise draws, and only the new rows
    draw fresh ones. The refit therefore solves a system whose RHS agrees with
    the old one on the first n rows — the old solution, zero-padded to the new
    n, is the warm start that cuts iterations (measured by the engine's
    ``refit_iterations_saved`` counter and gated in the serve benchmark).
    """
    x_new = jnp.atleast_2d(jnp.asarray(x_new))
    y_new = jnp.atleast_1d(jnp.asarray(y_new))
    x2 = jnp.concatenate([state.x, x_new], axis=0)
    y2 = jnp.concatenate([state.y, y_new], axis=0)
    op = Gram(x=x2, params=state.params)
    prior = state.prior
    ke, ks = jax.random.split(key)
    f_new = prior(x_new)  # same paths, evaluated on the k NEW rows only —
    # old rows reuse the cached state.f_x instead of re-running the fused
    # feature pass over all n of them on every refit
    _, _, eps_new = pathwise_target_rows(op.noise, y_new, f_new, ke)
    eps2 = jnp.concatenate([state.eps, eps_new], axis=0)
    f_x2 = jnp.concatenate([state.f_x, f_new], axis=0)
    data = jnp.concatenate([y2[:, None], f_x2], axis=1)
    delta = jnp.concatenate([jnp.zeros_like(y2)[:, None], eps2 / op.noise], axis=1)
    x0 = None
    if warm:
        old = jnp.concatenate(
            [state.post.v_mean[:, None], state.post.alpha], axis=1
        )
        x0 = jnp.concatenate(
            [old, jnp.zeros((x_new.shape[0], old.shape[1]), dtype=old.dtype)],
            axis=0,
        )
    res = solve(op, data, state.spec, key=ks, x0=x0, delta=delta)
    sol = res.solution
    post = PosteriorFunctions(
        params=state.params,
        x=x2,
        prior=prior,
        v_mean=sol[:, 0],
        alpha=sol[:, 1:],
        solve_info=res,
    )
    return PosteriorState(
        params=state.params,
        x=x2,
        y=y2,
        spec=state.spec,
        post=post,
        eps=eps2,
        f_x=f_x2,
        fit_result=res,
        hypers_key=hypers_fingerprint(state.params, x2.shape[0]),
    )


@jax.jit
def _true_rel_residual(op, sol, rhs):
    """Certification pass: ``rhs - op.mv(sol)`` norms, jitted so the one
    extended-operator matvec costs one solver iteration, not an eager
    dispatch of the whole blocked kernel pipeline."""
    residual = rhs - op.mv(sol)
    rn = jnp.linalg.norm(residual, axis=0)
    bn = jnp.maximum(jnp.linalg.norm(rhs, axis=0), 1e-30)
    return rn, rn / bn


def _or_flags(flags) -> jax.Array:
    """OR-reduce a per-column flag vector to one combined bitmask."""
    f = jnp.atleast_1d(jnp.asarray(flags, dtype=jnp.int32))
    return (
        jnp.max(f & FLAG_NONFINITE)
        | jnp.max(f & FLAG_BREAKDOWN)
        | jnp.max(f & FLAG_STAGNATION)
    )


def update_state_lowrank(
    state: PosteriorState,
    x_new: jax.Array,
    y_new: jax.Array,
    key: jax.Array,
    *,
    z_tol_factor: float = 1e-1,
) -> PosteriorState:
    """Rank-k incremental posterior update via the bordered-system identity.

    Pathwise conditioning makes appending k observations a rank-k correction to
    the representer weights and per-sample uncertainty weights, NOT a fresh
    (n+k)-row solve: all 1+s systems share (K+σ²I), so one k-column solve
    Z = (K_old+σ²I)⁻¹ K(X_old, X_new) against the OLD operator, a dense k×k
    Schur factorization, and closed-form back-substitution extend every column
    of [v_mean | alpha] at once (:func:`~repro.core.solvers.spec.solve_bordered`
    has the algebra). Cost scales with k solve columns at the old n —
    independent of the sample count s — versus :func:`extend_state`'s
    (1+s)-column re-solve at n+k.

    Draw convention matches :func:`extend_state` (``ke, ks = split(key)``; new
    rows' noise draws from ``ke``), so at matching seeds both paths extend the
    *same* linear system and agree to solver tolerance.

    The returned ``fit_result`` is certified against the EXTENDED operator:
    one (n+k)-matvec computes the true residual of the corrected solution
    (accounted in ``matvecs`` on top of the Z solve's), so accumulated drift
    across successive low-rank updates is observable — the engine's ``auto``
    policy compacts (falls back to a full warm refit) when it exceeds the spec
    tolerance budget. The solver, not the cache, certifies freshness.

    ``z_tol_factor``: the back-substitution amplifies Z-solve error by ‖w‖
    (the Schur system's σ²-scaled conditioning), so the k correction columns
    are solved ``z_tol_factor`` TIGHTER than the state's spec tolerance. The
    premium is cheap: the Z columns are smooth kernel columns, which CG
    contracts roughly twice as fast as the fit system's noise-bearing RHS, so
    even the tightened solve stays strictly below a warm full refit's
    iterations. The default 1e-1 keeps the certified residual at ~1.5× the
    spec tol per update in the serving regime (measured in ``bench_serve``'s
    write-heavy section); successive updates stack drift until the engine's
    ``auto`` budget (``compaction_tol_factor`` × tol) forces a compaction.
    """
    x_new = jnp.atleast_2d(jnp.asarray(x_new))
    y_new = jnp.atleast_1d(jnp.asarray(y_new))
    x2 = jnp.concatenate([state.x, x_new], axis=0)
    y2 = jnp.concatenate([state.y, y_new], axis=0)
    op_old = state.operator()
    prior = state.prior
    ke, ks = jax.random.split(key)
    f_new = prior(x_new)  # same paths, new rows only (f_x is cached)
    data_new, delta_new, eps_new = pathwise_target_rows(
        op_old.noise, y_new, f_new, ke
    )
    rhs_new = data_new + op_old.noise * delta_new  # [y_new | f_new + eps_new]
    sol_old = jnp.concatenate(
        [state.post.v_mean[:, None], state.post.alpha], axis=1
    )
    b_cols = gram(state.params, state.x, x_new)  # (n, k) cross-covariance
    c_new = gram(state.params, x_new)  # (k, k), noise added inside the helper
    tol = float(getattr(state.spec, "tol", 1e-2))
    z_spec = (
        dataclasses.replace(state.spec, tol=tol * z_tol_factor)
        if dataclasses.is_dataclass(state.spec)
        else state.spec
    )
    sol_ext, z_result = solve_bordered(
        op_old, b_cols, c_new, rhs_new, sol_old, z_spec, key=ks
    )

    # certify the corrected solution against the EXTENDED operator: one
    # (n+k)-matvec gives the TRUE residual, so the result's convergence story
    # is as honest as a full refit's — drift from the inherited r_old and the
    # inexact Z shows up here instead of silently accumulating
    op2 = Gram(x=x2, params=state.params)
    eps2 = jnp.concatenate([state.eps, eps_new], axis=0)
    f_x2 = jnp.concatenate([state.f_x, f_new], axis=0)
    rhs_ext = jnp.concatenate([y2[:, None], f_x2 + eps2], axis=1)
    rn, rel = _true_rel_residual(op2, sol_ext, rhs_ext)
    # any frozen Z column poisons every output column through Z·w — carry the
    # correction solve's flags onto all of them, plus the final payload check
    carried = _or_flags(z_result.flags)
    col_ok = jnp.all(jnp.isfinite(sol_ext), axis=0) & jnp.isfinite(rn)
    flags = jnp.broadcast_to(carried, rel.shape).astype(jnp.int32)
    flags = flags | jnp.where(col_ok, 0, FLAG_NONFINITE).astype(jnp.int32)
    flags = jnp.where((rel <= tol) & col_ok, flags & ~FLAG_STAGNATION, flags)
    res = SolveResult(
        solution=sol_ext,
        residual_norm=rn,
        rel_residual=rel,
        iterations=z_result.iterations,  # k correction columns at the old n
        converged=jnp.all((rel <= tol) & (flags == 0)),
        matvecs=jnp.asarray(z_result.matvecs) + 1,  # + the certification matvec
        flags=flags,
    )
    post = PosteriorFunctions(
        params=state.params,
        x=x2,
        prior=prior,
        v_mean=sol_ext[:, 0],
        alpha=sol_ext[:, 1:],
        solve_info=res,
    )
    return PosteriorState(
        params=state.params,
        x=x2,
        y=y2,
        spec=state.spec,
        post=post,
        eps=eps2,
        f_x=f_x2,
        fit_result=res,
        hypers_key=hypers_fingerprint(state.params, x2.shape[0]),
    )


class WarmStartCache:
    """Previous solve solutions, keyed by ``(hypers fingerprint, request kind)``
    and — within a key — by the request seed that generated the RHS columns.

    A repeat query (same seed, same hyperparameters, same kind) regenerates the
    exact same RHS columns, so its cached solution is a near-exact warm start:
    CG re-verifies it in a couple of iterations instead of re-solving — the
    serving analogue of Ch. 5's warm-started MLL inner solves, but still every
    bit as fresh (the solver, not the cache, certifies the residual).

    Plain LRU over ``(key, seed)`` entries; values are host-side numpy copies
    so cached solutions never pin device buffers.
    """

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: OrderedDict[Tuple[str, str, int], np.ndarray] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def probe(self, hypers_key: str, kind: str, seed: int) -> bool:
        """Non-mutating hit test (used at submit time to tag requests warm)."""
        return (hypers_key, kind, seed) in self._entries

    def lookup(
        self, hypers_key: str, kind: str, seed: int
    ) -> Optional[np.ndarray]:
        entry = self._entries.get((hypers_key, kind, seed))
        if entry is not None:
            self._entries.move_to_end((hypers_key, kind, seed))
        return entry

    def store(
        self, hypers_key: str, kind: str, seed: int, solution: jax.Array
    ) -> None:
        self._entries[(hypers_key, kind, seed)] = np.asarray(solution)
        self._entries.move_to_end((hypers_key, kind, seed))
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def purge(self, hypers_key: str) -> int:
        """Drop every entry NOT keyed under ``hypers_key``; returns the count.

        After a refit re-keys the engine, entries under a superseded
        fingerprint are permanently unreachable (probes and lookups always use
        the live key) yet still occupy LRU slots until natural eviction —
        crowding out warm starts that could actually hit. The engine calls
        this on every re-key and surfaces the count as ``cache_purged``.
        """
        stale = [k for k in self._entries if k[0] != hypers_key]
        for k in stale:
            del self._entries[k]
        return len(stale)
