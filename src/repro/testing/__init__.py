"""Test/benchmark support — fault injection for the robustness suite
(docs/robustness.md). Not imported by the library proper."""
from .faults import (  # noqa: F401
    DenseOperator,
    FaultyFeatureOperator,
    FaultyOperator,
    nan_columns,
    near_singular_problem,
)
