"""Deterministic fault injection for the robustness suite (docs/robustness.md).

Three fault models, all pure and trace-safe (no host callbacks, no call
counters — a fault either fires for a given operand shape or it doesn't, so
tests are reproducible under jit, vmap and ``lax.while_loop`` alike):

* :class:`FaultyOperator` — wraps any :class:`LinearOperator` and corrupts
  chosen *columns* of every ``mv`` output. Because the solvers' health checks
  are per-column and matrix products keep columns independent, this poisons
  exactly the chosen RHS lanes of a shared multi-RHS solve and nothing else —
  the serving engine's fault-isolation contract is tested against precisely
  this wrapper. Columns beyond the operand's width never fire, so a request
  poisoned at batch position c ≥ its solo width escapes the fault when the
  engine re-runs it alone (the transient-corruption scenario); set
  ``min_width`` to make that threshold explicit.
* :class:`FaultyFeatureOperator` — the rectangular twin: corrupts chosen
  columns of ``phi_mv`` output, i.e. poisons the *right-hand sides* built from
  prior feature draws. Unlike a transient matvec fault, a poisoned RHS follows
  the request into its solo rescue — this is the repeat-offender model the
  engine's quarantine is tested with.
* :class:`DenseOperator` — a plain dense operator for constructing exact
  pathologies: indefinite matrices (CG breakdown, pᵀAp ≤ 0), exactly singular
  systems, arbitrary conditioning. ``near_singular_problem`` builds the
  standard duplicated-rows Gram that makes fp32 CG stagnate.

Injection is column-surgical on purpose: corrupting whole matvec outputs
would only test the trivial "everything failed" path, while per-column faults
exercise freezing, flag propagation, healthy-column parity and solo rescue.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..core.kernels_fn import make_params
from ..core.operators import Gram, LinearOperator

_STATIC = dict(metadata=dict(static=True))


def _corrupt_columns(out: jax.Array, columns, value: float, min_width: int):
    """Set the chosen columns of a matvec/feature-map output to ``value``.

    Width gating is static (shapes are trace-time constants), so the wrapped
    operator traces to a clean or a faulty program per shape — never a
    data-dependent branch."""
    if out.ndim == 1:
        if 0 in columns and min_width <= 1:
            return jnp.full_like(out, value)
        return out
    if out.shape[1] < max(min_width, 1):
        return out
    for c in columns:
        if c < out.shape[1]:
            out = out.at[:, c].set(value)
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FaultyOperator(LinearOperator):
    """``inner`` with chosen ``mv``-output columns forced to ``value``.

    Everything except ``mv`` forwards to the wrapped operator (capabilities
    included, via ``__getattr__`` — so ``rows_mv``-based stochastic solvers
    see the *clean* operator; this wrapper models a fault in the fused
    multi-RHS matvec path, the one every CG-family iteration goes through).
    ``dense()`` explicitly forwards clean: a dense fallback is a different
    code path and escaping a transient matvec fault there is the realistic
    behaviour — tests that want the dense rung closed set
    ``EscalationPolicy(dense_fallback_max_n=0)``.
    """

    inner: Any  # the wrapped LinearOperator (a pytree)
    columns: Tuple[int, ...] = dataclasses.field(default=(0,), **_STATIC)
    value: float = dataclasses.field(default=float("nan"), **_STATIC)
    #: fault only fires when the operand has at least this many columns —
    #: lets a batch-position fault vanish on solo re-runs
    min_width: int = dataclasses.field(default=0, **_STATIC)

    @property
    def shape(self) -> tuple:
        return self.inner.shape

    @property
    def noise(self) -> jax.Array:
        return self.inner.noise

    def mv(self, v: jax.Array) -> jax.Array:
        return _corrupt_columns(
            self.inner.mv(v), self.columns, self.value, self.min_width
        )

    def diag_part(self) -> jax.Array:
        return self.inner.diag_part()

    def dense(self) -> jax.Array:
        return self.inner.dense()

    def prepare_for_solve(self) -> "FaultyOperator":
        prep = getattr(self.inner, "prepare_for_solve", None)
        if callable(prep):
            return dataclasses.replace(self, inner=prep())
        return self

    def __getattr__(self, name: str):
        if name.startswith("__") or name in (
            "inner", "columns", "value", "min_width"
        ):
            raise AttributeError(name)
        return getattr(object.__getattribute__(self, "inner"), name)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FaultyFeatureOperator:
    """A feature operator whose ``phi_mv`` output columns are forced to
    ``value`` — poisons the RHS built from those prior weight columns, and
    keeps poisoning them on every rebuild (the persistent-fault model the
    engine's strike/quarantine bookkeeping is tested with)."""

    inner: Any  # the wrapped FeatureOperator (a pytree)
    columns: Tuple[int, ...] = dataclasses.field(default=(0,), **_STATIC)
    value: float = dataclasses.field(default=float("nan"), **_STATIC)
    min_width: int = dataclasses.field(default=0, **_STATIC)

    @property
    def num_features(self) -> int:
        return self.inner.num_features

    @property
    def shape(self) -> tuple:
        return self.inner.shape

    def phi_mv(self, x: jax.Array, w: jax.Array) -> jax.Array:
        return _corrupt_columns(
            self.inner.phi_mv(x, w), self.columns, self.value, self.min_width
        )

    def phi_t_mv(self, x: jax.Array, u: jax.Array) -> jax.Array:
        return self.inner.phi_t_mv(x, u)

    def __getattr__(self, name: str):
        if name.startswith("__") or name in (
            "inner", "columns", "value", "min_width"
        ):
            raise AttributeError(name)
        return getattr(object.__getattribute__(self, "inner"), name)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseOperator(LinearOperator):
    """A + σ²I for an explicit dense A — exact pathologies on demand.

    CG breakdown: ``DenseOperator(a=jnp.diag(jnp.array([1., -1.])))`` with
    b = [1, 1] hits pᵀAp = 0 on the very first iteration."""

    a: jax.Array  # (n, n) the raw matrix (need not be PSD — that's the point)
    sigma2: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.asarray(0.0)
    )

    @property
    def shape(self) -> tuple:
        return self.a.shape

    @property
    def noise(self) -> jax.Array:
        return self.sigma2

    def mv(self, v: jax.Array) -> jax.Array:
        return self.a @ v + self.sigma2 * v

    def diag_part(self) -> jax.Array:
        return jnp.diag(self.a) + self.sigma2

    def dense(self) -> jax.Array:
        return self.a + self.sigma2 * jnp.eye(self.a.shape[0], dtype=self.a.dtype)


def near_singular_problem(
    n: int = 96,
    s: int = 3,
    *,
    noise: float = 1e-8,
    seed: int = 0,
    d: int = 2,
):
    """The standard ill-conditioned setup: a Gram over inputs with duplicated
    rows and vanishing noise — fp32 CG stagnates well above any honest
    tolerance (flags ``FLAG_STAGNATION`` with ``stall_window`` ≈ 30).

    Returns ``(op, b, params, x)``."""
    key = jax.random.PRNGKey(seed)
    kx, kb = jax.random.split(key)
    half = jax.random.uniform(kx, (n // 2, d))
    x = jnp.concatenate([half, half], axis=0)[:n]  # duplicated rows
    params = make_params(kind="se", lengthscale=0.5, signal=1.0, noise=noise)
    op = Gram(x=x, params=params)
    b = jax.random.normal(kb, (n, s))
    return op, b, params, x


def nan_columns(b: jax.Array, columns: Tuple[int, ...]) -> jax.Array:
    """Return ``b`` with the chosen columns replaced by NaN."""
    b = jnp.asarray(b)
    for c in columns:
        b = b.at[:, c].set(jnp.nan)
    return b
