"""Sharded, atomic, step-tagged checkpointing with a manifest (DESIGN.md §5).

Layout:
    <dir>/step_000123.tmp/...   (written first)
    <dir>/step_000123/          (atomic rename when complete)
        manifest.json           {step, leaf paths, shapes, dtypes, logical specs}
        arrays.npz              one entry per flattened leaf

Arrays are saved by *tree path* with their logical-axis names, NOT by physical
layout: any mesh whose axes divide the logical dims can restore, which is what
makes elastic restarts (shrunk mesh after a pod failure) possible — the restore
path just re-shards with the new mesh's rules.

On a real cluster each host writes only its local shards; here (single process)
we write the full arrays but keep the same manifest contract.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Atomic write: tmp dir + rename. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any, step: Optional[int] = None):
    """Restore into the structure of `template` (arrays or ShapeDtypeStructs).
    Returns (tree, step, extra) or (None, None, None) when no checkpoint exists."""
    st = latest_step(directory) if step is None else step
    if st is None:
        return None, None, None
    path = os.path.join(directory, f"step_{st:08d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for pth, leaf in flat[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in pth)
        arr = data[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat[1], leaves), st, manifest.get("extra", {})


def prune_checkpoints(directory: str, keep: int = 3):
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(n[5:]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for st in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{st:08d}"), ignore_errors=True)
