"""Gradient compression for cross-pod reduction (DESIGN.md §5).

int8 stochastic-rounding quantisation with **error feedback**: the residual of each
quantisation is carried and added to the next step's gradient, so the compressed
SGD trajectory tracks the exact one (error-feedback SGD converges at the same rate
for smooth objectives). Intended for the "pod" axis all-reduce, whose DCN bandwidth
is ~10× lower than ICI; per-tensor scale keeps the quantisation range adaptive.

compress → (int8 payload, fp32 scale); decompress reverses. 4× wire reduction vs
bf16. The trainer applies it leaf-wise to the cross-pod gradient contribution.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jax.Array  # int8
    scale: jax.Array  # ()


def compress(x: jax.Array, key: jax.Array) -> Compressed:
    """Stochastic-rounding int8 quantisation."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    lo = jnp.floor(y)
    p = y - lo  # probability of rounding up
    up = jax.random.bernoulli(key, p.astype(jnp.float32))
    q = jnp.clip(lo + up.astype(jnp.float32), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale)


def decompress(c: Compressed, dtype=jnp.float32) -> jax.Array:
    return (c.q.astype(jnp.float32) * c.scale).astype(dtype)


def compress_with_feedback(grad: jax.Array, error: jax.Array, key: jax.Array):
    """Returns (compressed, new_error). new_error = (grad+error) − decompress(...)."""
    g = grad.astype(jnp.float32) + error
    c = compress(g, key)
    new_error = g - decompress(c)
    return c, new_error


def tree_compress_with_feedback(grads: Any, errors: Any, key: jax.Array):
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(errors)
    keys = jax.random.split(key, len(leaves))
    cs, nes = [], []
    for g, e, k in zip(leaves, errs, keys):
        c, ne = compress_with_feedback(g, e, k)
        cs.append(c)
        nes.append(ne)
    return jax.tree.unflatten(tdef, cs), jax.tree.unflatten(tdef, nes)


def tree_decompress(comp: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda c, g: decompress(c, g.dtype),
        comp, like, is_leaf=lambda x: isinstance(x, Compressed),
    )


def init_error_state(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
