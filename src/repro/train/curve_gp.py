"""Learning-curve prediction with the latent-Kronecker GP (Ch. 6 §6.3.2), wired
into the trainer as a first-class feature.

The trainer (or a sweep of trainers) logs (config, step) → loss into a partially
observed grid — exactly LKGP's setting: configs × steps is a product space, and
runs observed only as prefixes give the projection mask. The fitted GP predicts
each curve's continuation; the trainer uses it to

  * early-stop runs whose predicted final loss is dominated (sweep pruning),
  * flag divergence (observed loss outside the posterior's 3σ band).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.kernels_fn import make_params
from ..core.kronecker import lkgp_posterior, make_lkgp


@dataclasses.dataclass
class CurvePrediction:
    mean: jax.Array  # (configs, steps) posterior mean over the full grid
    std: jax.Array  # (configs, steps)
    final_mean: jax.Array  # (configs,) predicted final-step loss
    final_std: jax.Array


def fit_curve_gp(
    curves: jax.Array,  # (n_configs, n_steps) observed losses (junk where masked)
    mask: jax.Array,  # (n_configs, n_steps) bool — True = observed
    config_features: jax.Array,  # (n_configs, d1)
    step_features: Optional[jax.Array] = None,  # (n_steps, 1); default log-steps
    *,
    noise: float = 1e-2,
    num_samples: int = 16,
    max_iters: int = 300,
    key: Optional[jax.Array] = None,
) -> CurvePrediction:
    n_cfg, n_steps = curves.shape
    if step_features is None:
        step_features = jnp.log(jnp.arange(1, n_steps + 1, dtype=jnp.float32))[:, None]
    key = jax.random.PRNGKey(0) if key is None else key

    y_obs = curves.reshape(-1)[jnp.asarray(jnp.nonzero(mask.reshape(-1))[0])]
    mu = y_obs.mean()
    gp = make_lkgp(
        make_params("matern52", lengthscale=1.0, signal=1.0, d=config_features.shape[1]),
        make_params("matern52", lengthscale=1.0, signal=1.0, d=1),
        config_features,
        step_features,
        mask,
        noise,
    )
    mean, samples = lkgp_posterior(gp, y_obs - mu, key, num_samples=num_samples,
                                   max_iters=max_iters)
    mean = mean + mu
    std = jnp.std(samples, axis=-1)
    return CurvePrediction(
        mean=mean, std=std, final_mean=mean[:, -1], final_std=std[:, -1]
    )


def should_stop_early(pred: CurvePrediction, config_idx: int, margin: float = 1.0) -> bool:
    """Prune run i if its predicted final loss is at least `margin`·σ worse than the
    best predicted final loss across the sweep."""
    best = jnp.min(pred.final_mean)
    i = config_idx
    return bool(pred.final_mean[i] - margin * pred.final_std[i] > best)


def divergence_score(pred: CurvePrediction, config_idx: int, step: int,
                     observed_loss: float) -> float:
    """|z|-score of an observed loss under the GP posterior — >3 flags divergence."""
    m = pred.mean[config_idx, step]
    s = jnp.maximum(pred.std[config_idx, step], 1e-6)
    return float(jnp.abs(observed_loss - m) / s)
