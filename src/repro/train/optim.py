"""AdamW on arbitrary pytrees, with memory-tiered state dtypes (DESIGN.md §5).

At 398B params / 256 chips, fp32 (m, v) + fp32 params = 12 B/param → 18.6 GB/chip:
over the 16 GB v5e budget. We keep params bf16 (compute dtype), first moment bf16,
second moment fp32 → 8 B/param → 12.4 GB/chip for jamba-1.5-large. Optimizer states
inherit the parameter shardings (FSDP: states shard with their weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    mu_dtype: Any = jnp.bfloat16
    nu_dtype: Any = jnp.float32


class OptState(NamedTuple):
    mu: Any  # pytree like params
    nu: Any
    step: jax.Array  # () int32


def init_opt_state(params: Any, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    return OptState(
        mu=jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.mu_dtype), params),
        nu=jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.nu_dtype), params),
        step=jnp.zeros((), jnp.int32),
    )


def abstract_opt_state(params_abstract: Any, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    """ShapeDtypeStruct variant for the dry-run."""
    return OptState(
        mu=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, cfg.mu_dtype), params_abstract),
        nu=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, cfg.nu_dtype), params_abstract),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def adamw_update(
    params: Any, grads: Any, state: OptState, cfg: AdamWConfig = AdamWConfig()
) -> tuple[Any, OptState]:
    """One fused AdamW step (runs inside the same jit as backward)."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        delta = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (delta + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(mu=new_m, nu=new_v, step=step)
