"""Training loop: checkpoint/restart, straggler telemetry, elastic hooks.

Fault-tolerance contract (DESIGN.md §5):
  * checkpoints are atomic + step-tagged (train/checkpoint.py); the data pipeline
    is a pure function of (seed, step) so restore-and-resume is bit-exact;
  * `Trainer.run` restores the newest checkpoint automatically — killing the
    process at any point loses at most `ckpt_every` steps (tests simulate this);
  * per-step host timing feeds a straggler detector: hosts slower than
    `straggler_factor` × median over a window are reported; in elastic mode the
    runner is expected to evict them at the next checkpoint boundary and restart
    on a shrunk mesh (checkpoints are mesh-agnostic, keyed by logical axes);
  * the loss history is exposed to train/curve_gp.py (latent-Kronecker GP) for
    sweep pruning and divergence detection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..data.pipeline import token_batch
from ..models import model as model_lib
from .checkpoint import prune_checkpoints, restore_checkpoint, save_checkpoint
from .optim import AdamWConfig, OptState, init_opt_state


@dataclasses.dataclass
class TrainerConfig:
    batch: int = 8
    seq_len: int = 128
    num_steps: int = 100
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_window: int = 20
    straggler_factor: float = 2.0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


@dataclasses.dataclass
class StragglerReport:
    median_s: float
    slow_steps: list  # [(step, seconds)] steps slower than factor × median


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 step_fn: Optional[Callable] = None):
        from ..launch.steps import make_train_step

        self.cfg = cfg
        self.tc = tc
        self.step_fn = jax.jit(step_fn or make_train_step(cfg, tc.opt))
        self.losses: list[float] = []
        self.step_times: list[float] = []

    # -- state -----------------------------------------------------------------
    def init_state(self, dtype=jnp.float32):
        params = model_lib.init_model_params(self.cfg, jax.random.PRNGKey(self.tc.seed),
                                             dtype)
        return params, init_opt_state(params, self.tc.opt)

    def _restore(self, params, opt):
        if not self.tc.ckpt_dir:
            return params, opt, 0
        tree, step, extra = restore_checkpoint(self.tc.ckpt_dir, {"p": params, "o": opt})
        if tree is None:
            return params, opt, 0
        self.losses = list(extra.get("losses", []))
        return tree["p"], tree["o"], step

    # -- loop ------------------------------------------------------------------
    def run(self, dtype=jnp.float32, on_step: Optional[Callable] = None):
        params, opt = self.init_state(dtype)
        params, opt, start = self._restore(params, opt)
        tc = self.tc
        for step in range(start, tc.num_steps):
            batch = token_batch(tc.seed, step, tc.batch, tc.seq_len,
                                self.cfg.vocab_size)
            t0 = time.time()
            params, opt, metrics = self.step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            self.losses.append(loss)
            self.step_times.append(dt)
            if on_step is not None:
                on_step(step, loss)
            if tc.log_every and step % tc.log_every == 0:
                print(f"[train] step {step:5d}  loss {loss:.4f}  {dt*1e3:.0f} ms")
            if tc.ckpt_dir and (step + 1) % tc.ckpt_every == 0:
                save_checkpoint(tc.ckpt_dir, step + 1, {"p": params, "o": opt},
                                extra={"losses": self.losses})
                prune_checkpoints(tc.ckpt_dir, tc.keep_ckpts)
        if tc.ckpt_dir:
            save_checkpoint(tc.ckpt_dir, tc.num_steps, {"p": params, "o": opt},
                            extra={"losses": self.losses})
            prune_checkpoints(tc.ckpt_dir, tc.keep_ckpts)
        return params, opt

    # -- telemetry ---------------------------------------------------------------
    def straggler_report(self) -> StragglerReport:
        w = self.step_times[-self.tc.straggler_window:]
        if not w:
            return StragglerReport(0.0, [])
        med = float(np.median(w))
        off = len(self.step_times) - len(w)
        slow = [(off + i, t) for i, t in enumerate(w)
                if t > self.tc.straggler_factor * max(med, 1e-9)]
        return StragglerReport(med, slow)
