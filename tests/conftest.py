"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py installs the 512-device placeholder platform)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import gram, make_params


@pytest.fixture(scope="session")
def toy_regression():
    """Small GP regression problem with a dense ground-truth solve."""
    key = jax.random.PRNGKey(0)
    n, d = 400, 3
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2.0 * x[:, 0]) + jnp.cos(x[:, 1] + x[:, 2])
    y = y + 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    params = make_params("matern32", lengthscale=0.8, signal=1.0, noise=0.3, d=d)
    kmat = gram(params, x) + params.noise * jnp.eye(n)
    v_star = jnp.linalg.solve(kmat, y)
    xt = jax.random.normal(jax.random.fold_in(key, 2), (64, d))
    return dict(x=x, y=y, params=params, kmat=kmat, v_star=v_star, x_test=xt, n=n, d=d)
