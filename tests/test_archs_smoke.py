"""Per-architecture smoke tests (deliverable f): REDUCED config of each family runs
one forward + one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.launch.steps import make_train_step, input_specs
from repro.models import model as model_lib
from repro.train.optim import AdamWConfig, init_opt_state

ARCHS = list_configs()


def _inputs(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    inputs = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encdec:
        inputs["frames"] = jax.random.normal(jax.random.fold_in(key, 1),
                                             (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        inputs["vision_embeds"] = jax.random.normal(jax.random.fold_in(key, 2),
                                                    (b, cfg.vision_tokens, cfg.d_model))
    return inputs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = model_lib.init_model_params(cfg, key)
    inputs = _inputs(cfg, jax.random.fold_in(key, 7))
    logits = model_lib.forward_train(cfg, params, inputs)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = model_lib.init_model_params(cfg, key)
    opt = init_opt_state(params, AdamWConfig(mu_dtype=jnp.float32))
    step = jax.jit(make_train_step(cfg, AdamWConfig(mu_dtype=jnp.float32)))
    inputs = _inputs(cfg, jax.random.fold_in(key, 3))
    params2, opt2, metrics = step(params, opt, inputs)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(opt2.step) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    from repro.configs.base import SHAPES, cell_is_applicable

    cfg = get_config(arch)
    for shape in SHAPES.values():
        ok, why = cell_is_applicable(cfg, shape)
        if not ok:
            assert shape.name == "long_500k" and not cfg.sub_quadratic
            continue
        specs = input_specs(cfg, shape)
        if shape.mode == "train":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
            assert "labels" in specs
        elif shape.mode == "prefill":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
        else:
            assert specs["token"].shape == (shape.global_batch, 1)
            assert "cache_index" in specs
