"""Trace-time block autotuning (kernels/autotune.py): static resolution,
no-retrace behaviour, table lookup and the VMEM-budget heuristic fallback."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params
from repro.kernels import autotune
from repro.kernels.ops import gram_matvec, gram_mv


# ---------------------------------------------------------------------------
# Key bucketing and the expected-grid contract
# ---------------------------------------------------------------------------


def test_table_key_buckets_nearest_lower():
    assert autotune.table_key("gram", 5000, 3) == "gram|n4096|d2|float32"
    assert autotune.table_key("gram", 1024, 2) == "gram|n1024|d2|float32"
    assert autotune.table_key("rff", 100, 1000, "bfloat16") == "rff|n1024|d128|bfloat16"
    with pytest.raises(ValueError, match="family"):
        autotune.table_key("attention", 1024, 2)
    with pytest.raises(ValueError, match="dtype"):
        autotune.table_key("gram", 1024, 2, "float16")


def test_expected_keys_cover_full_grid():
    keys = autotune.expected_keys()
    assert len(keys) == (
        len(autotune.FAMILIES) * len(autotune.N_GRID)
        * len(autotune.D_GRID) * len(autotune.DTYPES)
    )
    assert "gram|n1024|d2|float32" in keys
    assert "rff|n65536|d128|bfloat16" in keys


# ---------------------------------------------------------------------------
# Heuristic: largest candidate that fits the VMEM budget without out-padding
# ---------------------------------------------------------------------------


def test_heuristic_respects_vmem_budget():
    # narrow RHS: the biggest candidate fits comfortably
    assert autotune.heuristic_block("gram", 65536, 8, s=16) == 512
    # very wide RHS blows the budget for 512 and 256 tiles; 128 fits
    assert autotune.heuristic_block("gram", 65536, 8, s=4096) == 128
    assert (
        autotune.vmem_bytes("gram", 128, 128, 8, s=4096)
        <= autotune.VMEM_BUDGET_BYTES
        < autotune.vmem_bytes("gram", 256, 256, 8, s=4096)
    )


def test_heuristic_never_outpads_small_problems():
    # 300 rows: a 512 tile would pad 40% garbage — refuse it even though it fits
    assert autotune.heuristic_block("gram", 300, 4) <= 256
    assert autotune.heuristic_block("gram", 64, 4) == 128  # floor candidate


def test_bf16_halves_operand_footprint():
    fp32 = autotune.vmem_bytes("gram", 256, 256, 32, s=16, dtype="float32")
    bf16 = autotune.vmem_bytes("gram", 256, 256, 32, s=16, dtype="bfloat16")
    assert bf16 < fp32  # operands shrink; fp32 accumulator/tile stay


# ---------------------------------------------------------------------------
# Table lookup wins over the heuristic; resolve_block is a plain int
# ---------------------------------------------------------------------------


def test_resolve_block_prefers_table_then_heuristic(tmp_path, monkeypatch):
    key = autotune.table_key("gram", 2048, 4)
    path = tmp_path / "table.json"
    path.write_text(json.dumps({"table": {key: 128}}))
    monkeypatch.setenv(autotune.AUTOTUNE_ENV, str(path))
    autotune.load_table.cache_clear()
    try:
        got = autotune.resolve_block("gram", 2048, 4)
        assert got == 128 and type(got) is int
        # a shape outside the table falls back to the heuristic
        fallback = autotune.resolve_block("rff", 2048, 4)
        assert fallback == autotune.heuristic_block("rff", 2048, 4)
        assert type(fallback) is int
    finally:
        autotune.load_table.cache_clear()


def test_missing_table_is_not_an_error(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.AUTOTUNE_ENV, str(tmp_path / "absent.json"))
    autotune.load_table.cache_clear()
    try:
        assert autotune.load_table() == {}
        assert type(autotune.resolve_block("gram", 1024, 2)) is int
    finally:
        autotune.load_table.cache_clear()


# ---------------------------------------------------------------------------
# block="auto" resolves at trace time: correct values, no retraces
# ---------------------------------------------------------------------------


def test_auto_block_matvec_matches_explicit():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (192, 3))
    v = jax.random.normal(jax.random.fold_in(key, 1), (192, 2))
    p = make_params("se", lengthscale=1.0, signal=1.0, d=3)
    auto = gram_matvec(p, x, v, block="auto", interpret=True)
    explicit = gram_matvec(
        p, x, v, block=autotune.resolve_block("gram", 192, 3), interpret=True
    )
    np.testing.assert_allclose(auto, explicit, rtol=1e-6, atol=1e-6)


def test_auto_block_does_not_retrace():
    """The resolved block is a static Python int derived from static shapes, so
    value-only changes reuse the compiled solve — the autotune lookup must
    never smuggle a traced quantity into the pallas_call config."""
    p = make_params("se", lengthscale=1.0, signal=1.0, d=3)
    traces = []

    @jax.jit
    def mv(x, v):
        traces.append(1)
        return gram_mv(p, x, v, backend="pallas", block="auto", interpret=True)

    key = jax.random.PRNGKey(1)
    x1 = jax.random.normal(key, (160, 3))
    v1 = jax.random.normal(jax.random.fold_in(key, 1), (160, 2))
    mv(x1, v1)
    mv(x1 + 1.0, v1 * 2.0)  # same shapes, new values: no retrace
    assert len(traces) == 1, "block='auto' retraced on value-only changes"
    # a different n is a shape change and legitimately retraces (and may
    # resolve a different block — still statically)
    x2 = jnp.concatenate([x1, x1])
    v2 = jnp.concatenate([v1, v1])
    mv(x2, v2)
    assert len(traces) == 2
