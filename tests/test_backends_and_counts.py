"""Matvec economy and backend plumbing.

Proves the PR's perf claims structurally:

* every solver's executed full-Gram-matvec count (via instrumented operators and
  ``jax.debug.callback``) matches ``SolveResult.matvecs`` — CG spends exactly one
  matvec per iteration (the seed paid iters + 2: an A·0 warm-start residual and a
  recomputed finalize residual), AP spends zero;
* ``optimize_mll`` with a Pallas-pinned spec never touches the chunked path;
* rebuilding a same-rank preconditioner reuses the compiled CG solve (the seed
  retraced on every rebuild because the apply closure was a static argument).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params
from repro.core.mll import optimize_mll
from repro.core.precond import WoodburyPrecond, nystrom_preconditioner
from repro.core.solvers.base import Gram, matvec_counts, reset_matvec_counts
from repro.core.solvers.cg import cg_trace_count, solve_cg
from repro.core.solvers.spec import AP, CG, SDD, SGD, Nystrom, solve
from repro.kernels.ops import MATVEC_TRACE_COUNTS, reset_matvec_trace_counts


def _instrumented(t, **kw):
    return Gram(x=t["x"], params=t["params"], instrument=True, **kw)


def _counts_after(fn):
    reset_matvec_counts()
    res = fn()
    jax.block_until_ready(res.solution)
    jax.effects_barrier()
    return res, matvec_counts()


def test_cg_matvecs_one_per_iteration(toy_regression):
    """Cold-started CG: exactly max_iters full matvecs — no A·0 residual, no
    recomputed finalize residual (the seed spent max_iters + 2)."""
    t = toy_regression
    op = _instrumented(t)
    iters = 7
    res, counts = _counts_after(
        lambda: solve_cg(op, t["y"], max_iters=iters, tol=0.0)
    )
    assert int(res.iterations) == iters
    assert counts["mv"] == iters
    assert int(res.matvecs) == counts["mv"]


def test_cg_warm_start_costs_one_extra_matvec(toy_regression):
    t = toy_regression
    op = _instrumented(t)
    iters = 5
    x0 = jnp.ones_like(t["y"])
    res, counts = _counts_after(
        lambda: solve_cg(op, t["y"], x0, max_iters=iters, tol=0.0)
    )
    assert counts["mv"] == iters + 1  # the b − A x₀ residual
    assert int(res.matvecs) == counts["mv"]


def test_ap_solve_spends_zero_full_matvecs(toy_regression):
    """AP maintains its residual incrementally: a cold-started solve touches the
    Gram operator only through row-block matvecs (the seed spent 2 full ones)."""
    t = toy_regression
    op = _instrumented(t)
    res, counts = _counts_after(
        lambda: solve(op, t["y"], AP(num_steps=20, block_size=32),
                      key=jax.random.PRNGKey(0))
    )
    assert counts["mv"] == 0
    assert counts["rows"] == 20  # one fused transposed row matvec per step
    assert int(res.matvecs) == 0
    assert float(res.rel_residual.max()) < 1.0  # tracked residual is real


@pytest.mark.parametrize(
    "spec,rows_per_step",
    [
        (SGD(num_steps=15, batch_size=32, num_features=16), 2),
        (SDD(num_steps=15, batch_size=32), 1),
    ],
    ids=["sgd", "sdd"],
)
def test_stochastic_solvers_spend_one_full_matvec(toy_regression, spec, rows_per_step):
    """SGD/SDD loops touch only row blocks; the single full matvec is the exact
    final-residual check in finalize (their only source of an honest
    ``converged`` flag — not redundant work)."""
    t = toy_regression
    op = _instrumented(t)
    res, counts = _counts_after(
        lambda: solve(op, t["y"], spec, key=jax.random.PRNGKey(1))
    )
    assert counts["mv"] == 1
    assert counts["rows"] == 15 * rows_per_step
    assert int(res.matvecs) == 1


def test_solve_result_matvecs_consistent_across_solvers(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    key = jax.random.PRNGKey(2)
    assert int(solve(op, t["y"], CG(max_iters=9, tol=0.0)).matvecs) == 9
    assert int(solve(op, t["y"], AP(num_steps=5, block_size=16), key=key).matvecs) == 0
    assert int(
        solve(op, t["y"], SGD(num_steps=5, batch_size=16, num_features=8),
              key=key).matvecs
    ) == 1
    assert int(
        solve(op, t["y"], SDD(num_steps=5, batch_size=16), key=key).matvecs
    ) == 1


# ---------------------------------------------------------------------------
# Backend pinning
# ---------------------------------------------------------------------------


def test_spec_pins_backend_on_gram(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    reset_matvec_trace_counts()
    res = solve(op, t["y"], CG(max_iters=30, tol=1e-4, backend="dense"))
    assert MATVEC_TRACE_COUNTS["dense"] > 0
    assert MATVEC_TRACE_COUNTS["chunked"] == 0
    np.testing.assert_allclose(res.solution, t["v_star"], atol=5e-2)
    with pytest.raises(ValueError, match="unknown backend"):
        solve(op, t["y"], CG(backend="cublas"))


def test_backends_produce_same_solution(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"], block=64)
    sols = {}
    for backend in ("chunked", "dense", "pallas"):
        res = solve(op, t["y"], CG(max_iters=100, tol=1e-6, backend=backend))
        sols[backend] = np.asarray(res.solution)
    np.testing.assert_allclose(sols["chunked"], sols["dense"], atol=2e-4)
    np.testing.assert_allclose(sols["chunked"], sols["pallas"], atol=2e-4)


def test_optimize_mll_pallas_never_touches_chunked():
    """The acceptance check: a Pallas-pinned spec drives the *entire* outer MLL
    loop — inner solves, quadratic forms, and their gradients — through the
    fused kernel; the chunked path is never even staged."""
    key = jax.random.PRNGKey(0)
    n, d = 72, 2
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2.0 * x[:, 0]) + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), (n,)
    )
    p0 = make_params("se", lengthscale=1.5, signal=0.8, noise=0.4, d=d)
    reset_matvec_trace_counts()
    st = optimize_mll(
        p0, x, y, jax.random.PRNGKey(1), num_steps=2, lr=0.05, num_probes=2,
        spec=CG(max_iters=25, tol=1e-4, backend="pallas"),
    )
    assert MATVEC_TRACE_COUNTS["chunked"] == 0
    assert MATVEC_TRACE_COUNTS["dense"] == 0
    assert MATVEC_TRACE_COUNTS["pallas"] > 0
    assert st.total_solver_iters > 0
    for leaf in jax.tree.leaves(st.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


# ---------------------------------------------------------------------------
# Compiled-solve cache: preconditioner rebuilds must not retrace
# ---------------------------------------------------------------------------


def test_precond_rebuild_hits_compiled_solve_cache(toy_regression):
    """A preconditioner is a pytree of arrays, so rebuilding one of the same
    rank (fresh subset, perturbed hyperparameters) reuses the compiled CG —
    the seed's closure-as-static-argument design retraced every time."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    pc1 = nystrom_preconditioner(t["params"], t["x"], jax.random.PRNGKey(0), rank=32)
    assert isinstance(pc1, WoodburyPrecond)
    solve_cg(op, t["y"], max_iters=40, tol=1e-6, precond=pc1)
    before = cg_trace_count()
    # fresh build: different subset, same rank/shapes → same treedef → cache hit
    pc2 = nystrom_preconditioner(t["params"], t["x"], jax.random.PRNGKey(9), rank=32)
    res = solve_cg(op, t["y"], max_iters=40, tol=1e-6, precond=pc2)
    assert cg_trace_count() == before, "same-rank precond rebuild retraced CG"
    np.testing.assert_allclose(res.solution, t["v_star"], atol=5e-3)
    # a different rank changes shapes and may legitimately retrace
    pc3 = nystrom_preconditioner(t["params"], t["x"], jax.random.PRNGKey(1), rank=16)
    solve_cg(op, t["y"], max_iters=40, tol=1e-6, precond=pc3)


def test_precond_spec_resolve_does_not_retrace(toy_regression):
    """End to end through solve(): repeated solves with a spec-built
    preconditioner (rebuilt fresh each call) reuse the compiled solve."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    spec = CG(max_iters=40, tol=1e-6, precond=Nystrom(rank=24))
    solve(op, t["y"], spec, key=jax.random.PRNGKey(0))
    before = cg_trace_count()
    for seed in range(1, 4):
        res = solve(op, t["y"], spec, key=jax.random.PRNGKey(seed))
    assert cg_trace_count() == before
    np.testing.assert_allclose(res.solution, t["v_star"], atol=5e-3)
