"""Ring-overlapped sharded matvecs (core/operators.py ShardedGram comm="ring").

Four-device subprocess tests (forced CPU host platform, so the mesh doesn't
leak into the main test process): ring-vs-gather parity on every primitive,
zero ``all_gather`` in the ring jaxpr, solver matvec accounting unchanged
across comm strategies, and the trace-counter proof that distributed SGD runs
the fused feature pair step without materialising the (n, 2q) feature matrix.
Validation of the comm flag surface runs in-process on a 1-device mesh.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest


def _run_on_devices(code: str, devices: int = 4) -> None:
    header = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"\n'
        'os.environ["JAX_PLATFORMS"] = "cpu"\n'
    )
    r = subprocess.run(
        [sys.executable, "-c", header + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "OK" in r.stdout


def test_ring_parity_and_zero_all_gather():
    """comm="ring" matches comm="gather" on every primitive (≤1e-5) with zero
    ``all_gather`` in the jaxpr — the collective is P-1 ``ppermute`` stages —
    and the ring mv's output stays row-sharded (O(n·s/P) per device)."""
    _run_on_devices("""
        import re
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ShardedGram, make_params
        from repro.core.distributed import shard_training_rows

        mesh = jax.make_mesh((4,), ("data",))
        n, d, s = 128, 3, 2
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
        p = make_params("se", lengthscale=0.9, noise=0.3, d=d)
        xs = shard_training_rows(mesh, x)
        op_g = ShardedGram(x=xs, params=p, mesh=mesh)
        op_r = ShardedGram(x=xs, params=p, mesh=mesh, comm="ring")

        # mv parity (the acceptance bound) and sharded output
        mg, mr = op_g.mv(v), op_r.mv(v)
        np.testing.assert_allclose(np.asarray(mr), np.asarray(mg),
                                   atol=1e-5, rtol=1e-5)
        assert not mr.sharding.is_fully_replicated, mr.sharding
        assert mg.sharding.is_fully_replicated, mg.sharding

        # row primitives and the principal block
        idx = jax.random.randint(jax.random.fold_in(key, 2), (16,), 0, n)
        u = jax.random.normal(jax.random.fold_in(key, 3), (16, s))
        np.testing.assert_allclose(np.asarray(op_r.rows_mv(idx, v)),
                                   np.asarray(op_g.rows_mv(idx, v)),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(op_r.rows_t_mv(idx, u)),
                                   np.asarray(op_g.rows_t_mv(idx, u)),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(op_r.block_at(idx)),
                                   np.asarray(op_g.block_at(idx)),
                                   atol=1e-5, rtol=1e-5)

        # the collective schedule: zero all_gather anywhere on the ring path,
        # P-1 ppermute stages (each rotating the (x_peer, v_peer) pair)
        for fn, a in ((lambda w: op_r.mv(w), (v,)),
                      (lambda i, w: op_r.rows_mv(i, w), (idx, v)),
                      (lambda i, w: op_r.rows_t_mv(i, w), (idx, u)),
                      (lambda i: op_r.block_at(i), (idx,))):
            txt = str(jax.make_jaxpr(fn)(*a))
            assert not re.findall(r"\\ball_gather\\b", txt), txt[:2000]
        mv_txt = str(jax.make_jaxpr(lambda w: op_r.mv(w))(v))
        assert len(re.findall(r"\\bppermute\\b", mv_txt)) == 2 * (4 - 1)  # x+v pairs
        # the gather path, by contrast, stages its all_gather
        g_txt = str(jax.make_jaxpr(lambda w: op_g.mv(w))(v))
        assert re.findall(r"\\ball_gather\\b", g_txt)
        print("OK")
    """)


def test_ring_solver_counts_and_solutions():
    """Matvec accounting is comm-invariant: cold CG = exactly its iteration
    count on both paths (equal at a fixed budget), SGD = 1 (the finalize
    residual), AP = 0 — and the ring solves match the dense reference. CG
    iterates stay row-sharded through the while_loop."""
    _run_on_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import make_params, CG, SGD, AP
        from repro.core.distributed import distributed_solve, shard_training_rows
        from repro.core.kernels_fn import gram

        mesh = jax.make_mesh((4,), ("data",))
        n, d = 128, 3
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        y = jnp.sin(x.sum(-1))
        p = make_params("se", lengthscale=1.0, noise=0.2, d=d)
        xs = shard_training_rows(mesh, x)
        dense = gram(p, x) + p.noise * jnp.eye(n)
        ref = jnp.linalg.solve(dense, y)

        # CG at a fixed iteration budget pinned below the convergence/breakdown
        # region (so the count is budget-determined, not fp-ordering-determined):
        # identical exact counts on both comm paths
        cg18 = CG(max_iters=18, tol=1e-12)
        res_r18 = distributed_solve(p, xs, y, mesh, cg18, comm="ring")
        res_g18 = distributed_solve(p, xs, y, mesh, cg18, comm="gather")
        assert int(res_r18.matvecs) == int(res_r18.iterations), (
            int(res_r18.matvecs), int(res_r18.iterations))
        assert int(res_r18.matvecs) == int(res_g18.matvecs) == 18

        # converged CG: the ring path lands on the dense reference (iteration
        # counts at a tolerance boundary may differ by the fp ordering of the
        # psum'd inner products; cold-start accounting holds on both paths)
        cg = CG(max_iters=300, tol=1e-8)
        res_r = distributed_solve(p, xs, y, mesh, cg, comm="ring")
        res_g = distributed_solve(p, xs, y, mesh, cg, comm="gather")
        for res in (res_r, res_g):
            assert int(res.matvecs) == int(res.iterations), (
                int(res.matvecs), int(res.iterations))
        np.testing.assert_allclose(np.asarray(res_r.solution), np.asarray(ref),
                                   atol=1e-3)
        assert not res_r.solution.sharding.is_fully_replicated, (
            res_r.solution.sharding)

        # SGD: one full matvec total (the exact finalize residual), ring == gather
        sgd = SGD(num_steps=2000, batch_size=32, step_size_times_n=0.5,
                  num_features=64)
        res_rs = distributed_solve(p, xs, y, mesh, sgd, comm="ring", key=key)
        res_gs = distributed_solve(p, xs, y, mesh, sgd, comm="gather", key=key)
        assert int(res_rs.matvecs) == int(res_gs.matvecs) == 1
        pred_err = float(jnp.max(jnp.abs(dense @ (
            jnp.asarray(res_rs.solution) - ref))))
        assert pred_err < 0.2, pred_err

        # AP: exact block sub-solves, zero full matvecs cold-started
        ap = AP(num_steps=150, block_size=32)
        res_ra = distributed_solve(p, xs, y, mesh, ap, comm="ring", key=key)
        assert int(res_ra.matvecs) == 0
        np.testing.assert_allclose(np.asarray(res_ra.solution), np.asarray(ref),
                                   atol=2e-2)
        print("OK")
    """)


def test_distributed_sgd_fused_no_feature_materialisation():
    """The ROADMAP 2a closure: distributed SGD's regulariser runs the fused
    feature pair step through ShardedFourierFeatures — FEATURE_TRACE_COUNTS
    proves the (n, 2q) feature matrix is never materialised (features == 0),
    on the gather AND ring comm paths — and the sharded feature primitives
    match their materialised single-host references."""
    _run_on_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ShardedGram, solve, SGD, make_params
        from repro.core.distributed import shard_training_rows
        from repro.core.operators import supports
        from repro.core.rff import FourierFeatures, ShardedFourierFeatures
        from repro.kernels.ops import FEATURE_TRACE_COUNTS, reset_feature_trace_counts

        mesh = jax.make_mesh((4,), ("data",))
        n, d, s, m = 128, 3, 2, 16
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        xs = shard_training_rows(mesh, x)
        p = make_params("se", lengthscale=0.9, noise=0.3, d=d)

        # sharded feature primitives vs the materialised reference
        ff = FourierFeatures(omega=jax.random.normal(jax.random.fold_in(key, 1),
                                                     (m, d)),
                             phase=jnp.zeros((m,)), signal=p.signal,
                             backend="pallas")
        op = ShardedGram(x=xs, params=p, mesh=mesh, comm="ring", backend="pallas")
        assert supports(op, "wrap_features")
        sff = op.wrap_features(ff)
        assert isinstance(sff, ShardedFourierFeatures)
        assert sff.num_features == ff.num_features
        assert not supports(sff, "features")  # materialisation: deliberately absent
        w = jax.random.normal(jax.random.fold_in(key, 2), (2 * m, s))
        u = jax.random.normal(jax.random.fold_in(key, 3), (n, s))
        feats = ff.features(x)
        np.testing.assert_allclose(np.asarray(sff.phi_mv(xs, w)),
                                   np.asarray(feats @ w), atol=1e-5)
        np.testing.assert_allclose(np.asarray(sff.phi_t_mv(xs, u)),
                                   np.asarray(feats.T @ u), atol=1e-5)
        np.testing.assert_allclose(np.asarray(sff.phi_pair_mv(xs, u)),
                                   np.asarray(feats @ (feats.T @ u)), atol=1e-5)

        # trace counters: distributed SGD stages ONLY fused feature kernels —
        # phi_t_mv + phi_mv per scan trace, zero materialised-feature dispatches
        sgd = SGD(num_steps=60, batch_size=32, num_features=16)
        for comm in ("ring", "gather"):
            reset_feature_trace_counts()
            op_c = ShardedGram(x=xs, params=p, mesh=mesh, comm=comm,
                               backend="pallas")
            y = jnp.sin(x.sum(-1))
            solve(op_c, y, sgd, key=key)
            assert FEATURE_TRACE_COUNTS["features"] == 0, dict(FEATURE_TRACE_COUNTS)
            assert FEATURE_TRACE_COUNTS["pallas"] > 0, dict(FEATURE_TRACE_COUNTS)
        print("OK")
    """)


def test_comm_flag_validation():
    """The flag surface needs no multi-device mesh: unknown names and the
    gather_once/ring conflict raise up front, auto resolves against the
    byte budget (and to gather under gather_once or a 1-device mesh)."""
    from repro.core import ShardedGram, make_params
    from repro.core.distributed import distributed_solve

    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 3))
    p = make_params("se", lengthscale=1.0, noise=0.2, d=3)

    with pytest.raises(ValueError, match="comm strategy"):
        ShardedGram(x=x, params=p, mesh=mesh, comm="bogus")
    with pytest.raises(ValueError, match="gather_once"):
        ShardedGram(x=x, params=p, mesh=mesh, comm="ring", gather_once=True)
    with pytest.raises(ValueError, match="comm strategy"):
        distributed_solve(p, x, jnp.zeros(16), mesh, "cg", comm="bogus")
    with pytest.raises(ValueError, match="gather_once"):
        distributed_solve(p, x, jnp.zeros(16), mesh, "cg", comm="ring",
                          gather_once=True)

    # auto: panel over budget → ring; under → gather; gather_once wins;
    # a 1-device mesh has no ring to run
    op = ShardedGram(x=x, params=p, mesh=mesh, comm="auto")
    assert op._resolve_comm() == "gather"  # 1-device mesh
    big = ShardedGram(x=x, params=p, mesh=mesh, comm="auto", comm_budget_bytes=8)
    assert big._resolve_comm() == "gather"  # still 1-device
    once = ShardedGram(x=x, params=p, mesh=mesh, comm="auto", gather_once=True,
                       comm_budget_bytes=8)
    assert once._resolve_comm() == "gather"
    # explicit comm pins regardless of budget
    pinned = ShardedGram(x=x, params=p, mesh=mesh, comm="gather",
                         comm_budget_bytes=0)
    assert pinned._resolve_comm() == "gather"


def test_auto_resolves_ring_on_multi_device():
    _run_on_devices("""
        import jax
        from repro.core import ShardedGram, make_params
        from repro.core.distributed import shard_training_rows

        mesh = jax.make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 3))
        p = make_params("se", lengthscale=1.0, noise=0.2, d=3)
        xs = shard_training_rows(mesh, x)
        small = ShardedGram(x=xs, params=p, mesh=mesh, comm="auto")
        assert small._resolve_comm() == "gather"  # 1.5 KiB panel, default budget
        big = ShardedGram(x=xs, params=p, mesh=mesh, comm="auto",
                          comm_budget_bytes=8)
        assert big._resolve_comm() == "ring"
        print("OK")
    """)
