"""FeatureOperator protocol (core/operators.py, core/rff.py) and the fused RFF
kernel family (kernels/rff_matvec.py):

* fused-vs-reference parity for the transposed kernel, and **gradient** parity
  (``jax.grad`` through ``rff_matvec``/``rff_t_matvec`` vs materialised
  features, interpret mode) — the PR's acceptance criterion (≤1e-4);
* capability dispatch: paired-only fused path, ``features`` capability errors,
  backend-name coercion;
* pytree no-retrace for ``PriorSamples``/``FourierFeatures`` (mirrors
  test_operators.py);
* the SGD regulariser never materialises a feature matrix on the pallas
  backend (``FEATURE_TRACE_COUNTS`` — the instrumented-counter idiom);
* ``RFFGram``: the feature surrogate as a LinearOperator (mv/diag vs dense,
  exact feature-space preconditioning, capability refusals);
* ``Jacobi``: diagonal preconditioning from the protocol's required
  ``diag_part`` on operators with no ``precond_factor`` capability.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params
from repro.core.kronecker import make_lkgp
from repro.core.operators import (
    FeatureOperator,
    Gram,
    LatentKroneckerOp,
    OPTIONAL_FEATURE_CAPABILITIES,
    RFFGram,
    capabilities,
    feature_capabilities,
    require_capabilities,
)
from repro.core.precond import JacobiPrecond, jacobi_preconditioner
from repro.core.rff import FourierFeatures, make_fourier_features, sample_prior
from repro.core.solvers.cg import cg_trace_count
from repro.core.solvers.spec import CG, Jacobi, RFF, SGD, solve
from repro.kernels.ops import (
    FEATURE_TRACE_COUNTS,
    reset_feature_trace_counts,
    resolve_feature_backend,
    rff_matvec,
    rff_mv,
    rff_t_matvec,
    rff_t_mv,
)
from repro.kernels.ref import rff_matvec_ref, rff_t_matvec_ref

KEY = jax.random.PRNGKey(21)


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-30)


def _problem(n=130, m=90, s=3, d=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (m, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (2 * m, s))
    u = jax.random.normal(jax.random.fold_in(key, 3), (n, s))
    return x, omega, w, u


# ---------------------------------------------------------------------------
# Transposed kernel parity + gradient parity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,f,s", [(64, 64, 1), (100, 90, 2), (256, 512, 4)])
def test_rff_t_matvec_shapes(n, f, s):
    """Φᵀu fused vs reference, sweeping shapes incl. padding at block=64."""
    key = jax.random.PRNGKey(n + f)
    x = jax.random.normal(key, (n, 3))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (f, 3))
    u = jax.random.normal(jax.random.fold_in(key, 2), (n, s))
    out = rff_t_matvec(x, omega, u, signal=1.3, block=64, interpret=True)
    ref = rff_t_matvec_ref(x, omega, u, signal=1.3)
    assert out.shape == (2 * f, s)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_rff_matvec_grad_parity_vs_materialised():
    """∂/∂{x, ω, w, σ_f²} of uᵀ(Φw) — fused custom-VJP (interpret mode) vs
    autodiff through materialised features: ≤1e-4 relative error everywhere."""
    x, omega, w, u = _problem()
    sig = 1.3

    def fused(x, omega, w, sig):
        return jnp.sum(u * rff_matvec(x, omega, w, signal=sig, block=64,
                                      interpret=True))

    def ref(x, omega, w, sig):
        return jnp.sum(u * rff_matvec_ref(x, omega, w, signal=sig))

    gf = jax.grad(fused, argnums=(0, 1, 2, 3))(x, omega, w, sig)
    gr = jax.grad(ref, argnums=(0, 1, 2, 3))(x, omega, w, sig)
    for name, a, b in zip(("x", "omega", "w", "signal"), gf, gr):
        assert _rel_err(a, b) < 1e-4, name


def test_rff_t_matvec_grad_parity_vs_materialised():
    """∂/∂{x, ω, u, σ_f²} of ⟨ḡ, Φᵀu⟩ through the fused transposed kernel."""
    x, omega, w, u = _problem()
    gbar = jax.random.normal(KEY, w.shape)
    sig = 0.8

    def fused(x, omega, u, sig):
        return jnp.sum(gbar * rff_t_matvec(x, omega, u, signal=sig, block=64,
                                           interpret=True))

    def ref(x, omega, u, sig):
        return jnp.sum(gbar * rff_t_matvec_ref(x, omega, u, signal=sig))

    gf = jax.grad(fused, argnums=(0, 1, 2, 3))(x, omega, u, sig)
    gr = jax.grad(ref, argnums=(0, 1, 2, 3))(x, omega, u, sig)
    for name, a, b in zip(("x", "omega", "u", "signal"), gf, gr):
        assert _rel_err(a, b) < 1e-4, name


def test_prior_sample_fused_grad_matches_features():
    """The acceptance check at the API level: jax.grad through a fused
    (backend='pallas', interpret-mode) RFF prior evaluation matches the
    materialised-features gradient — Thompson ascent differentiates through
    the fused prior safely."""
    p = make_params("matern32", lengthscale=0.8, signal=1.4, d=3)
    prior = sample_prior(p, jax.random.PRNGKey(0), 5, 96, 3)
    xs = jax.random.normal(jax.random.PRNGKey(1), (37, 3))

    g_fused = jax.grad(
        lambda xs: jnp.sum(jnp.sin(prior.with_backend("pallas")(xs)))
    )(xs)
    g_feat = jax.grad(
        lambda xs: jnp.sum(jnp.sin(prior.with_backend("features")(xs)))
    )(xs)
    assert _rel_err(g_fused, g_feat) < 1e-4


def test_phi_t_mv_backends_agree_and_differentiate():
    """FourierFeatures.phi_t_mv: pallas vs features parity, incl. gradients
    w.r.t. the operand — the SGD regulariser pullback."""
    p = make_params("se", lengthscale=1.1, signal=0.9, d=3)
    ff = make_fourier_features(p, KEY, 128, 3)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (75, 3))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (75, 2))
    out_p = ff.phi_t_mv(x, v, backend="pallas")
    out_f = ff.phi_t_mv(x, v, backend="features")
    np.testing.assert_allclose(out_p, out_f, rtol=1e-4, atol=1e-4)

    def reg(v, backend):  # σ²Φ(Φᵀv) — one SGD regulariser term
        return jnp.sum(ff.phi_mv(x, ff.phi_t_mv(x, v, backend=backend),
                                 backend=backend) ** 2)

    gp = jax.grad(reg)(v, "pallas")
    gf = jax.grad(reg)(v, "features")
    assert _rel_err(gp, gf) < 1e-4


# ---------------------------------------------------------------------------
# Capability dispatch + backend resolution
# ---------------------------------------------------------------------------


def test_feature_backend_resolution():
    assert resolve_feature_backend("auto") in ("pallas", "features")
    # Gram backend names coerce so one spec backend field pins both sides
    assert resolve_feature_backend("chunked") == "features"
    assert resolve_feature_backend("dense") == "features"
    assert resolve_feature_backend("fused") == "pallas"  # legacy alias
    assert resolve_feature_backend("auto", paired=False) == "features"
    with pytest.raises(ValueError, match="paired"):
        resolve_feature_backend("pallas", paired=False)
    with pytest.raises(ValueError, match="unknown feature backend"):
        resolve_feature_backend("cuda")


def test_unpaired_features_refuse_fused():
    p = make_params("se", lengthscale=1.0, d=2)
    ff = make_fourier_features(p, KEY, 32, 2, paired=False)
    x = jnp.ones((8, 2))
    w = jnp.ones((ff.num_features, 1))
    with pytest.raises(ValueError, match="paired"):
        ff.phi_mv(x, w, backend="pallas")
    # auto silently falls back to the materialised cos-only features
    np.testing.assert_allclose(ff.phi_mv(x, w), ff.features(x) @ w, rtol=1e-6)


def test_feature_capability_dispatch():
    p = make_params("se", lengthscale=1.0, d=2)
    ff = make_fourier_features(p, KEY, 32, 2)
    assert feature_capabilities(ff) == OPTIONAL_FEATURE_CAPABILITIES
    assert ff.shape == (None, 32)

    class BareFeatures(FeatureOperator):  # phi-matvecs only, no materialisation
        num_features = 16

        def phi_mv(self, x, w):
            return x @ w[: x.shape[1]]

        def phi_t_mv(self, x, u):
            return x.T @ u

    bare = BareFeatures()
    assert feature_capabilities(bare) == ()
    with pytest.raises(TypeError, match="features"):
        require_capabilities(bare, ("features",), consumer="the 'rff' precond")
    with pytest.raises(NotImplementedError, match="phi_mv"):
        FeatureOperator.phi_mv(bare, None, None)


# ---------------------------------------------------------------------------
# Pytree round-trips: same treedef ⇒ compiled consumers are reused
# ---------------------------------------------------------------------------


def test_prior_samples_pytree_roundtrip_and_no_retrace():
    """Mirrors test_operators.py: fresh draws with the same shapes share a
    treedef, so jitted evaluation (the Thompson inner loop) traces once."""
    d = 3
    p1 = make_params("matern32", lengthscale=0.8, signal=1.0, noise=0.3, d=d)
    p2 = make_params("matern32", lengthscale=1.3, signal=0.7, noise=0.1, d=d)
    prior1 = sample_prior(p1, jax.random.PRNGKey(0), 4, 64, d)
    prior2 = sample_prior(p2, jax.random.PRNGKey(9), 4, 64, d)

    leaves, treedef = jax.tree_util.tree_flatten(prior1)
    again = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(again) is type(prior1)
    assert jax.tree_util.tree_structure(again) == treedef
    assert jax.tree_util.tree_structure(prior2) == treedef

    traces = []

    @jax.jit
    def evaluate(prior, xs):
        traces.append(1)
        return prior(xs)

    xs = jnp.ones((8, d))
    evaluate(prior1, xs)
    evaluate(prior2, xs)  # same treedef+shapes, different values: no retrace
    assert len(traces) == 1, "PriorSamples retraced across fresh draws"
    # a different backend is a *static* change and legitimately retraces
    evaluate(prior1.with_backend("features"), xs)
    assert len(traces) == 2


# ---------------------------------------------------------------------------
# SGD regulariser: fused end to end, no materialised feature matrix
# ---------------------------------------------------------------------------


def test_sgd_regulariser_never_materialises_features_on_pallas(toy_regression):
    """The acceptance check: an SGD solve with backend='pallas' stages every
    feature matvec through the fused kernel — the 'features' (materialising)
    path is never dispatched, so no (n, 2q) feature matrix is ever allocated."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    reset_feature_trace_counts()
    solve(op, t["y"], SGD(num_steps=3, batch_size=32, num_features=16,
                          backend="pallas"), key=KEY)
    assert FEATURE_TRACE_COUNTS["features"] == 0
    assert FEATURE_TRACE_COUNTS["pallas"] > 0  # Φᵀ(v−δ) and Φ(·) per step


def test_sgd_regulariser_backend_follows_operator(toy_regression):
    """Default backend on CPU resolves to materialised features (pallas
    interpret mode is slower than XLA here) — and the two backends agree."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    reset_feature_trace_counts()
    res_auto = solve(op, t["y"], SGD(num_steps=200, batch_size=64,
                                     num_features=32), key=KEY)
    assert FEATURE_TRACE_COUNTS["features"] > 0
    res_pallas = solve(op, t["y"], SGD(num_steps=200, batch_size=64,
                                       num_features=32, backend="pallas"),
                       key=KEY)
    np.testing.assert_allclose(res_auto.solution, res_pallas.solution,
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# RFFGram: the feature surrogate as a LinearOperator
# ---------------------------------------------------------------------------


def _rff_gram(n=150, m=256, d=3, seed=4):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    p = make_params("matern32", lengthscale=0.9, signal=1.1, noise=0.25, d=d)
    ff = make_fourier_features(p, jax.random.fold_in(key, 1), m, d)
    return RFFGram(x=x, ff=ff, sigma2=p.noise), x, p


def test_rff_gram_matches_dense():
    op, x, p = _rff_gram()
    dense = op.dense()
    assert op.shape == (150, 150)
    v = jax.random.normal(KEY, (150, 3))
    np.testing.assert_allclose(op.mv(v), dense @ v, atol=1e-4)
    np.testing.assert_allclose(op.diag_part(), jnp.diag(dense), atol=1e-4)
    # the surrogate really approximates K: diag(ΦΦᵀ) = σ_f² exactly (paired)
    np.testing.assert_allclose(op.diag_part(), p.signal + p.noise, atol=1e-5)


def test_rff_gram_solve_and_exact_feature_precond():
    """solve() drives RFFGram like any operator, and its precond_factor is the
    operator's own Φ — Woodbury becomes an exact inverse, so preconditioned CG
    converges in O(1) iterations."""
    op, x, p = _rff_gram()
    y = jnp.sin(x.sum(axis=1))
    dense = op.dense()
    ref = jnp.linalg.solve(dense, y)
    plain = solve(op, y, CG(max_iters=300, tol=1e-8))
    np.testing.assert_allclose(plain.solution, ref, atol=1e-3)
    pre = solve(op, y, CG(max_iters=300, tol=1e-8, precond=RFF()), key=KEY)
    np.testing.assert_allclose(pre.solution, ref, atol=1e-3)
    assert int(pre.iterations) <= 3 < int(plain.iterations)


def test_rff_gram_refuses_row_specs():
    op, x, _ = _rff_gram()
    assert capabilities(op) == ("precond_factor",)
    with pytest.raises(TypeError, match="rows_mv"):
        solve(op, jnp.ones(op.shape[0]), SGD(num_steps=5), key=KEY)


def test_rff_gram_refuses_foreign_factor_methods():
    """A Nyström/pivoted-Cholesky spec on RFFGram would silently get the full
    feature matrix instead of the requested low-rank factor — it raises and
    points at the specs that do apply."""
    from repro.core.solvers.spec import Nystrom

    op, x, _ = _rff_gram()
    with pytest.raises(ValueError, match="nystrom"):
        solve(op, jnp.ones(op.shape[0]), CG(precond=Nystrom(rank=16)), key=KEY)
    # the matching spec and the capability-free fallback both work
    solve(op, jnp.ones(op.shape[0]), CG(max_iters=5, precond=RFF()), key=KEY)
    solve(op, jnp.ones(op.shape[0]), CG(max_iters=5, precond=Jacobi()))


def test_rff_precond_spec_on_gram(toy_regression):
    """The feature-space preconditioner on a *real* Gram operator: ΦΦᵀ ≈ K cuts
    CG iterations vs unpreconditioned at the same tolerance."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    base = solve(op, t["y"], CG(max_iters=400, tol=1e-6))
    pre = solve(op, t["y"], CG(max_iters=400, tol=1e-6, precond=RFF(rank=256)),
                key=KEY)
    np.testing.assert_allclose(pre.solution, t["v_star"], atol=5e-3)
    assert int(pre.iterations) < int(base.iterations)
    with pytest.raises(ValueError, match="even"):
        solve(op, t["y"], CG(precond=RFF(rank=33)), key=KEY)


# ---------------------------------------------------------------------------
# Jacobi: diagonal preconditioning from the protocol's required diag_part
# ---------------------------------------------------------------------------


def test_jacobi_precond_on_gram(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    pc = jacobi_preconditioner(op)
    assert isinstance(pc, JacobiPrecond)
    r = jax.random.normal(KEY, (t["n"], 2))
    np.testing.assert_allclose(pc(pc.mv(r)), r, atol=1e-5)  # M⁻¹M = I
    np.testing.assert_allclose(pc.diag_part(), op.diag_part(), atol=1e-6)
    res = solve(op, t["y"], CG(max_iters=300, tol=1e-6, precond=Jacobi()))
    np.testing.assert_allclose(res.solution, t["v_star"], atol=5e-3)


def test_jacobi_precond_on_matvec_only_operator():
    """The point of the satellite: LatentKroneckerOp has no precond_factor
    capability (Nystrom raises), but Jacobi builds from the required
    diag_part — preconditioned CG matches the dense solve."""
    rng = np.random.default_rng(0)
    g1 = jnp.asarray(rng.normal(size=(11, 3)).astype(np.float32))
    g2 = jnp.asarray(rng.normal(size=(8, 1)).astype(np.float32))
    mask = jnp.asarray(rng.random((11, 8)) < 0.7)
    p1 = make_params("matern52", lengthscale=1.0, d=3)
    p2 = make_params("matern52", lengthscale=1.0, d=1)
    op = LatentKroneckerOp(gp=make_lkgp(p1, p2, g1, g2, mask, 0.05))
    n = op.shape[0]
    kfull = np.kron(np.asarray(op.gp.k1()), np.asarray(op.gp.k2()))
    idx = np.asarray(op.gp.obs_idx)
    dense = jnp.asarray(kfull[np.ix_(idx, idx)] + 0.05 * np.eye(n))
    b = jax.random.normal(KEY, (n,))
    from repro.core.solvers.spec import Nystrom

    with pytest.raises(TypeError, match="precond_factor"):
        solve(op, b, CG(precond=Nystrom(rank=8)), key=KEY)
    res = solve(op, b, CG(max_iters=300, tol=1e-8, precond=Jacobi()))
    np.testing.assert_allclose(res.solution, jnp.linalg.solve(dense, b),
                               rtol=1e-3, atol=1e-3)


def test_jacobi_rebuild_hits_compiled_solve_cache(toy_regression):
    """JacobiPrecond is a one-leaf pytree: per-solve rebuilds for new
    hyperparameters reuse the compiled CG (same guarantee as Woodbury)."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    spec = CG(max_iters=40, tol=1e-6, precond=Jacobi())
    solve(op, t["y"], spec)
    before = cg_trace_count()
    p2 = make_params("matern32", lengthscale=0.9, signal=1.1, noise=0.2,
                     d=t["d"])
    solve(Gram(x=t["x"], params=p2), t["y"], spec)
    assert cg_trace_count() == before, "Jacobi rebuild retraced CG"
