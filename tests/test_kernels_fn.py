"""Covariance-function unit + property tests (§2.1.3, §2.2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.kernels_fn import (
    SE, MATERN12, MATERN32, MATERN52, TANIMOTO,
    gram, make_params, matvec, spectral_sample,
)
from repro.core.rff import make_fourier_features, sample_prior

KINDS = [SE, MATERN12, MATERN32, MATERN52]


@pytest.mark.parametrize("kind", KINDS)
def test_gram_symmetric_psd(kind):
    x = jax.random.normal(jax.random.PRNGKey(0), (40, 3))
    p = make_params(kind, lengthscale=0.9, signal=1.3, d=3)
    k = gram(p, x)
    np.testing.assert_allclose(k, k.T, rtol=1e-5)
    evals = np.linalg.eigvalsh(np.asarray(k, np.float64))
    assert evals.min() > -1e-4
    # diag ≈ signal variance (distance-as-matmul gives d²≈1e-6 wobble on the diag,
    # which the non-smooth matern12 amplifies to ~1e-3 relative)
    np.testing.assert_allclose(np.diag(k), 1.3**2, rtol=3e-3)


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    ls=st.floats(0.3, 3.0),
    shift=st.floats(-5.0, 5.0),
)
def test_stationarity_property(kind, ls, shift):
    """k(x, x') depends only on x − x' for stationary kernels."""
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 2))
    z = jax.random.normal(jax.random.PRNGKey(2), (8, 2))
    p = make_params(kind, lengthscale=ls, d=2)
    k1 = gram(p, x, z)
    k2 = gram(p, x + shift, z + shift)
    np.testing.assert_allclose(k1, k2, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 5))
def test_matvec_matches_dense(n, s):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, 2))
    v = jax.random.normal(jax.random.PRNGKey(s), (n, s))
    p = make_params(SE, lengthscale=1.1, d=2, noise=0.4)
    dense = (gram(p, x) + p.noise * jnp.eye(n)) @ v
    chunked = matvec(p, x, v, row_chunk=16, jitter=p.noise)
    np.testing.assert_allclose(chunked, dense, rtol=2e-4, atol=2e-4)


def test_tanimoto_bounds_and_identity():
    x = (jax.random.uniform(jax.random.PRNGKey(0), (30, 64)) < 0.2).astype(jnp.float32)
    p = make_params(TANIMOTO, signal=1.0)
    k = gram(p, x)
    assert float(k.min()) >= 0.0 and float(k.max()) <= 1.0 + 1e-6
    nz = np.asarray(x.sum(1) > 0)
    np.testing.assert_allclose(np.diag(k)[nz], 1.0, atol=1e-6)


@pytest.mark.parametrize("kind", KINDS)
def test_rff_approximates_kernel(kind):
    """ΦΦᵀ → K as m grows (§2.2.2) — unbiasedness + variance decay."""
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(3), (20, d))
    p = make_params(kind, lengthscale=1.0, signal=1.0, d=d)
    k_true = gram(p, x)
    ff = make_fourier_features(p, jax.random.PRNGKey(4), 8192, d)
    phi = ff.features(x)
    err = np.abs(np.asarray(phi @ phi.T - k_true)).max()
    assert err < 0.12, err


def test_prior_samples_cov():
    """Prior samples via RFF have covariance ≈ K (Eq. 2.63)."""
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(5), (12, d))
    p = make_params(SE, lengthscale=1.0, signal=1.0, d=d)
    prior = sample_prior(p, jax.random.PRNGKey(6), 4096, 2048, d)
    f = np.asarray(prior(x))  # (12, 4096)
    cov = f @ f.T / f.shape[1]
    np.testing.assert_allclose(cov, gram(p, x), atol=0.15)


def test_spectral_sample_matches_kernel_curvature():
    """E[ωωᵀ] = −∇²k(0)/ℓ² : SE spectral variance = 1/ℓ²."""
    p = make_params(SE, lengthscale=2.0, d=3)
    w = spectral_sample(p, jax.random.PRNGKey(7), 40_000, 3)
    np.testing.assert_allclose(np.var(np.asarray(w), axis=0), 0.25, rtol=0.1)
