"""Per-kernel allclose vs ref.py oracles (interpret mode), sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params
from repro.kernels.ops import flash_attention, gram_matvec, rff_matvec
from repro.kernels.ref import flash_attention_ref, gram_matvec_ref, rff_matvec_ref


@pytest.mark.parametrize("kind", ["se", "matern12", "matern32", "matern52"])
@pytest.mark.parametrize("n,m,s", [(64, 64, 1), (200, 130, 3), (256, 256, 8)])
def test_gram_matvec_kinds_shapes(kind, n, m, s):
    key = jax.random.PRNGKey(n + m + s)
    x = jax.random.normal(key, (n, 4))
    z = jax.random.normal(jax.random.fold_in(key, 1), (m, 4))
    v = jax.random.normal(jax.random.fold_in(key, 2), (m, s))
    p = make_params(kind, lengthscale=0.8, signal=1.4, d=4)
    out = gram_matvec(p, x, v, z=z, block=64, interpret=True)
    ref = gram_matvec_ref(x / p.lengthscale, z / p.lengthscale, v,
                          kind=kind, signal=float(p.signal))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_gram_matvec_jitter_square():
    key = jax.random.PRNGKey(0)
    n, s = 192, 4
    x = jax.random.normal(key, (n, 3))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    p = make_params("se", lengthscale=1.0, signal=1.0, d=3, noise=0.5)
    out = gram_matvec(p, x, v, jitter=float(p.noise), block=64, interpret=True)
    ref = gram_matvec_ref(x, x, v, kind="se", signal=1.0, jitter=float(p.noise))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_gram_matvec_1d_vector_rhs():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (100, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), (100,))
    p = make_params("matern32", lengthscale=1.2, d=2)
    out = gram_matvec(p, x, v, block=64, interpret=True)
    ref = gram_matvec_ref(x / p.lengthscale, x / p.lengthscale, v[:, None],
                          kind="matern32")[:, 0]
    assert out.shape == (100,)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,f,s", [(64, 64, 1), (100, 90, 2), (256, 512, 4)])
def test_rff_matvec_shapes(n, f, s):
    key = jax.random.PRNGKey(n + f)
    x = jax.random.normal(key, (n, 3))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (f, 3))
    w = jax.random.normal(jax.random.fold_in(key, 2), (2 * f, s))
    out = rff_matvec(x, omega, w, signal=1.3, block=64, interpret=True)
    ref = rff_matvec_ref(x, omega, w, signal=1.3)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,s,hq,hkv,d", [(1, 128, 2, 2, 32), (2, 256, 4, 2, 64),
                                          (1, 130, 2, 1, 32)])
def test_flash_attention_vs_ref(causal, b, s, hq, hkv, d):
    key = jax.random.PRNGKey(s + hq)
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    head_map = jnp.arange(hq) // (hq // hkv)
    ref = flash_attention_ref(q, k[:, :, head_map], v[:, :, head_map], causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 128, 2, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 32), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=3e-2, atol=3e-2)


def test_gram_matvec_bf16_inputs():
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (128, 4), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 1), (128, 2), jnp.bfloat16)
    p = make_params("se", lengthscale=1.0, d=4, dtype=jnp.float32)
    out = gram_matvec(p, x.astype(jnp.float32), v.astype(jnp.float32), block=64,
                      interpret=True)
    ref = gram_matvec_ref(x.astype(jnp.float32), x.astype(jnp.float32),
                          v.astype(jnp.float32), kind="se")
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
