"""Per-kernel allclose vs ref.py oracles (interpret mode), sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params
from repro.kernels.ops import flash_attention, gram_matvec, rff_matvec
from repro.kernels.ref import flash_attention_ref, gram_matvec_ref, rff_matvec_ref


@pytest.mark.parametrize("kind", ["se", "matern12", "matern32", "matern52"])
@pytest.mark.parametrize("n,m,s", [(64, 64, 1), (200, 130, 3), (256, 256, 8)])
def test_gram_matvec_kinds_shapes(kind, n, m, s):
    key = jax.random.PRNGKey(n + m + s)
    x = jax.random.normal(key, (n, 4))
    z = jax.random.normal(jax.random.fold_in(key, 1), (m, 4))
    v = jax.random.normal(jax.random.fold_in(key, 2), (m, s))
    p = make_params(kind, lengthscale=0.8, signal=1.4, d=4)
    out = gram_matvec(p, x, v, z=z, block=64, interpret=True)
    ref = gram_matvec_ref(x / p.lengthscale, z / p.lengthscale, v,
                          kind=kind, signal=float(p.signal))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_gram_matvec_jitter_square():
    key = jax.random.PRNGKey(0)
    n, s = 192, 4
    x = jax.random.normal(key, (n, 3))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    p = make_params("se", lengthscale=1.0, signal=1.0, d=3, noise=0.5)
    out = gram_matvec(p, x, v, jitter=float(p.noise), block=64, interpret=True)
    ref = gram_matvec_ref(x, x, v, kind="se", signal=1.0, jitter=float(p.noise))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_gram_matvec_1d_vector_rhs():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (100, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), (100,))
    p = make_params("matern32", lengthscale=1.2, d=2)
    out = gram_matvec(p, x, v, block=64, interpret=True)
    ref = gram_matvec_ref(x / p.lengthscale, x / p.lengthscale, v[:, None],
                          kind="matern32")[:, 0]
    assert out.shape == (100,)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,f,s", [(64, 64, 1), (100, 90, 2), (256, 512, 4)])
def test_rff_matvec_shapes(n, f, s):
    key = jax.random.PRNGKey(n + f)
    x = jax.random.normal(key, (n, 3))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (f, 3))
    w = jax.random.normal(jax.random.fold_in(key, 2), (2 * f, s))
    out = rff_matvec(x, omega, w, signal=1.3, block=64, interpret=True)
    ref = rff_matvec_ref(x, omega, w, signal=1.3)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,s,hq,hkv,d", [(1, 128, 2, 2, 32), (2, 256, 4, 2, 64),
                                          (1, 130, 2, 1, 32)])
def test_flash_attention_vs_ref(causal, b, s, hq, hkv, d):
    key = jax.random.PRNGKey(s + hq)
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    head_map = jnp.arange(hq) // (hq // hkv)
    ref = flash_attention_ref(q, k[:, :, head_map], v[:, :, head_map], causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 128, 2, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 32), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=3e-2, atol=3e-2)


def test_gram_matvec_bf16_inputs():
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (128, 4), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 1), (128, 2), jnp.bfloat16)
    p = make_params("se", lengthscale=1.0, d=4, dtype=jnp.float32)
    out = gram_matvec(p, x.astype(jnp.float32), v.astype(jnp.float32), block=64,
                      interpret=True)
    ref = gram_matvec_ref(x.astype(jnp.float32), x.astype(jnp.float32),
                          v.astype(jnp.float32), kind="se")
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Differentiability: jax.grad through the fused Pallas matvec must match
# autodiff through the dense gram() reference (interpret mode, CPU).
# ---------------------------------------------------------------------------

from repro.core.kernels_fn import gram  # noqa: E402
from repro.kernels.ops import gram_mv, gram_rows_matvec, resolve_backend  # noqa: E402


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-30)


@pytest.mark.parametrize("kind", ["se", "matern12", "matern32", "matern52"])
@pytest.mark.parametrize("n,m", [(96, 96), (96, 130)])
def test_gram_matvec_vjp_matches_dense_autodiff(kind, n, m):
    """∂/∂{log ℓ, log σ_f, x, z, v} of uᵀ(σ_f²K)v: fused custom-VJP vs dense."""
    key = jax.random.PRNGKey(n + m)
    x = jax.random.normal(key, (n, 3))
    z = jax.random.normal(jax.random.fold_in(key, 1), (m, 3))
    v = jax.random.normal(jax.random.fold_in(key, 2), (m, 4))
    u = jax.random.normal(jax.random.fold_in(key, 3), (n, 4))
    p = make_params(kind, lengthscale=0.9, signal=1.3, d=3)

    def fused(p, x, z, v):
        return jnp.sum(u * gram_matvec(p, x, v, z=z, block=64, interpret=True))

    def dense(p, x, z, v):
        return jnp.sum(u * (gram(p, x, z) @ v))

    gf = jax.grad(fused, argnums=(0, 1, 2, 3))(p, x, z, v)
    gd = jax.grad(dense, argnums=(0, 1, 2, 3))(p, x, z, v)
    assert _rel_err(gf[0].log_lengthscale, gd[0].log_lengthscale) < 1e-4
    assert _rel_err(gf[0].log_signal, gd[0].log_signal) < 1e-4
    for a, b in zip(gf[1:], gd[1:]):
        assert _rel_err(a, b) < 1e-4


@pytest.mark.parametrize("kind", ["se", "matern32", "matern52"])
def test_gram_matvec_vjp_symmetric(kind):
    """z=None (K(X,X), duplicate diagonal): fused VJP still matches autodiff."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (100, 3))
    v = jax.random.normal(jax.random.fold_in(key, 1), (100, 2))
    u = jax.random.normal(jax.random.fold_in(key, 2), (100, 2))
    p = make_params(kind, lengthscale=1.1, signal=0.8, d=3)

    def fused(p, x):
        return jnp.sum(u * gram_matvec(p, x, v, block=64, interpret=True))

    def dense(p, x):
        return jnp.sum(u * (gram(p, x) @ v))

    gf = jax.grad(fused, argnums=(0, 1))(p, x)
    gd = jax.grad(dense, argnums=(0, 1))(p, x)
    assert _rel_err(gf[0].log_lengthscale, gd[0].log_lengthscale) < 1e-4
    assert _rel_err(gf[1], gd[1]) < 1e-4


def test_gram_matvec_vjp_matern12_diagonal_is_finite():
    """Matérn-1/2 is non-differentiable at coincident points: plain autodiff
    through sqrt(d²+ε) produces ~1/√ε garbage on the symmetric diagonal, while
    the fused VJP adopts the symmetric-limit convention (zero contribution) and
    stays finite and bounded."""
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (64, 3))
    v = jax.random.normal(jax.random.fold_in(key, 1), (64, 2))
    p = make_params("matern12", lengthscale=1.0, signal=1.0, d=3)
    g = jax.grad(
        lambda x: jnp.sum(gram_matvec(p, x, v, block=64, interpret=True))
    )(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) < 1e3  # bounded, unlike the 1/√ε blow-up


def test_gram_matvec_grad_through_jitter():
    """∂/∂log σ_n of uᵀ(σ_f²K + σ²I)v flows through the jitter term (applied
    outside the custom-VJP core, in plain JAX)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (64, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    p = make_params("se", lengthscale=1.0, noise=0.3, d=2)

    def fused(p):
        return jnp.sum(v * gram_mv(p, x, v, jitter=p.noise, backend="pallas",
                                   block=64, interpret=True))

    def dense(p):
        kmat = gram(p, x) + p.noise * jnp.eye(64)
        return jnp.sum(v * (kmat @ v))

    gf = jax.grad(fused)(p)
    gd = jax.grad(dense)(p)
    np.testing.assert_allclose(gf.log_noise, gd.log_noise, rtol=1e-4)
    np.testing.assert_allclose(gf.log_lengthscale, gd.log_lengthscale, rtol=1e-4)


# ---------------------------------------------------------------------------
# Backend selection + tanimoto fallback
# ---------------------------------------------------------------------------


def test_resolve_backend_auto_and_tanimoto():
    # auto: pallas on TPU only; CPU test container resolves to chunked
    assert resolve_backend("auto", "se") in ("pallas", "chunked")
    assert resolve_backend("auto", "tanimoto") == "chunked"  # silent fallback
    assert resolve_backend("chunked", "tanimoto") == "chunked"
    with pytest.raises(ValueError, match="tanimoto"):
        resolve_backend("pallas", "tanimoto")
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda", "se")


def test_tanimoto_pallas_raises_auto_falls_back():
    key = jax.random.PRNGKey(8)
    x = jnp.abs(jax.random.normal(key, (50, 6)))
    v = jax.random.normal(jax.random.fold_in(key, 1), (50, 2))
    p = make_params("tanimoto", lengthscale=1.0, signal=1.2, d=6)
    with pytest.raises(ValueError, match="tanimoto"):
        gram_mv(p, x, v, backend="pallas", interpret=True)
    out = gram_mv(p, x, v, backend="auto")  # falls back to chunked
    np.testing.assert_allclose(out, gram(p, x) @ v, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["chunked", "dense", "pallas"])
def test_gram_mv_backends_agree(backend):
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (90, 3))
    v = jax.random.normal(jax.random.fold_in(key, 1), (90, 2))
    p = make_params("matern52", lengthscale=0.7, signal=1.1, noise=0.2, d=3)
    out = gram_mv(p, x, v, jitter=p.noise, backend=backend, block=64,
                  interpret=True)
    ref = (gram(p, x) + p.noise * jnp.eye(90)) @ v
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fused row-block matvec (the SGD/SDD/AP primitive)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "chunked"])
def test_gram_rows_matvec_vs_dense_panel(backend):
    key = jax.random.PRNGKey(11)
    n, p_rows, s = 200, 48, 3
    x = jax.random.normal(key, (n, 4))
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    w = jax.random.normal(jax.random.fold_in(key, 2), (p_rows, s))
    idx = jax.random.randint(jax.random.fold_in(key, 3), (p_rows,), 0, n)
    p = make_params("matern32", lengthscale=0.9, signal=1.4, d=4)
    panel = gram(p, x[idx], x)  # (p, n) dense reference
    fwd = gram_rows_matvec(p, x, idx, u, backend=backend, block=64,
                           interpret=True)
    np.testing.assert_allclose(fwd, panel @ u, rtol=2e-4, atol=2e-4)
    bwd = gram_rows_matvec(p, x, idx, w, transpose=True, backend=backend,
                           block=64, interpret=True)
    np.testing.assert_allclose(bwd, panel.T @ w, rtol=2e-4, atol=2e-4)


def test_prior_samples_fused_matches_features():
    """PriorSamples backend='fused' (Pallas RFF matvec, interpret on CPU) agrees
    with the materialised-feature evaluation, including traced σ_f² handling."""
    import dataclasses as dc

    from repro.core.rff import sample_prior

    p = make_params("matern32", lengthscale=0.8, signal=1.4, d=3)
    prior = sample_prior(p, jax.random.PRNGKey(0), 5, 96, 3)
    x = jax.random.normal(jax.random.PRNGKey(1), (130, 3))
    via_features = prior(x)
    via_fused = dc.replace(prior, backend="fused")(x)
    np.testing.assert_allclose(via_features, via_fused, rtol=2e-4, atol=2e-4)


def test_gram_mv_rejects_jitter_on_cross_gram():
    """jitter·I is only meaningful for the symmetric operator; rectangular
    cross-Gram calls must refuse it instead of silently adding jitter·v."""
    key = jax.random.PRNGKey(12)
    x = jax.random.normal(key, (40, 2))
    z = jax.random.normal(jax.random.fold_in(key, 1), (30, 2))
    v = jax.random.normal(jax.random.fold_in(key, 2), (30,))
    p = make_params("se", d=2)
    with pytest.raises(ValueError, match="jitter"):
        gram_mv(p, x, v, z=z, jitter=0.1, backend="chunked")


def test_prior_samples_default_backend_is_differentiable():
    """User-facing posterior samples are differentiated through (Thompson
    gradient ascent). The default is now ``auto`` — safe on every resolution
    because the fused Pallas path carries a full custom VJP: its gradient
    matches the materialised-features gradient."""
    import dataclasses as dc

    from repro.core.rff import sample_prior

    p = make_params("se", lengthscale=1.0, d=2)
    prior = sample_prior(p, jax.random.PRNGKey(0), 3, 64, 2)
    assert prior.backend == "auto"
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 2))
    g_auto = jax.grad(lambda xs: jnp.sum(prior(xs)))(xs)
    g_fused = jax.grad(
        lambda xs: jnp.sum(dc.replace(prior, backend="pallas")(xs))
    )(xs)
    g_feat = jax.grad(
        lambda xs: jnp.sum(dc.replace(prior, backend="features")(xs))
    )(xs)
    assert bool(jnp.all(jnp.isfinite(g_auto)))
    np.testing.assert_allclose(g_fused, g_feat, rtol=1e-4, atol=1e-5)
