"""Latent Kronecker GP (Ch. 6): structured matvec, posterior, break-even."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.gp import exact_posterior
from repro.core.kernels_fn import gram, make_params
from repro.core.kronecker import (
    break_even_density, lkgp_matvec_flops, lkgp_posterior, make_lkgp,
)
from repro.core.operators import LatentKroneckerOp
from repro.core.solvers.spec import CG, solve


def _make_problem(n1=12, n2=9, density=0.7, seed=0):
    rng = np.random.default_rng(seed)
    g1 = jnp.asarray(rng.normal(size=(n1, 3)).astype(np.float32))
    g2 = jnp.asarray(rng.normal(size=(n2, 1)).astype(np.float32))
    mask = jnp.asarray(rng.random((n1, n2)) < density)
    p1 = make_params("matern52", lengthscale=1.0, d=3)
    p2 = make_params("matern52", lengthscale=1.0, d=1)
    gp = make_lkgp(p1, p2, g1, g2, mask, 0.05)
    return gp, mask


def test_lkgp_matvec_matches_dense():
    """(K_obs + σ²I)v via the latent Kronecker matvec == dense P(K₁⊗K₂)Pᵀ + σ²I."""
    gp, mask = _make_problem()
    kfull = np.kron(np.asarray(gp.k1()), np.asarray(gp.k2()))
    idx = np.asarray(gp.obs_idx)
    kobs = kfull[np.ix_(idx, idx)] + 0.05 * np.eye(len(idx))
    v = jnp.asarray(np.random.default_rng(1).normal(size=(len(idx), 3)).astype(np.float32))
    np.testing.assert_allclose(gp.mv(v), kobs @ np.asarray(v), rtol=1e-3, atol=1e-3)


def test_lkgp_solve_matches_dense():
    """solve(LatentKroneckerOp, b, CG) — the structured operator goes through the
    unified solver layer (the private lkgp_solve_cg loop is gone)."""
    gp, _ = _make_problem()
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(size=len(np.asarray(gp.obs_idx))).astype(np.float32))
    res = solve(LatentKroneckerOp(gp=gp), b, CG(max_iters=500, tol=1e-8))
    kfull = np.kron(np.asarray(gp.k1()), np.asarray(gp.k2()))
    idx = np.asarray(gp.obs_idx)
    kobs = kfull[np.ix_(idx, idx)] + 0.05 * np.eye(len(idx))
    np.testing.assert_allclose(res.solution, np.linalg.solve(kobs, np.asarray(b)), atol=1e-3)
    assert int(res.matvecs) == int(res.iterations)  # cold CG: 1 matvec/iter


def test_lkgp_posterior_matches_exact_gp():
    """LKGP pathwise posterior == exact GP with the equivalent product kernel on the
    observed subset (mean), and calibrated variances on the full grid."""
    gp, mask = _make_problem(n1=10, n2=8, density=0.65, seed=3)
    n1, n2 = gp.shape
    rng = np.random.default_rng(4)
    y_full = np.asarray(gp.prior_sample_grid(jax.random.PRNGKey(0), 1))[..., 0]
    y_obs = jnp.asarray(y_full.reshape(-1)[np.asarray(gp.obs_idx)])
    mean, samples = lkgp_posterior(gp, y_obs, jax.random.PRNGKey(1),
                                   num_samples=256, max_iters=400)
    # dense reference posterior mean on the grid
    kfull = np.kron(np.asarray(gp.k1()), np.asarray(gp.k2()))
    idx = np.asarray(gp.obs_idx)
    kobs = kfull[np.ix_(idx, idx)] + float(gp.noise) * np.eye(len(idx))
    w = np.linalg.solve(kobs, np.asarray(y_obs))
    mean_ref = (kfull[:, idx] @ w).reshape(n1, n2)
    np.testing.assert_allclose(np.asarray(mean), mean_ref, atol=2e-2)
    # sample variance ≈ dense posterior variance
    cov_ref = kfull - kfull[:, idx] @ np.linalg.solve(kobs, kfull[idx, :])
    var_emp = np.var(np.asarray(samples), axis=-1).reshape(-1)
    np.testing.assert_allclose(var_emp, np.clip(np.diag(cov_ref), 0, None), atol=0.16)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 200), st.integers(4, 200))
def test_break_even_formula(n1, n2):
    """§6.2.6: at ρ*, structured and direct matvec FLOPs are equal; above it the
    latent Kronecker matvec wins."""
    rho = break_even_density(n1, n2)
    lk, direct = lkgp_matvec_flops(n1, n2, rho)
    np.testing.assert_allclose(lk, direct, rtol=1e-6)
    lk_hi, direct_hi = lkgp_matvec_flops(n1, n2, min(1.0, rho * 1.5))
    if rho * 1.5 <= 1.0:
        assert lk_hi < direct_hi
