"""Marginal-likelihood machinery (Ch. 5): estimator correctness, warm starts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gp import exact_mll
from repro.core.kernels_fn import make_params
from repro.core.mll import mll_grad, optimize_mll
from repro.core.solvers.spec import CG


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    n, d = 300, 2
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2 * x[:, 0]) * jnp.cos(x[:, 1])
    y = y + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    p = make_params("se", lengthscale=1.2, signal=0.8, noise=0.3, d=d)
    return dict(x=x, y=y, p=p, n=n, d=d)


def _exact_grad(p, x, y):
    return jax.grad(lambda q: exact_mll(q, x, y))(p)


@pytest.mark.parametrize("estimator", ["pathwise", "hutchinson"])
def test_mll_grad_unbiased(problem, estimator):
    """Both estimators approach the exact autodiff gradient as probes grow."""
    t = problem
    gs = []
    for seed in range(6):
        est = mll_grad(t["p"], t["x"], t["y"], jax.random.PRNGKey(seed),
                       num_probes=64, num_features=4096, estimator=estimator,
                       spec=CG(max_iters=300, tol=1e-8))
        gs.append(est.grad)
    mean_g = jax.tree.map(lambda *a: jnp.mean(jnp.stack(a), 0), *gs)
    exact = _exact_grad(t["p"], t["x"], t["y"])
    for name in ("log_lengthscale", "log_signal", "log_noise"):
        a, b = np.asarray(getattr(mean_g, name)), np.asarray(getattr(exact, name))
        np.testing.assert_allclose(a, b, rtol=0.25, atol=1.5)


def test_pathwise_estimator_lower_variance_for_trace(problem):
    """§5.2.3: pathwise probes z ~ N(0,A) need fewer solver iterations than
    Hutchinson probes z ~ N(0,I) — the initial distance ‖α*‖_A is smaller."""
    t = problem
    iters = {}
    for est in ("pathwise", "hutchinson"):
        r = mll_grad(t["p"], t["x"], t["y"], jax.random.PRNGKey(0), num_probes=16,
                     estimator=est, spec=CG(max_iters=500, tol=1e-6))
        iters[est] = int(r.solver_iterations)
    assert iters["pathwise"] <= iters["hutchinson"] + 5  # not worse


def test_optimize_mll_improves_evidence(problem):
    t = problem
    p0 = make_params("se", lengthscale=3.0, signal=0.3, noise=0.8, d=t["d"])
    before = float(exact_mll(p0, t["x"], t["y"]))
    st = optimize_mll(p0, t["x"], t["y"], jax.random.PRNGKey(0), num_steps=15,
                      lr=0.1, num_probes=8, spec=CG(max_iters=200, tol=1e-6))
    after = float(exact_mll(st.params, t["x"], t["y"]))
    assert after > before + 1.0, (before, after)


def test_warm_start_cuts_total_iterations(problem):
    """Ch. 5 headline: warm starting across hyperparameter steps reduces the total
    number of inner solver iterations."""
    t = problem
    p0 = make_params("se", lengthscale=2.0, signal=0.5, noise=0.5, d=t["d"])
    kw = dict(num_steps=10, lr=0.05, num_probes=8, spec=CG(max_iters=500, tol=1e-4))
    warm = optimize_mll(p0, t["x"], t["y"], jax.random.PRNGKey(0), warm_start=True, **kw)
    cold = optimize_mll(p0, t["x"], t["y"], jax.random.PRNGKey(0), warm_start=False, **kw)
    assert warm.total_solver_iters < cold.total_solver_iters
    # and reaches a comparable model (bias of warm starting is negligible, §5.3.2)
    lw = float(exact_mll(warm.params, t["x"], t["y"]))
    lc = float(exact_mll(cold.params, t["x"], t["y"]))
    assert lw > lc - 3.0
