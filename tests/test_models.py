"""Model substrate: SSD vs sequential oracle, decode-vs-train consistency, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as model_lib
from repro.models.layers import apply_rope, apply_mrope
from repro.models.ssm import ssd_chunked, ssm_scan_ref


# ---------------------------------------------------------------- SSD ---------


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_sequential(chunk):
    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 64, 3, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    d_skip = jnp.ones((h,))
    y_ref, h_ref = ssm_scan_ref(x, dt, a_log, bm, cm, d_skip)
    y_chk, h_chk = ssd_chunked(x, dt, a_log, bm, cm, d_skip, chunk)
    np.testing.assert_allclose(y_chk, y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(h_chk, h_ref, rtol=2e-3, atol=2e-3)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in half and carrying the state == one long scan."""
    key = jax.random.PRNGKey(1)
    b, s, h, p, n = 1, 32, 2, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    d_skip = jnp.zeros((h,))
    y_full, h_full = ssd_chunked(x, dt, a_log, bm, cm, d_skip, 8)
    y1, h1 = ssd_chunked(x[:, :16], dt[:, :16], a_log, bm[:, :16], cm[:, :16], d_skip, 8)
    y2, h2 = ssd_chunked(x[:, 16:], dt[:, 16:], a_log, bm[:, 16:], cm[:, 16:], d_skip, 8,
                         h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(h2, h_full, rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------- RoPE --------


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 6, 2, 16))
    pos = jnp.arange(6)[None]
    out = apply_rope(q, pos, 10_000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(out, axis=-1), jnp.linalg.norm(q, axis=-1), rtol=1e-5
    )
    # relative property: <R(p)q, R(p+k)v> depends only on k
    v = jax.random.normal(jax.random.fold_in(key, 1), (1, 6, 2, 16))
    r_q0 = apply_rope(q, pos, 1e4)
    r_v0 = apply_rope(v, pos + 3, 1e4)
    r_q1 = apply_rope(q, pos + 7, 1e4)
    r_v1 = apply_rope(v, pos + 10, 1e4)
    d0 = jnp.sum(r_q0[:, 0] * r_v0[:, 0])
    d1 = jnp.sum(r_q1[:, 0] * r_v1[:, 0])
    np.testing.assert_allclose(d0, d1, rtol=1e-4)


def test_mrope_text_positions_reduce_to_rope():
    """Identical (t,h,w) streams == plain RoPE (qwen2-vl text tokens)."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 5, 1, 32))
    pos = jnp.broadcast_to(jnp.arange(5)[None], (2, 5))
    pos3 = jnp.stack([pos, pos, pos])
    half = 16
    sections = (4, 6, 6)
    out_m = apply_mrope(q, pos3, 1e4, sections)
    out_r = apply_rope(q, pos, 1e4)
    np.testing.assert_allclose(out_m, out_r, rtol=1e-5, atol=1e-6)


# ------------------------------------------------- decode == train ------------


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b", "mamba2-130m",
                                  "jamba-1.5-large-398b", "whisper-tiny",
                                  "dbrx-132b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy consistency: forward_train logits at position t == prefill(≤t-1) +
    decode_step(t) logits — the KV/SSM cache machinery is exact."""
    overrides = {} if arch.startswith("jamba") else {"num_layers": 2}
    cfg = get_config(arch).reduced(remat=False, **overrides)
    key = jax.random.PRNGKey(0)
    params = model_lib.init_model_params(cfg, key)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab_size)
    inputs = {"tokens": tokens}
    if cfg.is_encdec:
        inputs["frames"] = jax.random.normal(jax.random.fold_in(key, 2),
                                             (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        inputs["vision_embeds"] = jax.random.normal(jax.random.fold_in(key, 3),
                                                    (b, cfg.vision_tokens, cfg.d_model))
    full = model_lib.forward_train(cfg, params, inputs)  # (b, s, v)

    cache = model_lib.zero_cache(cfg, b, s + 4, jnp.float32)
    pre_inputs = dict(inputs, tokens=tokens[:, :-1])
    logits_pre, cache = model_lib.prefill(cfg, params, pre_inputs, cache)
    np.testing.assert_allclose(logits_pre[:, -1], full[:, -2], rtol=5e-2, atol=5e-3)

    logits_dec, _ = model_lib.decode_step(cfg, params, tokens[:, -1:], cache,
                                          jnp.asarray(s - 1))
    np.testing.assert_allclose(logits_dec[:, -1], full[:, -1], rtol=5e-2, atol=5e-3)


def test_moe_routing_mass_conservation():
    """Top-k gates renormalise to 1 for kept tokens; output is a convex combination
    of expert outputs (checked via linearity in expert outputs)."""
    from repro.models import moe as moe_mod

    cfg = get_config("dbrx-132b").reduced(num_layers=2, remat=False)
    key = jax.random.PRNGKey(0)
    p = model_lib.init_params(moe_mod.moe_params(cfg), key) if hasattr(model_lib, "init_params") else None
    from repro.models.param import init_params
    p = init_params(moe_mod.moe_params(cfg), key)
    x = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y = moe_mod.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # scaling all expert output projections scales routed output linearly
    p2 = dict(p, down=2.0 * p["down"])
    y2 = moe_mod.moe_apply(p2, cfg, x)
    np.testing.assert_allclose(y2, 2.0 * y, rtol=1e-4, atol=1e-5)


def test_param_counts_match_architecture_scale():
    """Full configs land in the right parameter-count ballpark."""
    expect = {
        "llama3-8b": (7e9, 9e9),
        "dbrx-132b": (1.2e11, 1.45e11),
        "deepseek-v2-236b": (2.1e11, 2.6e11),
        "jamba-1.5-large-398b": (3.3e11, 4.4e11),
        "mamba2-130m": (1.1e8, 1.6e8),
        "olmo-1b": (0.9e9, 1.6e9),
        "deepseek-coder-33b": (3.0e10, 3.7e10),
        "minitron-8b": (7e9, 10e9),
        "qwen2-vl-7b": (6.5e9, 9e9),
        "whisper-tiny": (2e7, 9e7),
    }
    for arch, (lo, hi) in expect.items():
        n = model_lib.count_params(get_config(arch))
        assert lo <= n <= hi, (arch, n)


def test_active_params_moe():
    cfg = get_config("dbrx-132b")
    total = model_lib.count_params(cfg)
    active = model_lib.active_param_count(cfg)
    assert active < total * 0.45  # top-4 of 16 experts + shared trunk


def test_mla_absorbed_matches_baseline():
    """§Perf H3: latent-space (absorbed) MLA attention == up-projected baseline."""
    import dataclasses

    cfg = get_config("deepseek-v2-236b").reduced(num_layers=2, remat=False)
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    key = jax.random.PRNGKey(0)
    params = model_lib.init_model_params(cfg, key)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab_size)
    cache = model_lib.zero_cache(cfg, b, s + 2, jnp.float32)
    _, cache = model_lib.prefill(cfg, params, {"tokens": tokens}, cache)
    tok = tokens[:, -1:]
    base, _ = model_lib.decode_step(cfg, params, tok, cache, jnp.asarray(s))
    absorbed, _ = model_lib.decode_step(cfg_a, params, tok, cache, jnp.asarray(s))
    np.testing.assert_allclose(absorbed, base, rtol=2e-2, atol=2e-3)
