"""LinearOperator protocol (core/operators.py): mv consistency against dense
materialisation, capability dispatch, pytree round-trips (same treedef ⇒ no
retrace), and SolveResult matvec accounting for the structured operators —
including ShardedGram on a 2-device CPU mesh (subprocess)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import gram, make_params
from repro.core.kronecker import make_lkgp
from repro.core.operators import (
    OPTIONAL_CAPABILITIES,
    Gram,
    LatentKroneckerOp,
    NormalEq,
    capabilities,
    matvec_counts,
    require_capabilities,
    reset_matvec_counts,
    supports,
)
from repro.core.precond import WoodburyPrecond, nystrom_preconditioner
from repro.core.solvers.spec import AP, CG, SDD, SGD, Nystrom, solve

KEY = jax.random.PRNGKey(11)


def _lkgp_problem(n1=11, n2=8, density=0.7, seed=0):
    rng = np.random.default_rng(seed)
    g1 = jnp.asarray(rng.normal(size=(n1, 3)).astype(np.float32))
    g2 = jnp.asarray(rng.normal(size=(n2, 1)).astype(np.float32))
    mask = jnp.asarray(rng.random((n1, n2)) < density)
    p1 = make_params("matern52", lengthscale=1.0, d=3)
    p2 = make_params("matern52", lengthscale=1.0, d=1)
    gp = make_lkgp(p1, p2, g1, g2, mask, 0.05)
    kfull = np.kron(np.asarray(gp.k1()), np.asarray(gp.k2()))
    idx = np.asarray(gp.obs_idx)
    dense = kfull[np.ix_(idx, idx)] + 0.05 * np.eye(len(idx))
    return LatentKroneckerOp(gp=gp), jnp.asarray(dense.astype(np.float32))


# ---------------------------------------------------------------------------
# Protocol surface + capability dispatch
# ---------------------------------------------------------------------------


def test_capability_table(toy_regression):
    t = toy_regression
    g = Gram(x=t["x"], params=t["params"])
    assert capabilities(g) == OPTIONAL_CAPABILITIES  # full set
    ne = NormalEq(x=t["x"], z=t["x"][:16], params=t["params"])
    assert capabilities(ne) == ()
    lk, _ = _lkgp_problem()
    assert capabilities(lk) == ()
    assert supports(g, "rows_mv", "block_at") and not supports(ne, "rows_mv")
    require_capabilities(g, ("rows_mv", "precond_factor"), consumer="test")
    with pytest.raises(TypeError, match="block_at"):
        require_capabilities(lk, ("block_at",), consumer="solver 'ap'")


@pytest.mark.parametrize("spec_cls,missing", [
    (SGD, "rows_mv"), (SDD, "rows_mv"), (AP, "block_at"),
])
def test_row_specs_refused_by_matvec_only_ops(toy_regression, spec_cls, missing):
    """A spec requesting row blocks from an operator without them raises a
    clear capability error, for both NormalEq and LatentKroneckerOp."""
    t = toy_regression
    ne = NormalEq(x=t["x"], z=t["x"][:16], params=t["params"])
    lk, _ = _lkgp_problem()
    for op, rhs in [(ne, jnp.ones(16)), (lk, jnp.ones(lk.shape[0]))]:
        with pytest.raises(TypeError, match=missing):
            solve(op, rhs, spec_cls(num_steps=5), key=KEY)


def test_precond_capability_refused_by_matvec_only_ops(toy_regression):
    t = toy_regression
    ne = NormalEq(x=t["x"], z=t["x"][:16], params=t["params"])
    with pytest.raises(TypeError, match="precond_factor"):
        solve(ne, jnp.ones(16), CG(max_iters=10, precond=Nystrom(rank=4)), key=KEY)


# ---------------------------------------------------------------------------
# mv / diag_part consistency against dense materialisation
# ---------------------------------------------------------------------------


def test_gram_matches_dense(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    assert op.shape == (t["n"], t["n"])
    v = jax.random.normal(KEY, (t["n"], 3))
    np.testing.assert_allclose(op.mv(v), t["kmat"] @ v, atol=1e-4)
    np.testing.assert_allclose(op.diag_part(), jnp.diag(t["kmat"]), atol=1e-5)


def test_normal_eq_matches_dense(toy_regression):
    t = toy_regression
    z = t["x"][:24]
    op = NormalEq(x=t["x"], z=z, params=t["params"], row_chunk=100)  # forces padding
    kxz = gram(t["params"], t["x"], z)
    kzz = gram(t["params"], z)
    dense = kxz.T @ kxz + t["params"].noise * kzz
    assert op.shape == (24, 24)
    u = jax.random.normal(KEY, (24, 2))
    np.testing.assert_allclose(op.mv(u), dense @ u, atol=1e-3)
    np.testing.assert_allclose(op.diag_part(), jnp.diag(dense), atol=1e-3)


def test_lkgp_op_matches_dense():
    op, dense = _lkgp_problem()
    n = dense.shape[0]
    assert op.shape == (n, n)
    v = jax.random.normal(KEY, (n, 3))
    np.testing.assert_allclose(op.mv(v), dense @ v, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(op.diag_part(), jnp.diag(dense), atol=1e-4)


def test_woodbury_precond_is_an_operator(toy_regression):
    """WoodburyPrecond implements the protocol with mv the FORWARD apply
    M @ v (like every other operator), while __call__ keeps the
    preconditioner-apply convention r ↦ M⁻¹r consumed by CG."""
    t = toy_regression
    pc = nystrom_preconditioner(t["params"], t["x"], KEY, rank=32)
    assert isinstance(pc, WoodburyPrecond)
    assert pc.shape == (t["n"], t["n"])
    m_dense = pc.l @ pc.l.T + pc.sigma2 * jnp.eye(t["n"])
    r = jax.random.normal(KEY, (t["n"], 2))
    np.testing.assert_allclose(pc.mv(r), m_dense @ r, atol=1e-3)
    np.testing.assert_allclose(pc(r), jnp.linalg.inv(m_dense) @ r, atol=1e-3)
    np.testing.assert_allclose(pc(pc.mv(r)), r, atol=1e-3)  # M⁻¹M = I
    np.testing.assert_allclose(pc.diag_part(), jnp.diag(m_dense), atol=1e-4)
    # and as a protocol operator, solve() against it means solving MV = b
    res = solve(pc, r[:, 0], CG(max_iters=200, tol=1e-8))
    np.testing.assert_allclose(res.solution, pc(r[:, 0]), atol=1e-3)


# ---------------------------------------------------------------------------
# Pytree round-trip: same treedef ⇒ compiled solves are reused (no retrace)
# ---------------------------------------------------------------------------


def _variants(toy):
    p2 = make_params("matern32", lengthscale=1.1, signal=0.9, noise=0.2, d=toy["d"])
    g1 = Gram(x=toy["x"], params=toy["params"])
    g2 = Gram(x=toy["x"] * 1.5, params=p2)
    ne1 = NormalEq(x=toy["x"], z=toy["x"][:16], params=toy["params"])
    ne2 = NormalEq(x=toy["x"] * 2.0, z=toy["x"][:16], params=p2)
    lk1, _ = _lkgp_problem(seed=0)
    # same mask (⇒ same shapes/treedef), perturbed grid and noise values
    import dataclasses

    gp2 = dataclasses.replace(lk1.gp, grid1=lk1.gp.grid1 * 1.2, noise=lk1.gp.noise * 2.0)
    lk2 = LatentKroneckerOp(gp=gp2)
    return [(g1, g2), (ne1, ne2), (lk1, lk2)]


def test_operator_pytree_roundtrip(toy_regression):
    for op, _ in _variants(toy_regression):
        leaves, treedef = jax.tree_util.tree_flatten(op)
        again = jax.tree_util.tree_unflatten(treedef, leaves)
        assert type(again) is type(op)
        leaves2, treedef2 = jax.tree_util.tree_flatten(again)
        assert treedef2 == treedef
        for a, b in zip(leaves, leaves2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_same_treedef_means_no_retrace(toy_regression):
    """Two instances of the same operator with different array *values* share a
    treedef, so a jitted consumer traces once — hyperparameter steps don't
    recompile solves."""
    for op_a, op_b in _variants(toy_regression):
        traces = []

        @jax.jit
        def run(op, v):
            traces.append(1)
            return op.mv(v)

        v = jnp.ones((op_a.shape[0],))
        run(op_a, v)
        run(op_b, v)
        assert len(traces) == 1, f"{type(op_a).__name__} retraced"


# ---------------------------------------------------------------------------
# Matvec accounting for the structured operators
# ---------------------------------------------------------------------------


def test_lkgp_solve_matvec_accounting():
    """SolveResult.matvecs is exact for LatentKroneckerOp, and instrument=True
    runtime counters agree with it (one structured matvec per CG iteration)."""
    op, dense = _lkgp_problem()
    op = LatentKroneckerOp(gp=op.gp, instrument=True)
    n = dense.shape[0]
    b = jax.random.normal(KEY, (n,))
    iters = 9
    reset_matvec_counts()
    res = solve(op, b, CG(max_iters=iters, tol=0.0))
    jax.block_until_ready(res.solution)
    jax.effects_barrier()
    counts = matvec_counts()
    assert int(res.iterations) == iters
    assert int(res.matvecs) == iters  # cold start: no A·0, no finalize recompute
    assert counts["mv"] == iters


def test_sharded_gram_two_device_subprocess():
    """The acceptance check: solve(ShardedGram, b, spec) on a 2-device CPU mesh —
    correct results and matvec counts for CG and SGD, and the sharded row-gather
    primitives match their dense references. Subprocess so the forced 2-device
    platform doesn't leak."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ShardedGram, solve, CG, SGD, AP, make_params
        from repro.core.distributed import shard_training_rows
        from repro.core.kernels_fn import gram
        from repro.core.operators import capabilities, OPTIONAL_CAPABILITIES

        mesh = jax.make_mesh((2,), ("data",))
        n, d = 128, 3
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        y = jnp.sin(x.sum(-1))
        p = make_params("se", lengthscale=1.0, noise=0.2, d=d)
        op = ShardedGram(x=shard_training_rows(mesh, x), params=p, mesh=mesh)
        assert capabilities(op) == OPTIONAL_CAPABILITIES, capabilities(op)
        dense = gram(p, x) + p.noise * jnp.eye(n)
        ref = jnp.linalg.solve(dense, y)

        # sharded row-gather primitives vs dense
        idx = jax.random.randint(jax.random.fold_in(key, 1), (16,), 0, n)
        v = jax.random.normal(jax.random.fold_in(key, 2), (n, 3))
        u = jax.random.normal(jax.random.fold_in(key, 3), (16, 3))
        kidx = gram(p, x[idx], x)
        np.testing.assert_allclose(op.mv(v), dense @ v, atol=1e-4)
        np.testing.assert_allclose(op.rows_mv(idx, v), kidx @ v, atol=1e-4)
        np.testing.assert_allclose(op.rows_t_mv(idx, u), kidx.T @ u, atol=1e-4)
        np.testing.assert_allclose(op.block_at(idx), gram(p, x[idx], x[idx]), atol=1e-5)

        # CG: correct + exactly one mesh-wide matvec per iteration
        res = solve(op, y, CG(max_iters=300, tol=1e-8))
        np.testing.assert_allclose(res.solution, ref, atol=1e-3)
        assert int(res.matvecs) == int(res.iterations), (res.matvecs, res.iterations)

        # SGD: the sharded row-gather makes the stochastic solver work
        # distributed; one full matvec total (the exact finalize residual)
        res_sgd = solve(op, y, SGD(num_steps=2000, batch_size=32,
                                   step_size_times_n=0.5, num_features=64),
                        key=key)
        pred_err = float(jnp.max(jnp.abs(dense @ (res_sgd.solution - ref))))
        assert pred_err < 0.2, pred_err
        assert int(res_sgd.matvecs) == 1, int(res_sgd.matvecs)

        # AP: exact block sub-solves, zero full matvecs cold-started
        res_ap = solve(op, y, AP(num_steps=150, block_size=32), key=key)
        np.testing.assert_allclose(res_ap.solution, ref, atol=2e-2)
        assert int(res_ap.matvecs) == 0

        # gather_once: prepare_for_solve replicates the inputs once (outside
        # the solver loop); all primitives match the per-matvec-gather results
        go = ShardedGram(x=shard_training_rows(mesh, x), params=p, mesh=mesh,
                         gather_once=True)
        assert go.x_full is None
        prep = go.prepare_for_solve()
        assert prep.x_full is not None
        assert prep.prepare_for_solve() is prep  # idempotent: gathered already
        np.testing.assert_allclose(prep.mv(v), dense @ v, atol=1e-4)
        np.testing.assert_allclose(prep.rows_mv(idx, v), kidx @ v, atol=1e-4)
        np.testing.assert_allclose(prep.rows_t_mv(idx, u), kidx.T @ u, atol=1e-4)
        np.testing.assert_allclose(prep.block_at(idx), gram(p, x[idx], x[idx]),
                                   atol=1e-5)
        # through solve(): the hook fires automatically, results unchanged
        res_go = solve(go, y, CG(max_iters=300, tol=1e-8))
        np.testing.assert_allclose(res_go.solution, ref, atol=1e-3)
        res_go_sgd = solve(go, y, SGD(num_steps=500, batch_size=32,
                                      step_size_times_n=0.5, num_features=64),
                           key=key)
        assert int(res_go_sgd.matvecs) == 1
        print("OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
