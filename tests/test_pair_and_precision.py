"""Fused pair primitives (gram_rows_pair / rff_pair_mv) and tile precision.

Three contracts:

* **pair parity** — the fused pair step equals its two-call composition on
  every backend, for values AND gradients (the Pallas pair kernels carry
  composition custom VJPs; a drift here silently corrupts SGD training);
* **precision parity** — bf16 tile contractions with fp32 accumulation stay
  within loose tolerance of fp32 (the opt-in is for stochastic solvers whose
  mini-batch variance dominates tile noise);
* **fp32 default** — nothing opts into bf16 unless asked: operator fields,
  spec fields and op-level defaults all say fp32/None.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import gram, make_params
from repro.core.operators import Gram, supports
from repro.core.rff import make_fourier_features
from repro.core.solvers.spec import CG, SGD, solve
from repro.kernels.ops import (
    PRECISIONS,
    gram_rows_pair,
    rff_mv,
    rff_pair_mv,
    rff_t_mv,
)

KEY = jax.random.PRNGKey(7)


def _pair_problem(n=200, d=3, p=40, s=3, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (p,), 0, n)
    look = jax.random.normal(jax.random.fold_in(key, 2), (n, s))
    b = jax.random.normal(jax.random.fold_in(key, 3), (p, s))
    params = make_params("matern32", lengthscale=0.9, signal=1.3, d=d, noise=0.1)
    return params, x, idx, look, b


def _pair_ref(params, x, idx, look, b):
    panel = gram(params, x[idx], x)
    err = panel @ look - b
    return err, panel.T @ err


# ---------------------------------------------------------------------------
# gram_rows_pair: fused vs unfused parity, values and grads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
@pytest.mark.parametrize("kind", ["se", "matern32"])
@pytest.mark.parametrize(
    "n,p,s", [(200, 40, 3), (128, 32, 1), (130, 17, 2)]  # incl. non-block shapes
)
def test_gram_rows_pair_matches_composition(backend, kind, n, p, s):
    params, x, idx, look, b = _pair_problem(n=n, p=p, s=s)
    params = dataclasses.replace(
        params, kind=kind, log_lengthscale=params.log_lengthscale
    )
    err, g = gram_rows_pair(params, x, idx, look, b, backend=backend)
    err_ref, g_ref = _pair_ref(params, x, idx, look, b)
    assert err.shape == (p, s) and g.shape == (n, s)
    np.testing.assert_allclose(err, err_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(g, g_ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_gram_rows_pair_grads_match_composition(backend):
    params, x, idx, look, b = _pair_problem(n=150, p=24, s=2)

    def loss_fused(x_, look_, b_, log_ls):
        p_ = dataclasses.replace(params, log_lengthscale=log_ls)
        err, g = gram_rows_pair(p_, x_, idx, look_, b_, backend=backend)
        return jnp.sum(err ** 2) + jnp.sum(jnp.sin(g))

    def loss_ref(x_, look_, b_, log_ls):
        p_ = dataclasses.replace(params, log_lengthscale=log_ls)
        err, g = _pair_ref(p_, x_, idx, look_, b_)
        return jnp.sum(err ** 2) + jnp.sum(jnp.sin(g))

    args = (x, look, b, params.log_lengthscale)
    grads = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(*args)
    grads_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(*args)
    for got, ref in zip(grads, grads_ref):
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_gram_rows_pair_operator_capability(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    assert supports(op, "rows_pair_mv")
    idx = jnp.arange(16)
    look = jnp.ones((op.n, 2))
    b = jnp.zeros((16, 2))
    err, g = op.rows_pair_mv(idx, look, b)
    err_ref = op.rows_mv(idx, look) - b
    g_ref = op.rows_t_mv(idx, err_ref)
    np.testing.assert_allclose(err, err_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(g, g_ref, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# rff_pair_mv: fused vs unfused parity, values and grads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["features", "pallas"])
@pytest.mark.parametrize("n,m,s", [(128, 64, 2), (130, 48, 1), (96, 128, 3)])
def test_rff_pair_matches_composition(backend, n, m, s):
    key = jax.random.PRNGKey(n + m)
    x = jax.random.normal(key, (n, 4))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (m, 4))
    u = jax.random.normal(jax.random.fold_in(key, 2), (n, s))
    out = rff_pair_mv(x, omega, u, signal=1.2, backend=backend)
    ref = rff_mv(x, omega,
                 rff_t_mv(x, omega, u, signal=1.2, backend="features"),
                 signal=1.2, backend="features")
    # composition applies √signal twice — same total scaling as the pair
    assert out.shape == (n, s)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("backend", ["features", "pallas"])
def test_rff_pair_grads_match_composition(backend):
    key = jax.random.PRNGKey(11)
    n, m, s = 96, 48, 2
    x = jax.random.normal(key, (n, 3))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (m, 3))
    u = jax.random.normal(jax.random.fold_in(key, 2), (n, s))

    def loss_fused(x_, om_, u_):
        return jnp.sum(jnp.cos(rff_pair_mv(x_, om_, u_, backend=backend)))

    def loss_ref(x_, om_, u_):
        t = rff_t_mv(x_, om_, u_, backend="features")
        return jnp.sum(jnp.cos(rff_mv(x_, om_, t, backend="features")))

    grads = jax.grad(loss_fused, argnums=(0, 1, 2))(x, omega, u)
    grads_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, omega, u)
    for got, ref in zip(grads, grads_ref):
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_feature_operator_pair_threads_backend():
    p = make_params("se", lengthscale=1.0, d=3)
    ff = make_fourier_features(p, KEY, 32, 3)
    x = jax.random.normal(KEY, (64, 3))
    u = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 2))
    out = ff.phi_pair_mv(x, u)
    ref = ff.phi_mv(x, ff.phi_t_mv(x, u))
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Mixed precision: bf16 tiles track fp32 within loose tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_gram_pair_bf16_tracks_fp32(backend):
    params, x, idx, look, b = _pair_problem(n=150, p=24, s=2)
    err32, g32 = gram_rows_pair(params, x, idx, look, b, backend=backend)
    err16, g16 = gram_rows_pair(params, x, idx, look, b, backend=backend,
                                precision="bf16")
    scale = float(jnp.max(jnp.abs(g32)))
    np.testing.assert_allclose(err16, err32, atol=5e-2 * max(scale, 1.0))
    np.testing.assert_allclose(g16, g32, atol=5e-2 * max(scale, 1.0))


@pytest.mark.parametrize("backend", ["features", "pallas"])
def test_rff_pair_bf16_tracks_fp32(backend):
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (128, 3))
    omega = jax.random.normal(jax.random.fold_in(key, 1), (64, 3))
    u = jax.random.normal(jax.random.fold_in(key, 2), (128, 2))
    out32 = rff_pair_mv(x, omega, u, backend=backend)
    out16 = rff_pair_mv(x, omega, u, backend=backend, precision="bf16")
    scale = float(jnp.max(jnp.abs(out32)))
    np.testing.assert_allclose(out16, out32, atol=5e-2 * max(scale, 1.0))


def test_unknown_precision_rejected():
    params, x, idx, look, b = _pair_problem(n=128, p=16, s=1)
    with pytest.raises(ValueError, match="precision"):
        gram_rows_pair(params, x, idx, look, b, precision="fp16")


# ---------------------------------------------------------------------------
# fp32 is the default everywhere; specs pin precision like backend
# ---------------------------------------------------------------------------


def test_fp32_defaults():
    assert PRECISIONS[0] == "fp32"
    params = make_params("se", d=2)
    op = Gram(x=jnp.zeros((4, 2)), params=params)
    assert op.precision == "fp32"
    assert CG().precision is None  # inherits the operator's fp32
    assert SGD().precision is None


def test_spec_pins_precision_through_solve(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    res32 = solve(op, t["y"], SGD(num_steps=150, batch_size=32,
                                  num_features=16), key=KEY)
    res16 = solve(op, t["y"], SGD(num_steps=150, batch_size=32,
                                  num_features=16, precision="bf16"), key=KEY)
    scale = float(jnp.max(jnp.abs(res32.solution)))
    np.testing.assert_allclose(res16.solution, res32.solution,
                               atol=8e-2 * max(scale, 1.0))
    with pytest.raises(ValueError, match="precision"):
        solve(op, t["y"], CG(precision="tf32"))


def test_spec_precision_serializes():
    spec = SGD(num_steps=10, precision="bf16")
    d = spec.to_json()
    assert SGD.from_json(d) == spec
