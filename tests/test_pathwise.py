"""Pathwise conditioning (§2.1.2, Ch. 3): sampled posteriors match the exact GP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gp import exact_posterior, exact_mll
from repro.core.kernels_fn import make_params, gram
from repro.core.pathwise import posterior_functions
from repro.core.solvers.spec import CG, SDD, SGD


@pytest.fixture(scope="module")
def small_problem():
    key = jax.random.PRNGKey(0)
    n, d = 500, 2
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2 * x[:, 0]) + jnp.cos(x[:, 1])
    p = make_params("se", lengthscale=0.7, signal=1.0, noise=0.2, d=d)
    xt = jax.random.normal(jax.random.fold_in(key, 9), (40, d))
    post = exact_posterior(p, x, y)
    return dict(x=x, y=y, p=p, xt=xt, mu=post.mean(xt), cov=post.cov(xt))


def test_pathwise_cg_moments(small_problem):
    """Sampled posterior moments match the exact GP. The representer-weight mean
    is solver-exact (CG at tol=1e-8, matvec counts now exactly iters); the
    sample mean/variance carry Monte-Carlo + RFF error ~ sqrt(2/s), so the
    sample budget must support the tolerance: at s=384/q=4096 the max variance
    error over 40 test points is ~0.095 (seed-dependent) — more than atol; at
    s=768/q=8192 it is ~0.013–0.047 across seeds, comfortably inside 6e-2."""
    t = small_problem
    pf = posterior_functions(t["p"], t["x"], t["y"], jax.random.PRNGKey(2),
                             num_samples=768, num_features=8192,
                             spec=CG(max_iters=300, tol=1e-8))
    assert int(pf.solve_info.matvecs) == int(pf.solve_info.iterations)
    f = pf(t["xt"])  # (40, s)
    np.testing.assert_allclose(f.mean(1), t["mu"], atol=6e-2)
    np.testing.assert_allclose(jnp.var(f, axis=1), jnp.diag(t["cov"]), atol=6e-2)
    # the mean head uses the representer weights directly (no MC error)
    np.testing.assert_allclose(pf.mean(t["xt"]), t["mu"], atol=5e-3)


def test_pathwise_joint_covariance(small_problem):
    """Samples are jointly correct over pairs of test points, not just marginals."""
    t = small_problem
    pf = posterior_functions(t["p"], t["x"], t["y"], jax.random.PRNGKey(2),
                             num_samples=512, num_features=4096,
                             spec=CG(max_iters=300, tol=1e-8))
    f = np.asarray(pf(t["xt"][:8]))
    emp = np.cov(f)
    np.testing.assert_allclose(emp, np.asarray(t["cov"])[:8, :8], atol=8e-2)


def test_pathwise_sdd_matches_cg(small_problem):
    t = small_problem
    pf = posterior_functions(t["p"], t["x"], t["y"], jax.random.PRNGKey(3),
                             num_samples=8,
                             spec=SDD(num_steps=20_000, batch_size=128,
                                      step_size_times_n=5.0))
    np.testing.assert_allclose(pf.mean(t["xt"]), t["mu"], atol=2e-2)


def test_sgd_variance_reduced_objective(small_problem):
    """Eq. 3.6: moving ε into the regulariser preserves the optimum (δ-shift)."""
    t = small_problem
    pf = posterior_functions(t["p"], t["x"], t["y"], jax.random.PRNGKey(4),
                             num_samples=8,
                             spec=SGD(num_steps=15_000, batch_size=128,
                                      step_size_times_n=0.5))
    # SGD at this fixed step budget carries O(1/√t) optimisation error that
    # peaks ~0.15 at the hardest of the 40 test points (seed-stable); the test's
    # claim is the δ-shift preserves the optimum, not solver-exactness
    np.testing.assert_allclose(pf.mean(t["xt"]), t["mu"], atol=0.2)
    f = pf(t["xt"])
    assert np.isfinite(np.asarray(f)).all()


def test_prior_region_reverts_to_prior(small_problem):
    """§3.2.4: far from data, pathwise posteriors revert to the prior (variance →
    signal variance, mean → 0) for ANY representer weights."""
    t = small_problem
    pf = posterior_functions(t["p"], t["x"], t["y"], jax.random.PRNGKey(5),
                             num_samples=256, num_features=4096,
                             spec=CG(max_iters=100))
    far = 50.0 + jax.random.normal(jax.random.PRNGKey(6), (10, 2))
    f = pf(far)
    np.testing.assert_allclose(f.mean(1), 0.0, atol=0.2)
    np.testing.assert_allclose(jnp.var(f, axis=1), 1.0, atol=0.25)
