"""Deterministic chaos suite: solver guardrails, the escalation ladder, and
the serving engine's fault tolerance (docs/robustness.md).

Layered like the machinery itself:

* solver layer — every family detects per-column trouble *inside* its loop
  (non-finite, CG breakdown, stagnation), freezes the bad columns, and leaves
  healthy columns bit-identical to a fault-free run (the isolation contract);
* ladder layer — ``solve_robust`` recovers what is recoverable (jitter /
  precondition / family switch / dense fallback) and reports what is not as a
  structured failure, never a silent NaN;
* scheduler layer — deadline expiry and the max-skips starvation guard;
* engine layer — poisoned requests are rescued solo or failed structurally,
  repeat offenders are quarantined, overload sheds or degrades, raising
  batches retry then fail structurally — and requests that shared a batch
  with a poisoned one are served exactly as if the fault never happened.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CG,
    EscalationPolicy,
    FLAG_BREAKDOWN,
    FLAG_NONFINITE,
    FLAG_STAGNATION,
    FROZEN_FLAGS,
    Gram,
    IterativeGP,
    SGD,
    flag_names,
    make_params,
    solve,
    solve_robust,
)
from repro.serve import EngineOverloaded, FIFOScheduler, GPEngine, Request
from repro.testing import (
    DenseOperator,
    FaultyFeatureOperator,
    FaultyOperator,
    nan_columns,
    near_singular_problem,
)

SPECS = {
    "cg": dict(spec="cg", max_iters=40, tol=1e-5),
    "sgd": dict(spec="sgd", num_steps=200, batch_size=32),
    "sdd": dict(spec="sdd", num_steps=200, batch_size=32, step_size_times_n=1.0),
    "ap": dict(spec="ap", num_steps=100, block_size=32),
}


@pytest.fixture(scope="module")
def well_posed():
    key = jax.random.PRNGKey(3)
    kx, kb = jax.random.split(key)
    x = jax.random.uniform(kx, (80, 2))
    params = make_params("se", lengthscale=0.7, signal=1.0, noise=0.3)
    op = Gram(x=x, params=params)
    b = jax.random.normal(kb, (80, 3))
    return op, b


def _flags(res):
    return np.atleast_1d(np.asarray(jax.device_get(res.flags))).astype(np.int64)


# ---------------------------------------------------------------------------
# solver layer: in-loop detection + isolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(SPECS))
def test_nan_rhs_flags_only_its_column(well_posed, family):
    """A NaN RHS column is flagged non-finite and frozen; the other columns'
    solutions are bit-identical to a fault-free solve (same key)."""
    op, b = well_posed
    kw = dict(SPECS[family])
    spec = kw.pop("spec")
    key = jax.random.PRNGKey(11)
    clean = solve(op, b, spec, key=key, **kw)
    dirty = solve(op, nan_columns(b, (1,)), spec, key=key, **kw)
    fl = _flags(dirty)
    assert fl[1] & FLAG_NONFINITE
    assert not (fl[0] | fl[2]) & FLAG_NONFINITE
    assert not bool(dirty.healthy)
    assert bool(clean.healthy)
    np.testing.assert_array_equal(
        np.asarray(dirty.solution[:, 0]), np.asarray(clean.solution[:, 0])
    )
    np.testing.assert_array_equal(
        np.asarray(dirty.solution[:, 2]), np.asarray(clean.solution[:, 2])
    )
    # the poisoned column never reads converged
    assert not bool(dirty.converged)


def test_cg_breakdown_flag():
    """pᵀAp ≤ 0 on an indefinite operator raises FLAG_BREAKDOWN in-loop."""
    op = DenseOperator(a=jnp.diag(jnp.array([1.0, -1.0])))
    res = solve(op, jnp.ones((2, 1)), "cg", max_iters=10, tol=1e-6)
    assert _flags(res)[0] & FLAG_BREAKDOWN
    assert not bool(res.converged)


def test_cg_stagnation_flag_and_no_silent_nan():
    """fp32 CG on a near-singular Gram stalls → advisory FLAG_STAGNATION;
    and no family ever returns an unflagged non-finite column."""
    op, b, _, _ = near_singular_problem(96, 3)
    res = solve(op, b, "cg", max_iters=400, tol=1e-6, stall_window=30)
    fl = _flags(res)
    assert (fl & FLAG_STAGNATION).all()
    # stagnation is advisory: nothing frozen, result stays finite
    assert bool(res.healthy)
    for family, kw in SPECS.items():
        kw = dict(kw)
        spec = kw.pop("spec")
        r = solve(op, b, spec, key=jax.random.PRNGKey(0), **kw)
        sol = np.asarray(jax.device_get(r.solution))
        bad_cols = ~np.isfinite(sol).all(axis=0)
        flagged = (_flags(r) & FROZEN_FLAGS) != 0
        assert (~bad_cols | flagged).all(), (
            f"{family}: non-finite column without a freezing flag"
        )


def test_faulty_operator_isolation(well_posed):
    """A transient matvec fault in one column flags that column only, and the
    fault vanishes below min_width (the solo re-run escape hatch)."""
    op, b = well_posed
    fop = FaultyOperator(op, columns=(1,), min_width=2)
    clean = solve(op, b, "cg", max_iters=40, tol=1e-5)
    dirty = solve(fop, b, "cg", max_iters=40, tol=1e-5)
    fl = _flags(dirty)
    assert fl[1] & FLAG_NONFINITE and not fl[0] and not fl[2]
    np.testing.assert_array_equal(
        np.asarray(dirty.solution[:, 0]), np.asarray(clean.solution[:, 0])
    )
    solo = solve(fop, b[:, :1], "cg", max_iters=40, tol=1e-5)
    assert bool(solo.healthy)


def test_facade_warns_with_flag_names():
    gp = IterativeGP("se", noise=0.1, spec="cg")
    y = jnp.zeros((16,)).at[3].set(jnp.nan)
    gp.fit(jax.random.uniform(jax.random.PRNGKey(0), (16, 1)), y)
    with pytest.warns(RuntimeWarning, match="nonfinite"):
        gp.posterior(num_samples=4, num_features=64)


# ---------------------------------------------------------------------------
# ladder layer: solve_robust
# ---------------------------------------------------------------------------


def test_ladder_happy_path_is_free(well_posed):
    """No flags → no rungs, and matvec spend identical to plain solve."""
    op, b = well_posed
    plain = solve(op, b, "cg", max_iters=40, tol=1e-5)
    rep = solve_robust(op, b, "cg", max_iters=40, tol=1e-5)
    assert not rep.escalated and rep.rungs == () and rep.recovered
    assert int(rep.result.matvecs) == int(plain.matvecs)
    np.testing.assert_array_equal(
        np.asarray(rep.result.solution), np.asarray(plain.solution)
    )


def test_ladder_recovers_stagnation():
    op, b, _, _ = near_singular_problem(96, 3)
    rep = solve_robust(
        op, b, "cg", max_iters=200, tol=1e-6, stall_window=30,
        policy=EscalationPolicy(),
    )
    assert rep.escalated and rep.recovered and rep.failed_columns == ()
    assert rep.ladder  # at least one rung taken
    assert (_flags(rep.result) == 0).all()
    assert np.isfinite(np.asarray(rep.result.solution)).all()


def test_ladder_structured_failure_on_nan_rhs(well_posed):
    """A NaN RHS is unrescuable: every rung declines, the report says which
    columns failed, and the healthy columns keep their base payload."""
    op, b = well_posed
    base = solve(op, b, "cg", max_iters=40, tol=1e-5)
    rep = solve_robust(op, nan_columns(b, (2,)), "cg", max_iters=40, tol=1e-5)
    assert rep.escalated and not rep.recovered
    assert rep.failed_columns == (2,)
    assert _flags(rep.result)[2] & FLAG_NONFINITE
    np.testing.assert_array_equal(
        np.asarray(rep.result.solution[:, 0]), np.asarray(base.solution[:, 0])
    )


def test_ladder_switches_stochastic_family_to_cg(well_posed):
    """A flagged SGD solve walks jitter rungs then the switch:cg rung."""
    op, b = well_posed
    rep = solve_robust(
        op, nan_columns(b, (0,)), SGD(num_steps=40, batch_size=32),
        key=jax.random.PRNGKey(0),
        policy=EscalationPolicy(dense_fallback_max_n=0),
    )
    assert "switch:cg" in rep.ladder
    assert rep.failed_columns == (0,)  # NaN b defeats every rung — structured


def test_ladder_indefinite_unrescuable_is_structured():
    """Genuinely indefinite A (zero trace): no PSD jitter exists, the dense
    factorisation never holds — a structured failure, not an exception."""
    op = DenseOperator(a=jnp.diag(jnp.array([1.0, -1.0])))
    rep = solve_robust(op, jnp.ones((2, 1)), "cg", max_iters=10, tol=1e-6)
    assert rep.escalated and not rep.recovered
    assert rep.failed_columns == (0,)
    for r in rep.rungs:
        assert r.recovered == ()


def test_rung_records_are_auditable():
    op, b, _, _ = near_singular_problem(64, 2)
    rep = solve_robust(
        op, b, "cg", max_iters=100, tol=1e-6, stall_window=25,
    )
    assert rep.escalated
    for rec in rep.rungs:
        assert rec.columns  # every rung says what it attempted
        assert len(rec.flags_before) == len(rec.columns)
        assert all(
            isinstance(names, tuple) for names in rec.flag_names_before
        )
    # the matvec bill includes the rungs
    assert int(rep.result.matvecs) > 0


# ---------------------------------------------------------------------------
# scheduler layer: starvation guard + deadline expiry
# ---------------------------------------------------------------------------


def _req(i, kind, *, t=0.0, num=2, deadline=None):
    xs = jnp.zeros((4, 2)) if kind != "thompson_step" else None
    return Request(
        id=i, kind=kind, xs=xs, num_samples=num, seed=i, arrival=t,
        deadline=deadline,
    )


def test_scheduler_starvation_guard_promotes_skipped_request():
    """An over-skipped request is promoted to *be* the head — its group fixes
    the batch even when the true head belongs to a different group."""
    sched = FIFOScheduler(max_batch_requests=4, max_rhs_columns=8, max_skips=2)
    sched.add(_req(0, "predict"))
    starved = _req(1, "sample")
    starved.skips = 2  # at the threshold (pure FIFO keeps skips monotone
    # along the queue, so this state needs an external policy — the guard is
    # the invariant that bounds deferral under ANY such policy)
    sched.add(starved)
    plan = sched.next_batch()
    assert plan.group == "solve_cold"
    assert [r.id for r in plan.requests] == [1]
    # the passed-over predict kept its position and heads the next batch
    plan2 = sched.next_batch()
    assert plan2.group == "predict" and [r.id for r in plan2.requests] == [0]


def test_scheduler_fifo_wait_is_bounded():
    """Under pure FIFO evolution no request waits more than the queue length
    ahead of it: a skipped request's position advances every batch because the
    head is always consumed."""
    sched = FIFOScheduler(max_batch_requests=1, max_skips=16)
    sched.add(_req(0, "predict"))
    sched.add(_req(1, "sample"))
    sched.add(_req(2, "predict"))
    groups = [sched.next_batch().group for _ in range(3)]
    assert groups == ["predict", "solve_cold", "predict"]
    assert len(sched) == 0


def test_scheduler_expire_removes_past_deadline():
    sched = FIFOScheduler()
    sched.add(_req(0, "predict", deadline=1.0))
    sched.add(_req(1, "predict", deadline=5.0))
    sched.add(_req(2, "predict"))  # no deadline: never expires
    gone = sched.expire(now=2.0)
    assert [r.id for r in gone] == [0]
    assert len(sched) == 2
    assert sched.expire(now=2.0) == []


# ---------------------------------------------------------------------------
# engine layer: isolation, rescue, quarantine, shedding, retry
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_problem():
    key = jax.random.PRNGKey(5)
    x = jax.random.uniform(key, (64, 2))
    y = jnp.sin(3.0 * x[:, 0]) + 0.1 * jax.random.normal(key, (64,))
    params = make_params("se", lengthscale=0.5, signal=1.0, noise=1e-2)
    return params, x, y


def _mk_engine(engine_problem, **kw):
    params, x, y = engine_problem
    kw.setdefault("spec", CG(max_iters=80, tol=1e-5))
    kw.setdefault("num_features", 256)
    kw.setdefault("num_samples", 8)
    return GPEngine(params, x, y, **kw)


def test_engine_rescues_transient_fault_and_isolates(engine_problem):
    """Batch column 3 is poisoned by a width-gated matvec fault (it vanishes
    on solo re-runs). The affected request is rescued through the ladder; the
    *other* request's payload is bit-identical to a fault-free engine."""
    faulty = _mk_engine(
        engine_problem,
        operator_transform=lambda op: FaultyOperator(
            op, columns=(3,), min_width=5
        ),
    )
    clean = _mk_engine(engine_problem)
    hs_f = [faulty.sample(faulty.state.x[:6], num_samples=2, seed=s) for s in (1, 2)]
    hs_c = [clean.sample(clean.state.x[:6], num_samples=2, seed=s) for s in (1, 2)]
    faulty.run_until_idle()
    clean.run_until_idle()
    for h in hs_f:
        assert h.result().ok
    np.testing.assert_array_equal(  # bystander request: exact parity
        np.asarray(hs_f[0].result().value["samples"]),
        np.asarray(hs_c[0].result().value["samples"]),
    )
    assert np.isfinite(np.asarray(hs_f[1].result().value["samples"])).all()
    st = faulty.stats()
    assert st["escalations"] == 1 and st["failed"] == 0


def test_engine_fails_structurally_without_escalation(engine_problem):
    eng = _mk_engine(
        engine_problem,
        escalation=None,
        operator_transform=lambda op: FaultyOperator(op, columns=(0,)),
    )
    h = eng.sample(eng.state.x[:4], num_samples=2, seed=1)
    eng.run_until_idle()
    res = h.result()
    assert not res.ok and res.error["code"] == "solver_failure"
    assert "nonfinite" in res.error["message"]
    assert eng.stats()["escalations"] == 0


def test_engine_quarantines_repeat_offender(engine_problem):
    """A persistently poisoned RHS (faulty feature map) fails its rescue every
    time; after quarantine_after strikes the (kind, seed) identity is refused
    at submit, without touching another batch."""
    import dataclasses

    eng = _mk_engine(engine_problem, quarantine_after=2)
    eng.state.post = dataclasses.replace(
        eng.state.post, prior=FaultyFeatureOperator(eng.state.prior, columns=(0,))
    )
    for _ in range(2):
        h = eng.sample(eng.state.x[:4], num_samples=2, seed=77)
        eng.run_until_idle()
        res = h.result()
        assert not res.ok and res.error["code"] == "solver_failure"
        assert res.error["rungs"]  # the ladder was tried and recorded
    h3 = eng.sample(eng.state.x[:4], num_samples=2, seed=77)
    res3 = h3.result()  # completed at submit — no step needed
    assert not res3.ok and res3.error["code"] == "quarantined"
    st = eng.stats()
    assert st["quarantined"] == 1 and st["escalations"] == 2
    assert st["failed"] == 3
    # a fresh seed still hits the poisoned column 0 of its own batch, but it
    # is NOT pre-quarantined: isolation is per-identity, not global
    h4 = eng.sample(eng.state.x[:4], num_samples=2, seed=78)
    eng.run_until_idle()
    assert h4.result().error["code"] == "solver_failure"


def test_engine_deadline_and_overload(engine_problem):
    eng = _mk_engine(
        engine_problem, max_queue_depth=2, overload_policy="degrade"
    )
    xs = eng.state.x[:4]
    h_exp = eng.sample(xs, num_samples=2, deadline_s=-1.0)  # already late
    eng.predict(xs)
    hd = eng.sample(xs, num_samples=2)  # depth 2 hit → degraded to predict
    assert hd.request.kind == "predict"
    with pytest.raises(EngineOverloaded):
        eng.thompson_step(num_samples=2)  # not degradable → shed
    eng.run_until_idle()
    assert h_exp.result().error["code"] == "deadline_exceeded"
    assert hd.result().ok and hd.result().metrics["degraded"] is True
    st = eng.stats()
    assert st["deadline_misses"] == 1 and st["shed"] == 1 and st["degraded"] == 1


def test_engine_reject_policy(engine_problem):
    eng = _mk_engine(
        engine_problem, max_queue_depth=1, overload_policy="reject"
    )
    eng.predict(eng.state.x[:4])
    with pytest.raises(EngineOverloaded):
        eng.predict(eng.state.x[:4])
    eng.run_until_idle()
    assert eng.stats()["shed"] == 1


def test_engine_retries_then_fails_raising_batch(engine_problem):
    """A batch whose execution *raises* is retried with backoff, then every
    rider completes with exec_error — the engine loop survives."""
    def boom(op):
        raise RuntimeError("injected dispatch failure")

    eng = _mk_engine(
        engine_problem, operator_transform=boom,
        max_exec_retries=1, retry_backoff_s=0.0,
    )
    h1 = eng.sample(eng.state.x[:4], num_samples=2, seed=1)
    h2 = eng.sample(eng.state.x[:4], num_samples=2, seed=2)
    eng.run_until_idle()
    for h in (h1, h2):
        res = h.result()
        assert not res.ok and res.error["code"] == "exec_error"
        assert "injected dispatch failure" in res.error["message"]
    st = eng.stats()
    assert st["retries"] == 1 and st["failed"] == 2
    # the engine still serves afterwards (predicts bypass the solve transform)
    hp = eng.predict(eng.state.x[:4])
    eng.run_until_idle()
    assert hp.result().ok


def test_flag_names_roundtrip():
    assert flag_names(0) == ()
    assert flag_names(FLAG_NONFINITE | FLAG_STAGNATION) == (
        "nonfinite", "stagnation",
    )
    assert "breakdown" in flag_names(FROZEN_FLAGS)
