"""Property-based guardrail test: no solver family ever returns a silently
poisoned result, on randomly generated near-singular / badly scaled systems.

The invariant (docs/robustness.md): for every RHS column, the returned
solution is finite OR the column carries a freezing flag — and warm-starting
from any previous solution preserves it. Skipped when hypothesis is not
installed (it is not a repo dependency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import FROZEN_FLAGS, Gram, make_params, solve  # noqa: E402

FAMILIES = {
    "cg": dict(max_iters=60, tol=1e-5, stall_window=25),
    "sgd": dict(num_steps=150, batch_size=16),
    "sdd": dict(num_steps=150, batch_size=16, step_size_times_n=1.0),
    "ap": dict(num_steps=60, block_size=16),
}


def _problem(seed, n, dup, log_noise, log_ls, scale):
    """A Gram system whose conditioning is driven by the draw: duplicated
    rows (rank deficiency), tiny noise, extreme lengthscales, badly scaled b."""
    key = jax.random.PRNGKey(seed)
    kx, kb = jax.random.split(key)
    base = jax.random.uniform(kx, (n, 2))
    if dup:
        half = base[: n // 2]
        base = jnp.concatenate([half, half], axis=0)[:n]
    params = make_params(
        "se", lengthscale=10.0 ** log_ls, signal=1.0, noise=10.0 ** log_noise
    )
    b = jax.random.normal(kb, (n, 2)) * (10.0 ** scale)
    return Gram(x=base, params=params), b


@pytest.mark.parametrize("family", sorted(FAMILIES))
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.sampled_from([24, 48]),
    dup=st.booleans(),
    log_noise=st.sampled_from([-8, -4, -1]),
    log_ls=st.sampled_from([-2, 0, 2]),
    scale=st.sampled_from([-6, 0, 6]),
)
def test_no_silent_poison(family, seed, n, dup, log_noise, log_ls, scale):
    op, b = _problem(seed, n, dup, log_noise, log_ls, scale)
    kw = FAMILIES[family]
    res = solve(op, b, family, key=jax.random.PRNGKey(seed), **kw)
    sol = np.asarray(jax.device_get(res.solution))
    fl = np.atleast_1d(np.asarray(jax.device_get(res.flags))).astype(np.int64)
    finite = np.isfinite(sol).all(axis=0)
    frozen = (fl & FROZEN_FLAGS) != 0
    assert (finite | frozen).all(), (
        f"{family}: non-finite column without a freezing flag "
        f"(flags={fl.tolist()})"
    )
    # converged never co-exists with a flagged column
    if bool(res.converged):
        assert (fl == 0).all()
    # warm-starting from this result preserves the invariant (poisoned x0 is
    # caught at initialisation, finite x0 just restarts)
    x0 = jnp.asarray(np.nan_to_num(sol, nan=np.nan))  # keep NaN as-is
    res2 = solve(op, b, family, key=jax.random.PRNGKey(seed + 1), x0=x0, **kw)
    sol2 = np.asarray(jax.device_get(res2.solution))
    fl2 = np.atleast_1d(np.asarray(jax.device_get(res2.flags))).astype(np.int64)
    assert (np.isfinite(sol2).all(axis=0) | ((fl2 & FROZEN_FLAGS) != 0)).all()
