"""Serving engine + multi-RHS batching (src/repro/serve, solve_batched).

Two layers of guarantees:

* solver layer — ``solve_batched`` coalesces per-consumer RHS blocks into one
  multi-RHS solve whose per-block solutions match independent single-block
  solves (CG freezes converged columns; the stochastic solvers' column updates
  are independent given the shared key), while spending ONE solve's worth of
  matvecs for the whole batch;
* engine layer — FIFO fairness, bucket-padding correctness, warm-vs-cold
  iteration reduction, determinism under interleaved arrival orders, and the
  ``stats()`` counter contract the benchmark relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params
from repro.core.operators import Gram
from repro.core.rff import PriorSamples
from repro.core.solvers.spec import AP, CG, SDD, SGD, solve, solve_batched
from repro.serve import (
    FIFOScheduler,
    GPEngine,
    Request,
    bucket,
    extend_state,
    fit_state,
    percentile,
    update_state_lowrank,
)


@pytest.fixture(scope="module")
def small_problem():
    key = jax.random.PRNGKey(0)
    n, d = 96, 2
    x = jax.random.uniform(key, (n, d))
    y = jnp.sin(4.0 * x[:, 0]) + 0.5 * jnp.cos(3.0 * x[:, 1])
    params = make_params("matern32", lengthscale=0.5, signal=1.0, noise=0.1, d=d)
    return dict(x=x, y=y, params=params, n=n, d=d)


@pytest.fixture(scope="module")
def op(small_problem):
    return Gram(x=small_problem["x"], params=small_problem["params"])


def _rhs_blocks(small_problem):
    key = jax.random.PRNGKey(3)
    n = small_problem["n"]
    b1 = small_problem["y"]  # (n,) 1-D block
    b2 = jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
    b3 = jax.random.normal(jax.random.fold_in(key, 2), (n, 2))
    return [b1, b2, b3]


# ---------------------------------------------------------------------------
# solve_batched: stacked RHS columns match independent solves, for every
# solver family, with shared matvec accounting
# ---------------------------------------------------------------------------


# CG compares at its convergence floor: a column that converges before the
# batch's slowest column freezes, so its trajectory can differ from the solo
# run's by (solver tolerance)-level float32 drift. The fixed-step stochastic
# solvers share their index sequences through the key and match near-exactly.
@pytest.mark.parametrize(
    "spec",
    [
        CG(max_iters=300, tol=1e-4),
        SGD(num_steps=60, batch_size=32, num_features=32),
        SDD(num_steps=60, batch_size=32, step_size_times_n=5.0),
        AP(num_steps=80, block_size=32),
    ],
    ids=["cg", "sgd", "sdd", "ap"],
)
def test_solve_batched_matches_single_solves(op, small_problem, spec):
    blocks = _rhs_blocks(small_problem)
    key = jax.random.PRNGKey(11)
    batched = solve_batched(op, blocks, spec, key=key)
    assert len(batched) == len(blocks)
    total_single_matvecs = 0
    for blk, res in zip(blocks, batched):
        solo = solve(op, blk, spec, key=key)
        np.testing.assert_allclose(
            np.asarray(res.solution), np.asarray(solo.solution),
            rtol=1e-2, atol=1e-3,
        )
        assert res.solution.shape == solo.solution.shape  # squeeze preserved
        total_single_matvecs += int(solo.matvecs)
    # the whole batch spends ONE solve's worth of full-operator matvecs —
    # every block's result reports the same shared totals
    shared = {(int(r.iterations), int(r.matvecs)) for r in batched}
    assert len(shared) == 1
    assert int(batched[0].matvecs) <= total_single_matvecs


def test_solve_batched_column_padding_is_inert(op, small_problem):
    blocks = _rhs_blocks(small_problem)
    spec = CG(max_iters=300, tol=1e-4)
    plain = solve_batched(op, blocks, spec)
    padded = solve_batched(op, blocks, spec, pad_columns_to=16)
    for a, b in zip(plain, padded):
        # padding changes the compiled matvec width, so agreement is at the
        # solver-tolerance level, not bitwise
        np.testing.assert_allclose(
            np.asarray(a.solution), np.asarray(b.solution), rtol=2e-2, atol=2e-2
        )
        assert bool(b.converged)


def test_solve_batched_mixed_warm_cold_blocks(op, small_problem):
    blocks = _rhs_blocks(small_problem)
    spec = CG(max_iters=300, tol=1e-4)
    cold = solve_batched(op, blocks, spec)
    warm = solve_batched(
        op, blocks, spec,
        x0_blocks=[cold[0].solution, None, cold[2].solution],
    )
    for a, b in zip(cold, warm):
        np.testing.assert_allclose(
            np.asarray(a.solution), np.asarray(b.solution), rtol=2e-2, atol=2e-2
        )
    # warm columns are already converged: the batch's budget is the cold block's
    assert int(warm[0].iterations) <= int(cold[0].iterations)


# ---------------------------------------------------------------------------
# x0 validation at the solve() boundary
# ---------------------------------------------------------------------------


def test_x0_shape_mismatch_is_a_clear_error(op, small_problem):
    y = small_problem["y"]
    with pytest.raises(ValueError, match="warm start x0"):
        solve(op, jnp.stack([y, y], axis=1), "cg", x0=y)  # 1-D x0, 2-column b
    with pytest.raises(ValueError, match="stale warm-start"):
        solve(op, y, "cg", x0=y[:-1])  # old-n cache entry


def test_x0_dtype_mismatch_is_a_clear_error(op, small_problem):
    y = small_problem["y"]
    with pytest.raises(TypeError, match="dtype"):
        solve(op, y, "cg", x0=y.astype(jnp.float16))


def test_x0_matching_shape_still_accepted(op, small_problem):
    y = small_problem["y"]
    sol = solve(op, y, CG(max_iters=200, tol=1e-4)).solution
    res = solve(op, y, CG(max_iters=200, tol=1e-4), x0=sol)
    assert int(res.iterations) <= 2  # re-verifying a solution is nearly free


# ---------------------------------------------------------------------------
# scheduler: grouping, caps, FIFO with position-preserving skips
# ---------------------------------------------------------------------------


def _req(i, kind, rows=4, cols=4, warm=False):
    xs = None if kind == "thompson_step" else jnp.zeros((rows, 2))
    return Request(
        id=i, kind=kind, xs=xs, num_samples=cols, seed=i, arrival=float(i),
        warm=warm,
    )


def test_scheduler_coalesces_compatible_and_preserves_positions():
    sched = FIFOScheduler(max_batch_requests=8, max_rhs_columns=64)
    sched.add(_req(0, "sample"))
    sched.add(_req(1, "predict"))
    sched.add(_req(2, "thompson_step"))  # solve group: joins request 0
    sched.add(_req(3, "sample", warm=True))  # warm never mixes with cold
    plan = sched.next_batch()
    assert [r.id for r in plan.requests] == [0, 2]
    assert plan.group == "solve_cold"
    # skipped requests keep arrival order: predict is now head-of-line
    assert sched.next_batch().group == "predict"
    assert sched.next_batch().group == "solve_warm"
    assert sched.next_batch() is None


def test_scheduler_respects_column_cap():
    sched = FIFOScheduler(max_batch_requests=8, max_rhs_columns=8)
    for i in range(3):
        sched.add(_req(i, "sample", cols=4))
    plan = sched.next_batch()
    assert [r.id for r in plan.requests] == [0, 1]  # 8 columns — third waits
    assert [r.id for r in sched.next_batch().requests] == [2]
    with pytest.raises(ValueError, match="RHS columns"):
        sched.add(_req(9, "sample", cols=9))


def test_bucket_ladder():
    assert bucket(1, 16) == 16
    assert bucket(17, 16) == 32
    assert bucket(5, 1) == 8  # next pow2
    assert bucket(8, 8) == 8


# ---------------------------------------------------------------------------
# engine: lifecycle, padding correctness, warm starts, determinism, stats
# ---------------------------------------------------------------------------


def _engine(small_problem, **kw):
    kw.setdefault("spec", CG(max_iters=300, tol=1e-4))
    kw.setdefault("num_samples", 4)
    kw.setdefault("num_features", 128)
    return GPEngine(
        small_problem["params"], small_problem["x"], small_problem["y"], **kw
    )


def test_predict_padding_matches_direct_evaluation(small_problem):
    eng = _engine(small_problem)
    xs = small_problem["x"][:5] + 0.01  # odd row count → real bucket padding
    h = eng.predict(xs)
    eng.step()
    mean, var = h.result().value["mean"], h.result().value["var"]
    mean_ref, var_ref = eng.state.post.sample_mean_and_var(xs)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), atol=1e-5)


def test_fifo_completion_order_and_batching(small_problem):
    eng = _engine(small_problem)
    xs = small_problem["x"][:3]
    ids = [
        eng.sample(xs, num_samples=2, seed=1).request.id,
        eng.predict(xs).request.id,
        eng.sample(xs, num_samples=2, seed=2).request.id,
    ]
    first = eng.step()  # head is a cold sample → both samples coalesce
    assert [c.request_id for c in first] == [ids[0], ids[2]]
    assert first[0].metrics["batch_columns"] == 4
    assert first[0].metrics["iterations"] == first[1].metrics["iterations"]
    second = eng.step()
    assert [c.request_id for c in second] == [ids[1]]


def test_warm_repeat_uses_fewer_iterations(small_problem):
    eng = _engine(small_problem)
    xs = small_problem["x"][:4]
    cold = eng.sample(xs, num_samples=4, seed=77)
    eng.run_until_idle()
    warm = eng.sample(xs, num_samples=4, seed=77)
    assert warm.request.warm
    eng.run_until_idle()
    cold_iters = cold.result().metrics["iterations"]
    warm_iters = warm.result().metrics["iterations"]
    assert warm_iters < cold_iters
    np.testing.assert_allclose(
        np.asarray(cold.result().value["samples"]),
        np.asarray(warm.result().value["samples"]),
        rtol=1e-4, atol=1e-5,
    )
    snap = eng.stats()
    assert snap["warm_hits"] == 1
    assert snap["iterations_saved_warm"] > 0


def test_deterministic_under_interleaved_arrival_orders(small_problem):
    xs_a = small_problem["x"][:5]
    xs_b = small_problem["x"][5:8]

    eng1 = _engine(small_problem)  # both samples coalesce into one solve
    h1a = eng1.sample(xs_a, num_samples=3, seed=101)
    h1b = eng1.sample(xs_b, num_samples=2, seed=202)
    eng1.run_until_idle()

    eng2 = _engine(small_problem)  # a predict interleaves; solves split
    h2b = eng2.sample(xs_b, num_samples=2, seed=202)
    eng2.step()
    eng2.predict(xs_a)
    h2a = eng2.sample(xs_a, num_samples=3, seed=101)
    eng2.run_until_idle()

    for ha, hb in ((h1a, h2a), (h1b, h2b)):
        np.testing.assert_allclose(
            np.asarray(ha.result().value["samples"]),
            np.asarray(hb.result().value["samples"]),
            rtol=1e-4, atol=1e-5,
        )


def test_row_and_column_buckets_do_not_change_payloads(small_problem):
    eng1 = _engine(small_problem, row_bucket_min=16, col_bucket_min=8)
    eng2 = _engine(small_problem, row_bucket_min=4, col_bucket_min=2)
    xs = small_problem["x"][:5]
    h1 = eng1.sample(xs, num_samples=3, seed=5)
    h2 = eng2.sample(xs, num_samples=3, seed=5)
    eng1.run_until_idle()
    eng2.run_until_idle()
    np.testing.assert_allclose(
        np.asarray(h1.result().value["samples"]),
        np.asarray(h2.result().value["samples"]),
        rtol=1e-4, atol=1e-5,
    )


def test_thompson_step_returns_in_bounds_points(small_problem):
    eng = _engine(small_problem)
    h = eng.thompson_step(num_samples=3, seed=4, ascent_steps=5, num_candidates=64)
    eng.run_until_idle()
    pts = np.asarray(h.result().value["points"])
    assert pts.shape == (3, small_problem["d"])
    assert (pts >= 0.0).all() and (pts <= 1.0).all()
    assert h.result().value["values"].shape == (3,)


def test_engine_stats_counters_and_handles(small_problem):
    eng = _engine(small_problem)
    with pytest.raises(ValueError, match="unknown request kind"):
        eng.submit("decode", small_problem["x"][:2])
    with pytest.raises(ValueError, match="xs must be None"):
        eng.submit("thompson_step", small_problem["x"][:2])
    h = eng.sample(small_problem["x"][:2], num_samples=2, seed=1)
    with pytest.raises(RuntimeError, match="still queued"):
        h.result()
    eng.predict(small_problem["x"][:3])
    eng.run_until_idle()
    snap = eng.stats()
    assert snap["requests_submitted"] == 2
    assert snap["requests_served"] == {"sample": 1, "predict": 1}
    assert snap["rhs_columns"] == 2
    assert snap["padded_columns"] == 6  # bucketed up to col_bucket_min=8
    assert snap["solves"] == 1
    assert snap["queue_depth"] == 0
    assert snap["solver"] == "cg"
    assert snap["predict_rows"] == 3


def test_add_observations_warm_refit_saves_iterations(small_problem):
    key = jax.random.PRNGKey(9)
    st = fit_state(
        small_problem["params"], small_problem["x"], small_problem["y"],
        key, spec=CG(max_iters=300, tol=1e-4), num_samples=4, num_features=128,
    )
    x_new = small_problem["x"][:6] + 0.02
    y_new = small_problem["y"][:6]
    k2 = jax.random.PRNGKey(10)
    warm = extend_state(st, x_new, y_new, k2, warm=True)
    cold = extend_state(st, x_new, y_new, k2, warm=False)
    assert int(warm.fit_result.iterations) < int(cold.fit_result.iterations)
    np.testing.assert_allclose(
        np.asarray(warm.post.v_mean), np.asarray(cold.post.v_mean),
        rtol=2e-2, atol=2e-2,
    )
    # engine-level: the refit counters move and the cache re-keys (old entries
    # are unreachable under the new fingerprint, so no stale-x0 shape errors)
    eng = _engine(small_problem)
    eng.sample(small_problem["x"][:2], num_samples=2, seed=1)
    eng.run_until_idle()
    old_key = eng.state.hypers_key
    eng.add_observations(x_new, y_new)
    assert eng.state.hypers_key != old_key
    assert eng.state.n == small_problem["n"] + 6
    assert eng.stats()["refits"] == 1
    repeat = eng.sample(small_problem["x"][:2], num_samples=2, seed=1)
    assert not repeat.request.warm  # cache is keyed by (hypers, n): re-keyed
    eng.run_until_idle()

# ---------------------------------------------------------------------------
# percentile: nearest-rank definition (regression for round-half-even bias)
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 50) == 0.0
    # N=1: every quantile is the single value
    for q in (0, 50, 99, 100):
        assert percentile([5.0], q) == 5.0
    # N=2: p50 is the 1st order statistic (⌈1.0⌉), anything above picks the 2nd
    assert percentile([2.0, 1.0], 0) == 1.0
    assert percentile([2.0, 1.0], 50) == 1.0
    assert percentile([2.0, 1.0], 51) == 2.0
    assert percentile([2.0, 1.0], 99) == 2.0
    # N=4: p50 is the 2nd smallest — int(round(...)) used to pick the 3rd
    assert percentile([4.0, 1.0, 3.0, 2.0], 50) == 2.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 100) == 4.0
    # N=100: p50 is the 50th order statistic — the old rounding picked the 51st
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0


# ---------------------------------------------------------------------------
# rank-k incremental updates: parity, cost accounting, prior-row economy,
# engine policies, compaction, interleaved writes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def update_problem():
    key = jax.random.PRNGKey(21)
    n, k, d = 64, 5, 2
    x = jax.random.uniform(key, (n + k, d))
    y = jnp.sin(4.0 * x[:, 0]) + 0.5 * jnp.cos(3.0 * x[:, 1])
    params = make_params("matern32", lengthscale=0.5, signal=1.0, noise=0.5, d=d)
    xt = jax.random.uniform(jax.random.PRNGKey(22), (20, d))
    return dict(x=x, y=y, params=params, n=n, k=k, xt=xt)


@pytest.mark.parametrize(
    "spec,parity_tol",
    [
        (CG(max_iters=400, tol=1e-6), 1e-4),
        (SGD(num_steps=2000, batch_size=32, num_features=64), 5e-2),
    ],
    ids=["cg", "sgd"],
)
def test_lowrank_update_matches_full_refit(update_problem, spec, parity_tol):
    """The bordered correction and the full row-extension refit extend the SAME
    linear system at matching seeds (shared draw convention), so their
    posteriors agree to solver accuracy. CG converges, so parity meets the
    1e-4 incremental-update budget outright. SGD sits at its stochastic
    optimisation floor (~0.1 relative residual — constant-step gradient noise,
    cf. test_sgd_variance_reduced_objective's documented atol): there the
    guarantees are that the bordered algebra does not AMPLIFY the solver's own
    error, and that the certification matvec reports the drift honestly
    (converged=False), which is exactly what the engine's auto policy uses to
    compact instead of silently serving a drifted posterior."""
    t = update_problem
    n = t["n"]
    st = fit_state(
        t["params"], t["x"][:n], t["y"][:n], jax.random.PRNGKey(2),
        spec=spec, num_samples=4, num_features=128,
    )
    ukey = jax.random.PRNGKey(3)
    lo = update_state_lowrank(st, t["x"][n:], t["y"][n:], ukey)
    fu = extend_state(st, t["x"][n:], t["y"][n:], ukey, warm=True)
    ml, vl = lo.post.sample_mean_and_var(t["xt"])
    mf, vf = fu.post.sample_mean_and_var(t["xt"])
    np.testing.assert_allclose(np.asarray(ml), np.asarray(mf), atol=parity_tol)
    np.testing.assert_allclose(np.asarray(vl), np.asarray(vf), atol=parity_tol)
    np.testing.assert_allclose(
        np.asarray(lo.post.mean(t["xt"])), np.asarray(fu.post.mean(t["xt"])),
        atol=parity_tol,
    )
    drift = float(jnp.max(lo.fit_result.rel_residual))
    assert bool(lo.fit_result.healthy)
    if isinstance(spec, CG):
        assert drift <= 1e-4  # certified against the extended operator
    else:
        assert drift <= 2.0 * float(jnp.max(fu.fit_result.rel_residual))
        assert not bool(lo.fit_result.converged)  # auto policy sees the floor


def test_lowrank_update_solves_only_k_columns(small_problem):
    """Cost accounting: the rank-k path spends its iterations on k correction
    columns against the OLD n-operator plus exactly ONE certification matvec
    of the extended operator — strictly below the warm full refit's spend on
    the same update, which re-solves all 1+s columns at n+k."""
    st = fit_state(
        small_problem["params"], small_problem["x"], small_problem["y"],
        jax.random.PRNGKey(9), spec=CG(max_iters=300, tol=1e-4),
        num_samples=4, num_features=128,
    )
    x_new = small_problem["x"][:6] + 0.02
    y_new = small_problem["y"][:6]
    ukey = jax.random.PRNGKey(10)
    lo = update_state_lowrank(st, x_new, y_new, ukey)
    fu = extend_state(st, x_new, y_new, ukey, warm=True)
    # CG: matvecs == iterations, + the one certification matvec
    assert int(lo.fit_result.matvecs) == int(lo.fit_result.iterations) + 1
    assert int(lo.fit_result.iterations) < int(fu.fit_result.iterations)
    assert int(lo.fit_result.matvecs) < int(fu.fit_result.matvecs)
    assert lo.n == st.n + 6
    # certified drift lands inside the engine's default auto budget (4× tol)
    assert float(jnp.max(lo.fit_result.rel_residual)) <= 4.0 * 1e-4


def test_incremental_updates_evaluate_prior_on_new_rows_only(
    small_problem, monkeypatch
):
    """Both incremental paths reuse the cached ``f_x`` rows: the prior paths
    are evaluated on the k NEW rows only, never re-run over all n old rows
    (the fused feature pass is the other O(n) cost a rank-k update avoids)."""
    st = fit_state(
        small_problem["params"], small_problem["x"], small_problem["y"],
        jax.random.PRNGKey(9), spec=CG(max_iters=300, tol=1e-4),
        num_samples=4, num_features=128,
    )
    x_new = small_problem["x"][:6] + 0.02
    y_new = small_problem["y"][:6]
    rows_seen = []
    orig_call = PriorSamples.__call__

    def spy(self, xs):
        rows_seen.append(int(jnp.asarray(xs).shape[0]))
        return orig_call(self, xs)

    monkeypatch.setattr(PriorSamples, "__call__", spy)
    lo = update_state_lowrank(st, x_new, y_new, jax.random.PRNGKey(10))
    fu = extend_state(st, x_new, y_new, jax.random.PRNGKey(10), warm=True)
    assert rows_seen and max(rows_seen) == 6, rows_seen
    # the cached rows carried over bit-exactly; only the tail is fresh
    np.testing.assert_array_equal(np.asarray(lo.f_x[:96]), np.asarray(st.f_x))
    np.testing.assert_array_equal(np.asarray(fu.f_x[:96]), np.asarray(st.f_x))


def test_engine_update_policies_and_cache_purge(small_problem):
    x_new = small_problem["x"][:6] + 0.02
    y_new = small_problem["y"][:6]
    with pytest.raises(ValueError, match="update_policy"):
        _engine(small_problem, update_policy="bogus")

    eng = _engine(small_problem)  # default auto
    with pytest.raises(ValueError, match="update must be"):
        eng.add_observations(x_new, y_new, update="bogus")
    eng.sample(small_problem["x"][:2], num_samples=2, seed=1)
    eng.run_until_idle()
    assert eng.stats()["warm_cache_entries"] == 1
    eng.add_observations(x_new, y_new)  # auto: drift within budget → lowrank
    snap = eng.stats()
    assert snap["refits"] == 1
    assert snap["lowrank_updates"] == 1
    assert snap["lowrank_rows"] == 6
    assert snap["compactions"] == 0
    assert snap["refit_iterations"] == 0  # no full solve ran
    assert snap["lowrank_matvecs"] == snap["lowrank_iterations"] + 1
    assert 0.0 < snap["last_refit_rel_residual"] <= 4.0 * 1e-4
    assert snap["n"] == small_problem["n"] + 6
    # the re-key made the old cache entry unreachable — it was purged, and the
    # post-update engine still serves correctly-shaped, finite payloads
    assert snap["cache_purged"] == 1
    assert snap["warm_cache_entries"] == 0
    h = eng.predict(small_problem["x"][:3])
    eng.run_until_idle()
    assert np.isfinite(np.asarray(h.result().value["mean"])).all()

    # update="full" on an auto engine forces the refit path for one call
    eng2 = _engine(small_problem)
    eng2.add_observations(x_new, y_new, update="full")
    snap2 = eng2.stats()
    assert snap2["refits"] == 1
    assert snap2["lowrank_updates"] == 0
    assert snap2["refit_iterations"] > 0


def test_engine_compaction_trigger(small_problem):
    """A drift budget below the lowrank path's per-update certified residual
    forces the auto fallback: the engine re-solves in full (compaction), and
    the resulting state is certified at the spec tolerance."""
    x_new = small_problem["x"][:6] + 0.02
    y_new = small_problem["y"][:6]
    eng = _engine(small_problem, compaction_tol_factor=1.0)
    eng.add_observations(x_new, y_new)  # certified drift ~1.5× tol > 1× tol
    snap = eng.stats()
    assert snap["compactions"] == 1
    assert snap["lowrank_updates"] == 0
    assert snap["refits"] == 1
    assert snap["refit_iterations"] > 0  # the fallback full refit ran
    assert snap["last_refit_rel_residual"] <= 1e-4
    assert bool(eng.state.fit_result.converged)
    assert eng.state.n == small_problem["n"] + 6


def test_interleaved_writes_fifo_and_bystanders(small_problem):
    """Write-heavy interleaving: ``add_observations`` drains the queue first,
    so a request submitted before the write is served against the state it was
    submitted under — bit-exact with a write-free engine — and post-write
    requests preserve FIFO semantics against the updated state."""
    xs = small_problem["x"][:3]
    x_new = small_problem["x"][:4] + 0.03
    y_new = small_problem["y"][:4]

    writer = _engine(small_problem)
    bystander = _engine(small_problem)
    hw = writer.sample(xs, num_samples=2, seed=11)
    hb = bystander.sample(xs, num_samples=2, seed=11)
    writer.add_observations(x_new, y_new)  # drains hw against pre-write state
    bystander.run_until_idle()
    np.testing.assert_array_equal(
        np.asarray(hw.result().value["samples"]),
        np.asarray(hb.result().value["samples"]),
    )

    # post-write: FIFO coalescing still holds on the updated state
    ids = [
        writer.sample(xs, num_samples=2, seed=21).request.id,
        writer.predict(xs).request.id,
        writer.sample(xs, num_samples=2, seed=22).request.id,
    ]
    first = writer.step()
    assert [c.request_id for c in first] == [ids[0], ids[2]]
    second = writer.step()
    assert [c.request_id for c in second] == [ids[1]]
    for comp in (*first, *second):
        assert comp.ok
        assert all(
            np.isfinite(np.asarray(v)).all() for v in comp.value.values()
        )
    # a second write interleaves just as well (alternating write/read traffic)
    writer.add_observations(x_new + 0.05, y_new)
    h2 = writer.sample(xs, num_samples=2, seed=21)
    writer.run_until_idle()
    assert not h2.request.warm  # both writes re-keyed the cache
    assert writer.state.n == small_problem["n"] + 8
    assert np.isfinite(np.asarray(h2.result().value["samples"])).all()


def test_refit_savings_rebaseline(small_problem):
    """``refit_iterations_saved`` credits warm refits against the most recent
    COLD fit-system solve; a ``warm=False`` refit re-baselines (n and
    iterations), so savings are never measured against a stale smaller-n
    reference."""
    eng = _engine(small_problem)
    snap0 = eng.stats()
    assert snap0["refit_baseline_n"] == small_problem["n"]
    assert snap0["refit_baseline_iters"] == int(eng.state.fit_result.iterations)

    x1 = small_problem["x"][:4] + 0.02
    y1 = small_problem["y"][:4]
    eng.add_observations(x1, y1, update="full", warm=False)
    snap1 = eng.stats()
    cold_iters = int(eng.state.fit_result.iterations)
    assert snap1["refit_baseline_n"] == small_problem["n"] + 4
    assert snap1["refit_baseline_iters"] == cold_iters
    assert snap1["refit_iterations_saved"] == 0  # cold refits never credit

    eng.add_observations(x1 + 0.05, y1, update="full", warm=True)
    snap2 = eng.stats()
    warm_iters = snap2["refit_iterations"] - cold_iters
    assert snap2["refit_baseline_n"] == small_problem["n"] + 4  # unchanged
    assert snap2["refit_iterations_saved"] == max(0, cold_iters - warm_iters)
