"""Sharding rules, HLO analyzer, and distributed GP solver (subprocess: multi-device)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import evenize_spec, spec_for_axes
from repro.models.sharding_ctx import rules_to_spec


def test_spec_dedup_mesh_axes():
    mesh = make_host_mesh()
    spec = spec_for_axes(("layers", "experts", "embed", "mlp"), mesh)
    flat = [a for s in spec if s for a in ((s,) if isinstance(s, str) else s)]
    assert len(flat) == len(set(flat))
    # experts (higher priority) takes "model"; mlp must not repeat it
    assert spec[1] == "model" and spec[3] is None


def test_rules_to_spec_dedup():
    spec = rules_to_spec(
        {"batch": "data", "experts_act": "model", "mlp_act": "model"},
        ("batch", "experts_act", None, "mlp_act"),
    )
    assert spec == PartitionSpec("data", "model", None, None)


def test_evenize_drops_nondividing_axes():
    mesh = make_host_mesh()  # (1,1): everything divides — identity
    s = evenize_spec(PartitionSpec("data", None), (7, 3), mesh)
    assert s == PartitionSpec("data", None)


def test_evenize_drops_on_16x16():
    import os
    # simulate: 16×16 shapes via a fake mesh object
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    s = evenize_spec(PartitionSpec("model", "data"), (50280, 2048), FakeMesh())
    assert s == PartitionSpec(None, "data")  # 50280 % 16 != 0 → dropped
    s2 = evenize_spec(PartitionSpec("model", None), (50304, 2048), FakeMesh())
    assert s2 == PartitionSpec("model", None)


HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %ag = f32[8,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %w = f32[64,16]{1,0} constant({...})
  %dot = f32[8,16]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %dot)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%z, %a)
  %wh = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_hlo_analyzer_loop_multipliers():
    prof = analyze_hlo(HLO_SAMPLE)
    # dot: 2·8·16·64 = 16384 flops × 10 iterations
    assert prof.flops == 10 * 2 * 8 * 16 * 64
    # all-gather operand f32[8,16] = 512 B × 10
    assert prof.collective_bytes == 10 * 512
    assert prof.collective_counts == {"all-gather": 1}


def test_distributed_solve_subprocess():
    """distributed_solve = solve(ShardedGram, …) on 8 virtual devices == dense
    solve, with SolveResult matvec accounting intact. Runs in a subprocess so
    the 8-device platform doesn't leak into this one."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import distributed_solve, shard_training_rows
        from repro.core.kernels_fn import make_params, gram
        from repro.core.solvers.spec import CG
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        n, d = 256, 3
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        y = jnp.sin(x.sum(-1))
        p = make_params("se", lengthscale=1.0, noise=0.2, d=d)
        xs = shard_training_rows(mesh, x)
        res = distributed_solve(p, xs, y, mesh, spec=CG(max_iters=300, tol=1e-8))
        ref = jnp.linalg.solve(gram(p, x) + p.noise * jnp.eye(n), y)
        err = float(jnp.linalg.norm(res.solution - ref))
        assert err < 1e-2, err
        assert int(res.matvecs) == int(res.iterations), (res.matvecs, res.iterations)
        assert bool(res.converged)
        print("OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       timeout=600, env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
