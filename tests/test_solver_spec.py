"""Unified SolverSpec API (core/solvers/spec.py): one solve() entry point,
registry lookup, δ channel, preconditioner specs, legacy shims, façade."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import IterativeGP
from repro.core.pathwise import posterior_functions
from repro.core.solvers.ap import solve_ap
from repro.core.solvers.base import Gram
from repro.core.solvers.cg import solve_cg
from repro.core.solvers.sdd import solve_sdd
from repro.core.solvers.sgd import solve_sgd
from repro.core.solvers.spec import (
    AP,
    CG,
    SDD,
    SGD,
    Nystrom,
    PivotedCholesky,
    SolverSpec,
    as_spec,
    get_solver,
    register_solver,
    registered_solvers,
    solve,
)

KEY = jax.random.PRNGKey(7)

# (spec, legacy function, legacy kwargs) — solve(op, b, spec, key=KEY) must agree
# exactly with the direct call, for all four solver families.
PARITY_CASES = [
    (CG(max_iters=200, tol=1e-6), solve_cg, dict(max_iters=200, tol=1e-6)),
    (
        SGD(num_steps=1500, batch_size=64, step_size_times_n=0.5),
        solve_sgd,
        dict(key=KEY, num_steps=1500, batch_size=64, step_size_times_n=0.5),
    ),
    (
        SDD(num_steps=1500, batch_size=64, step_size_times_n=5.0),
        solve_sdd,
        dict(key=KEY, num_steps=1500, batch_size=64, step_size_times_n=5.0),
    ),
    (
        AP(num_steps=100, block_size=64),
        solve_ap,
        dict(key=KEY, num_steps=100, block_size=64),
    ),
]


@pytest.mark.parametrize(
    "spec,fn,kwargs", PARITY_CASES, ids=[c[0].name for c in PARITY_CASES]
)
def test_solve_matches_direct_call(toy_regression, spec, fn, kwargs):
    """solve(op, b, spec) reproduces the legacy direct solver call bit-for-bit
    (same PRNG key ⇒ same mini-batches / features / blocks)."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    via_spec = solve(op, t["y"], spec, key=KEY)
    direct = fn(op, t["y"], **kwargs)
    np.testing.assert_array_equal(
        np.asarray(via_spec.solution), np.asarray(direct.solution)
    )
    assert int(via_spec.iterations) == int(direct.iterations)


def test_registry_roundtrip():
    assert get_solver("cg") is CG
    assert get_solver("sgd") is SGD
    assert get_solver("sdd") is SDD
    assert get_solver("ap") is AP
    assert set(registered_solvers()) >= {"cg", "sgd", "sdd", "ap"}
    for name in registered_solvers():
        assert get_solver(name).name == name


def test_registry_unknown_name_errors():
    with pytest.raises(ValueError, match="unknown solver"):
        get_solver("cholesky")
    with pytest.raises(ValueError, match="unknown solver"):
        as_spec("not-a-solver")


def test_register_solver_extension_point(toy_regression):
    """Third-party specs plug into the same string-lookup path as the built-ins."""

    @register_solver("cg-tight")
    class TightCG(CG):
        pass

    try:
        assert get_solver("cg-tight") is TightCG
        t = toy_regression
        op = Gram(x=t["x"], params=t["params"])
        res = solve(op, t["y"], "cg-tight", max_iters=300, tol=1e-6)
        np.testing.assert_allclose(res.solution, t["v_star"], atol=1e-3)
    finally:
        from repro.core.solvers import spec as spec_mod

        spec_mod._REGISTRY.pop("cg-tight", None)


def test_string_spec_with_overrides(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    res = solve(op, t["y"], "cg", max_iters=400, tol=1e-5)
    np.testing.assert_allclose(res.solution, t["v_star"], atol=1e-3)
    assert bool(res.converged)


def test_stochastic_solver_requires_key(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    for name in ("sgd", "sdd", "ap"):
        with pytest.raises(ValueError, match="stochastic"):
            solve(op, t["y"], name)


def test_delta_channel_is_uniform(toy_regression):
    """solve(op, b, spec, delta=δ) solves (K+σ²I)V = b + σ²δ for every solver —
    folding for CG/SDD/AP, natively (Eq. 3.6) for SGD."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    delta = 0.5 * jnp.ones_like(t["y"])
    shifted = t["y"] + op.noise * delta

    via_delta = solve(op, t["y"], CG(max_iters=400, tol=1e-8), delta=delta)
    via_rhs = solve(op, shifted, CG(max_iters=400, tol=1e-8))
    np.testing.assert_array_equal(
        np.asarray(via_delta.solution), np.asarray(via_rhs.solution)
    )

    sgd_spec = SGD(num_steps=8000, batch_size=128, step_size_times_n=0.5)
    via_sgd = solve(op, t["y"], sgd_spec, key=KEY, delta=delta)
    ref = jnp.linalg.solve(t["kmat"], shifted)
    k_test = np.asarray(t["kmat"])  # prediction-space comparison (§3.2.4)
    pred_err = np.max(np.abs(k_test @ (np.asarray(via_sgd.solution) - np.asarray(ref))))
    assert pred_err < 0.15, pred_err


def test_converged_respects_solver_tol(toy_regression):
    """finalize() threads the solver's actual tol: a starved budget must report
    converged=False (previously hard-coded rel < 1.0 ⇒ nearly always True)."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    starved = solve(op, t["y"], CG(max_iters=2, tol=1e-10))
    assert not bool(starved.converged)
    starved_sdd = solve(
        op, t["y"], SDD(num_steps=10, batch_size=32, tol=1e-10), key=KEY
    )
    assert not bool(starved_sdd.converged)
    done = solve(op, t["y"], CG(max_iters=400, tol=1e-4))
    assert bool(done.converged)


@pytest.mark.parametrize("pspec", [Nystrom(rank=100), PivotedCholesky(rank=100)])
def test_precond_specs(toy_regression, pspec):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    plain = solve(op, t["y"], CG(max_iters=400, tol=1e-6))
    fast = solve(op, t["y"], CG(max_iters=400, tol=1e-6, precond=pspec), key=KEY)
    assert int(fast.iterations) <= int(plain.iterations)
    np.testing.assert_allclose(fast.solution, t["v_star"], atol=5e-3)


def test_specs_are_static_hashable_pytrees():
    spec = CG(max_iters=50, tol=1e-3, precond=Nystrom(rank=10))
    assert hash(spec) == hash(CG(max_iters=50, tol=1e-3, precond=Nystrom(rank=10)))
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    assert leaves == []  # all-static: usable as a jit static argument / cache key
    assert jax.tree_util.tree_unflatten(treedef, leaves) == spec


def test_legacy_solver_kwarg_removed(toy_regression):
    """The PR-1 `solver=fn` deprecation shims are gone after one release cycle:
    consumers take spec= only, and coerce_spec no longer exists."""
    t = toy_regression
    with pytest.raises(TypeError):
        posterior_functions(
            t["params"], t["x"], t["y"], jax.random.PRNGKey(0),
            num_samples=2, num_features=128, solver=solve_cg,
        )
    import repro.core.solvers.spec as spec_mod

    assert not hasattr(spec_mod, "coerce_spec")
    # spec-field overrides through **kwargs still work
    pf = posterior_functions(
        t["params"], t["x"], t["y"], jax.random.PRNGKey(0),
        num_samples=2, num_features=128, spec="cg", max_iters=50,
    )
    assert pf.alpha.shape == (t["n"], 2)


def test_matvec_only_operator_rejects_row_solvers(toy_regression):
    """Stochastic solvers need row-block capabilities; matvec-only operators get
    a clear capability error (NormalEq stays importable from core.inducing)."""
    from repro.core.inducing import NormalEq

    t = toy_regression
    op = NormalEq(x=t["x"], z=t["x"][:32], params=t["params"])
    rhs = jnp.ones((32, 2))
    with pytest.raises(TypeError, match="rows_mv"):
        solve(op, rhs, "sdd", key=KEY)
    res = solve(op, rhs, CG(max_iters=100, tol=1e-4))
    assert res.solution.shape == (32, 2)


def test_iterative_gp_facade(toy_regression):
    """fit → optimize → predict in three lines, spec-driven end to end."""
    t = toy_regression
    gp = IterativeGP(
        "matern32", lengthscale=0.8, noise=0.3, spec=CG(max_iters=200, tol=1e-6)
    )
    gp.fit(t["x"], t["y"]).optimize(num_steps=2, lr=0.02)
    mu, var = gp.predict(t["x_test"], num_samples=32)
    assert mu.shape == (t["x_test"].shape[0],)
    assert var.shape == mu.shape
    assert np.isfinite(np.asarray(mu)).all() and (np.asarray(var) >= 0).all()
    samples = gp.sample(t["x_test"][:5], num_samples=32)
    assert samples.shape == (5, 32)
    with pytest.raises(RuntimeError, match="fit"):
        IterativeGP().predict(t["x_test"])


# ---------------------------------------------------------------------------
# JSON round-trip (ROADMAP item): run configs and the benchmark harness are
# file-drivable — every registered spec class must survive to_json/from_json.
# ---------------------------------------------------------------------------

from repro.core.solvers.spec import (  # noqa: E402
    get_precond,
    registered_preconds,
    spec_from_dict,
    spec_from_json,
    spec_to_dict,
    spec_to_json,
)


def test_every_registered_solver_spec_roundtrips_json():
    for name in registered_solvers():
        spec = get_solver(name)()  # defaults
        again = SolverSpec.from_json(spec.to_json())
        assert again == spec and type(again) is type(spec)


def test_every_registered_precond_spec_roundtrips_json():
    import dataclasses

    assert set(registered_preconds()) >= {
        "jacobi", "nystrom", "pivoted_cholesky", "rff",
    }
    for name in registered_preconds():
        cls = get_precond(name)
        fields = {f.name for f in dataclasses.fields(cls)}
        pspec = cls(rank=38) if "rank" in fields else cls()  # Jacobi: no fields
        again = spec_from_json(pspec.to_json())
        assert again == pspec and type(again) is type(pspec)


def test_spec_json_roundtrip_nested_and_nondefault():
    spec = CG(max_iters=123, tol=3e-5, precond=Nystrom(rank=17), backend="pallas")
    s = spec_to_json(spec)
    again = spec_from_json(s)
    assert again == spec
    assert again.precond == Nystrom(rank=17)
    assert again.backend == "pallas"
    d = spec_to_dict(spec)
    assert d["solver"] == "cg" and d["precond"]["precond"] == "nystrom"
    assert spec_from_dict(d) == spec
    # stochastic spec with non-default fields
    sdd = SDD(num_steps=77, batch_size=19, step_size_times_n=3.5, backend="chunked")
    assert spec_from_json(sdd.to_json()) == sdd


def test_spec_json_rejects_runtime_objects_and_bad_tags():
    prebuilt = lambda r: r  # noqa: E731 — a prebuilt apply closure
    with pytest.raises(TypeError, match="cannot be serialized"):
        spec_to_json(CG(precond=prebuilt))
    with pytest.raises(ValueError, match="unknown solver"):
        spec_from_dict({"solver": "cholesky"})
    with pytest.raises(ValueError, match="unknown preconditioner"):
        spec_from_dict({"precond": "ilu"})
    with pytest.raises(ValueError, match="tagged"):
        spec_from_dict({"max_iters": 3})


def test_spec_json_drives_solve(toy_regression):
    """A file-loaded spec runs a solve exactly like the in-memory original."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    spec = spec_from_json('{"solver": "cg", "max_iters": 300, "tol": 1e-6}')
    res = solve(op, t["y"], spec)
    np.testing.assert_allclose(res.solution, t["v_star"], atol=1e-3)
