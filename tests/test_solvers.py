"""Iterative linear system solvers vs the dense oracle (Ch. 2.2.4, 3, 4, 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import make_params, gram
from repro.core.solvers.base import Gram
from repro.core.solvers.ap import solve_ap
from repro.core.solvers.cg import solve_cg
from repro.core.solvers.sdd import solve_sdd
from repro.core.solvers.sgd import solve_sgd
from repro.core.precond import nystrom_preconditioner, pivoted_cholesky_preconditioner


def test_cg_converges_to_dense(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    # tol=1e-5: `converged` is judged on the honestly *recomputed* residual, which
    # sits ~1e-6 above CG's internal recursion residual in float32
    res = solve_cg(op, t["y"], max_iters=400, tol=1e-5)
    np.testing.assert_allclose(res.solution, t["v_star"], atol=1e-3)
    assert bool(res.converged)


def test_cg_multi_rhs(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    b = jax.random.normal(jax.random.PRNGKey(0), (t["n"], 5))
    res = solve_cg(op, b, max_iters=400, tol=1e-8)
    ref = jnp.linalg.solve(t["kmat"], b)
    np.testing.assert_allclose(res.solution, ref, atol=2e-3)


def test_sdd_converges_weights(toy_regression):
    """Ch. 4: dual descent reaches the dense solution in weight space."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    res = solve_sdd(op, t["y"], key=jax.random.PRNGKey(1), num_steps=30_000,
                    batch_size=128, step_size_times_n=5.0)
    assert float(jnp.linalg.norm(res.solution - t["v_star"])) < 5e-2 * float(
        jnp.linalg.norm(t["v_star"])
    )


def test_sgd_converges_predictions(toy_regression):
    """Ch. 3 implicit bias: SGD is accurate in PREDICTION space even when slow in
    weight space (§3.2.4)."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    res = solve_sgd(op, t["y"], key=jax.random.PRNGKey(2), num_steps=20_000,
                    batch_size=128, step_size_times_n=0.5)
    k_test = gram(t["params"], t["x_test"], t["x"])
    pred = k_test @ res.solution
    ref = k_test @ t["v_star"]
    err = float(jnp.max(jnp.abs(pred - ref)))
    assert err < 0.08, err


def test_ap_converges(toy_regression):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    res = solve_ap(op, t["y"], key=jax.random.PRNGKey(3), num_steps=2000,
                   block_size=100)
    assert float(res.rel_residual.max()) < 1e-2


def test_warm_start_reduces_iterations(toy_regression):
    """Ch. 5 §5.3: initialising at a nearby solution cuts CG iterations."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    cold = solve_cg(op, t["y"], max_iters=400, tol=1e-6)
    # perturb hyperparameters slightly — the warm start is the old solution
    import dataclasses
    p2 = dataclasses.replace(t["params"], log_lengthscale=t["params"].log_lengthscale + 0.05)
    op2 = Gram(x=t["x"], params=p2)
    cold2 = solve_cg(op2, t["y"], max_iters=400, tol=1e-6)
    warm2 = solve_cg(op2, t["y"], cold.solution, max_iters=400, tol=1e-6)
    assert int(warm2.iterations) < int(cold2.iterations)


def test_early_stopping_budget(toy_regression):
    """§5.4: a fixed iteration budget yields monotone-ish residual decrease."""
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    r10 = solve_cg(op, t["y"], max_iters=10, tol=0.0)
    r50 = solve_cg(op, t["y"], max_iters=50, tol=0.0)
    assert float(r50.rel_residual.max()) < float(r10.rel_residual.max())


@pytest.mark.parametrize("precond_fn", ["nystrom", "pivoted"])
def test_preconditioning_speeds_cg(toy_regression, precond_fn):
    t = toy_regression
    op = Gram(x=t["x"], params=t["params"])
    plain = solve_cg(op, t["y"], max_iters=400, tol=1e-6)
    if precond_fn == "nystrom":
        pc = nystrom_preconditioner(t["params"], t["x"], jax.random.PRNGKey(0), rank=100)
    else:
        pc = pivoted_cholesky_preconditioner(t["params"], t["x"], rank=100)
    fast = solve_cg(op, t["y"], max_iters=400, tol=1e-6, precond=pc)
    assert int(fast.iterations) <= int(plain.iterations)
    np.testing.assert_allclose(fast.solution, t["v_star"], atol=5e-3)


def test_sdd_multiplicative_noise_tolerates_low_noise():
    """Ch. 3/4 headline: iterative solvers stay accurate when σ² is tiny
    (ill-conditioned kernel matrix) — the regime where SVGP diverges."""
    key = jax.random.PRNGKey(0)
    n = 300
    x = jax.random.normal(key, (n, 2))
    y = jnp.sin(x.sum(-1))
    p = make_params("matern32", lengthscale=1.0, noise=0.01, d=2)
    op = Gram(x=x, params=p)
    res = solve_cg(op, y, max_iters=3000, tol=1e-6)
    kmat = gram(p, x) + p.noise * jnp.eye(n)
    ref = jnp.linalg.solve(kmat, y)
    np.testing.assert_allclose(res.solution, ref, atol=2e-2)
