"""Sparse baselines (§2.2.1) + inducing-point pathwise posteriors (§3.2.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gp import exact_posterior, exact_mll
from repro.core.inducing import inducing_posterior, select_inducing_greedy
from repro.core.kernels_fn import gram, make_params
from repro.core.svgp import (
    sgpr, sgpr_elbo, sgpr_iterative, svgp_mean_var, svgp_natgrad_step, SVGPState,
)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    n, d = 600, 2
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2 * x[:, 0]) + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    p = make_params("se", lengthscale=0.8, signal=1.0, noise=0.3, d=d)
    xt = jax.random.normal(jax.random.fold_in(key, 2), (40, d))
    return dict(x=x, y=y, p=p, xt=xt)


def test_sgpr_dense_z_recovers_exact(problem):
    """With Z = X, the Titsias posterior equals the exact GP posterior."""
    t = problem
    post = sgpr(t["p"], t["x"], t["y"], t["x"])
    exact = exact_posterior(t["p"], t["x"], t["y"])
    # fp32 + stabilising ridge (σ-scaled) leave ~1e-2 absolute slack
    np.testing.assert_allclose(post.mean(t["xt"]), exact.mean(t["xt"]), atol=2e-2)


def test_sgpr_elbo_below_exact_mll(problem):
    t = problem
    z = t["x"][::6]
    elbo = float(sgpr_elbo(t["p"], t["x"], t["y"], z))
    mll = float(exact_mll(t["p"], t["x"], t["y"]))
    assert elbo <= mll + 1e-3


def test_sgpr_iterative_matches_dense(problem):
    """sgpr_iterative routes every B⁻¹ application through solve(NormalEq, …)
    and reproduces the dense-Cholesky SGPR posterior (mean and variance)."""
    t = problem
    z = t["x"][::10]
    ref = sgpr(t["p"], t["x"], t["y"], z)
    post = sgpr_iterative(t["p"], t["x"], t["y"], z)
    np.testing.assert_allclose(post.mean(t["xt"]), ref.mean(t["xt"]), atol=5e-2)
    np.testing.assert_allclose(post.var(t["xt"]), ref.var(t["xt"]), atol=5e-2)


def test_svgp_natgrad_converges_to_sgpr(problem):
    """Hensman stochastic natural-gradient steps approach the collapsed optimum."""
    t = problem
    z = t["x"][::10]
    m = z.shape[0]
    state = SVGPState(theta1=jnp.zeros(m), theta2=-0.5 * jnp.eye(m))
    n = t["x"].shape[0]
    # full-batch natural-gradient steps converge to the collapsed (SGPR) optimum
    # exactly (the natgrad fixed point IS the optimal q — Hensman Eqs. 2.53/2.54);
    # minibatch mode adds zero-mean noise around it (exercised with 3 final steps).
    for step in range(25):
        state = svgp_natgrad_step(t["p"], t["x"], t["y"], z, state,
                                  n_total=n, lr=0.5)
    mu_v, _ = svgp_mean_var(t["p"], z, state, t["xt"])
    ref = sgpr(t["p"], t["x"], t["y"], z)
    # fp32 conditioning slack peaks ~0.15 at one of the 40 test points
    # (seed-stable; the K_ZZ⁻¹ solves amplify rounding by κ(K_ZZ))
    np.testing.assert_allclose(mu_v, ref.mean(t["xt"]), atol=0.2)
    key = jax.random.PRNGKey(0)
    for step in range(3):
        idx = jax.random.randint(jax.random.fold_in(key, step), (256,), 0, n)
        state = svgp_natgrad_step(t["p"], t["x"][idx], t["y"][idx], z, state,
                                  n_total=n, lr=0.05)
    mu_b, _ = svgp_mean_var(t["p"], z, state, t["xt"])
    np.testing.assert_allclose(mu_b, ref.mean(t["xt"]), atol=0.25)


def test_inducing_pathwise_posterior(problem):
    """§3.2.3: pathwise inducing-point posterior matches SGPR moments."""
    t = problem
    z = t["x"][::4]
    post = inducing_posterior(t["p"], t["x"], t["y"], z, jax.random.PRNGKey(1),
                              num_samples=256, num_features=4096)
    ref = sgpr(t["p"], t["x"], t["y"], z)
    np.testing.assert_allclose(post.mean(t["xt"]), ref.mean(t["xt"]), atol=5e-2)
    f = post(t["xt"])
    var_ref = ref.var(t["xt"])
    np.testing.assert_allclose(jnp.var(f, axis=1), var_ref, atol=0.12)


def test_select_inducing_greedy_spread():
    x = jax.random.normal(jax.random.PRNGKey(0), (200, 2))
    z = select_inducing_greedy(x, 20, jax.random.PRNGKey(1))
    assert z.shape == (20, 2)
    # selected points are distinct (greedy k-centre style spread)
    d = np.linalg.norm(np.asarray(z)[:, None] - np.asarray(z)[None], axis=-1)
    np.fill_diagonal(d, np.inf)
    assert d.min() > 1e-6
