"""Parallel Thompson sampling (§3.3.2 / §4.3.2) on a small toy problem."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import make_params
from repro.core.rff import sample_prior
from repro.core.thompson import ThompsonState, thompson_step


def test_thompson_improves_over_random():
    d = 2
    key = jax.random.PRNGKey(0)
    p = make_params("matern32", lengthscale=0.3, signal=1.0, noise=0.01, d=d)
    target_prior = sample_prior(p, jax.random.PRNGKey(42), 1, 2048, d)

    def objective(x):
        return target_prior(x)[:, 0]

    n0 = 100
    x0 = jax.random.uniform(jax.random.fold_in(key, 1), (n0, d))
    y0 = objective(x0)
    state = ThompsonState(x=x0, y=y0, best=float(y0.max()))
    best0 = state.best
    for step in range(3):
        from repro.core.solvers.spec import CG

        state = thompson_step(
            p, state, objective, jax.random.fold_in(key, 10 + step),
            acq_batch=16, num_candidates=256, num_top=4, ascent_steps=20,
            spec=CG(max_iters=100),
        )
    # random-search baseline with the same total evaluation budget
    xr = jax.random.uniform(jax.random.fold_in(key, 99), (3 * 16, d))
    best_rand = float(jnp.maximum(objective(xr).max(), best0))
    assert state.best >= best0
    assert state.best >= best_rand - 0.15  # at least competitive with random
    assert state.x.shape[0] == n0 + 3 * 16
