"""Training substrate: loss goes down, checkpoint/restart is exact, gradient
compression with error feedback, straggler detection, curve-GP integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import grid_curves, token_batch
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.compress import (
    compress, decompress, init_error_state, tree_compress_with_feedback,
    tree_decompress,
)
from repro.train.curve_gp import divergence_score, fit_curve_gp, should_stop_early
from repro.train.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return get_config("olmo-1b").reduced(num_layers=2, d_model=64, num_heads=2,
                                         num_kv_heads=2, d_ff=128, head_dim=32,
                                         vocab_size=128)


def test_loss_decreases():
    cfg = _tiny_cfg()
    tc = TrainerConfig(batch=4, seq_len=32, num_steps=40, log_every=0,
                       opt=AdamWConfig(lr=3e-3, warmup_steps=10, mu_dtype=jnp.float32))
    tr = Trainer(cfg, tc)
    tr.run()
    first = np.mean(tr.losses[:5])
    last = np.mean(tr.losses[-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_exact(tmp_path):
    """Kill-and-resume reproduces the uninterrupted run bit-for-bit (stateless
    data pipeline + atomic step-tagged checkpoints)."""
    cfg = _tiny_cfg()

    def make(steps, ckpt_dir):
        tc = TrainerConfig(batch=4, seq_len=32, num_steps=steps, log_every=0,
                           ckpt_dir=ckpt_dir, ckpt_every=10,
                           opt=AdamWConfig(lr=1e-3, mu_dtype=jnp.float32))
        return Trainer(cfg, tc)

    # uninterrupted 20 steps
    t_full = make(20, str(tmp_path / "full"))
    p_full, _ = t_full.run()
    # interrupted: run 10, then "crash" and resume to 20 in a fresh Trainer
    t_a = make(10, str(tmp_path / "resume"))
    t_a.run()
    assert latest_step(str(tmp_path / "resume")) == 10
    t_b = make(20, str(tmp_path / "resume"))
    p_res, _ = t_b.run()
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    save_checkpoint(d, 5, tree)
    # partial tmp dirs are ignored
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert latest_step(d) == 5
    restored, step, _ = restore_checkpoint(d, tree)
    assert step == 5
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_token_pipeline_stateless_and_learnable():
    b1 = token_batch(0, 7, 4, 16, 97)
    b2 = token_batch(0, 7, 4, 16, 97)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = token_batch(0, 8, 4, 16, 97)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # planted bigram: majority of transitions follow next = 31·cur + 17 (mod V)
    toks = np.asarray(b1["tokens"])
    labs = np.asarray(b1["labels"])
    hits = (labs == (31 * toks + 17) % 97).mean()
    assert hits > 0.5


def test_compression_error_feedback_unbiased():
    """Error feedback: the cumulative decompressed sum tracks the true sum."""
    key = jax.random.PRNGKey(0)
    g = {"a": jax.random.normal(key, (64, 64)) * 1e-3}
    err = init_error_state(g)
    total_true = jnp.zeros((64, 64))
    total_comp = jnp.zeros((64, 64))
    for t in range(30):
        gt = {"a": g["a"] * (1.0 + 0.1 * t)}
        comp, err = tree_compress_with_feedback(gt, err, jax.random.fold_in(key, t))
        dec = tree_decompress(comp, gt)
        total_true += gt["a"]
        total_comp += dec["a"]
    rel = float(jnp.linalg.norm(total_comp - total_true) / jnp.linalg.norm(total_true))
    assert rel < 0.05, rel


def test_compression_roundtrip_quantisation():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1000,))
    c = compress(x, jax.random.fold_in(key, 1))
    x2 = decompress(c)
    assert c.q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(x2 - x))) <= float(c.scale) + 1e-6


def test_straggler_detection():
    cfg = _tiny_cfg()
    tc = TrainerConfig(batch=2, seq_len=16, num_steps=1, log_every=0)
    tr = Trainer(cfg, tc)
    tr.step_times = [0.1] * 18 + [0.5, 0.1]
    rep = tr.straggler_report()
    assert len(rep.slow_steps) == 1
    assert abs(rep.median_s - 0.1) < 1e-6


def test_curve_gp_prediction_and_pruning():
    data = grid_curves(n_configs=24, n_steps=30, density=0.7, seed=0)
    pred = fit_curve_gp(data["curves"], data["mask"], data["grid1"],
                        max_iters=200, num_samples=32)
    # predictions on observed cells match the observed losses
    m = np.asarray(data["mask"])
    err = np.abs(np.asarray(pred.mean) - np.asarray(data["curves"]))[m]
    assert err.mean() < 0.1, err.mean()
    # the worst predicted config should be prunable against the best
    worst = int(np.argmax(np.asarray(pred.final_mean)))
    best = int(np.argmin(np.asarray(pred.final_mean)))
    if pred.final_mean[worst] - pred.final_mean[best] > 2 * pred.final_std[worst]:
        assert should_stop_early(pred, worst, margin=1.0)
    assert not should_stop_early(pred, best, margin=1.0)
    # divergence scoring: a wildly wrong loss has a big z-score
    z = divergence_score(pred, 0, 10, float(data["curves"][0, 10]) + 10.0)
    assert z > 3.0
